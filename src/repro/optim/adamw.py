"""AdamW + cosine schedule + global-norm clipping, pure JAX (no optax).

Optimizer moments inherit the parameter shardings (ZeRO under policy
fsdp_tp, where params themselves are sharded over the data axes).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(jnp.zeros((), jnp.int32),
                      jax.tree_util.tree_map(zeros, params),
                      jax.tree_util.tree_map(zeros, params))


def cosine_schedule(step, *, peak_lr: float, warmup: int, total: int,
                    min_ratio: float = 0.1):
    step = step.astype(jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup, 1)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = peak_lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup, warm, cos)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), gn


def adamw_update(params, grads, state: AdamWState, *, lr, b1: float = 0.9,
                 b2: float = 0.95, eps: float = 1e-8, wd: float = 0.1):
    step = state.step + 1
    t = step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g32
        v2 = b2 * v + (1 - b2) * g32 * g32
        mhat = m2 / (1 - b1 ** t)
        vhat = v2 / (1 - b2 ** t)
        delta = mhat / (jnp.sqrt(vhat) + eps) + wd * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    out = jax.tree_util.tree_map(upd, params, grads, state.m, state.v)
    # unzip the 3-tuples
    new_p = jax.tree_util.tree_map(lambda o: o[0], out,
                                   is_leaf=lambda o: isinstance(o, tuple) and len(o) == 3 and not isinstance(o[0], tuple))
    new_m = jax.tree_util.tree_map(lambda o: o[1], out,
                                   is_leaf=lambda o: isinstance(o, tuple) and len(o) == 3 and not isinstance(o[0], tuple))
    new_v = jax.tree_util.tree_map(lambda o: o[2], out,
                                   is_leaf=lambda o: isinstance(o, tuple) and len(o) == 3 and not isinstance(o[0], tuple))
    return new_p, AdamWState(step, new_m, new_v)
