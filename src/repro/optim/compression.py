"""Gradient compression for data-parallel reductions.

int8 uniform quantization with per-tensor scales and error feedback
(residual carry), the standard bandwidth/quality trade for DP gradient
all-reduce at multi-pod scale: wire bytes drop 4x vs fp32 (2x vs bf16), and
the error-feedback state makes the compression bias vanish over steps.

Plugs into an explicit-DP training loop (see tests/test_compression.py for
the shard_map reduction pattern).  Under GSPMD policies the backward's
implicit reductions cannot be intercepted; use policy "broadcast" + explicit
reduce for compressed-gradient training.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class CompressedGrad(NamedTuple):
    q: jax.Array        # int8 payload
    scale: jax.Array    # () fp32


def quantize(g: jax.Array, residual: Optional[jax.Array] = None,
             key: Optional[jax.Array] = None) -> Tuple[CompressedGrad, jax.Array]:
    """int8-quantize g (+ residual carry); returns (compressed, new_residual).

    With `key`, stochastic rounding (unbiased); otherwise round-to-nearest.
    """
    g32 = g.astype(jnp.float32)
    if residual is not None:
        g32 = g32 + residual
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    x = g32 / scale
    if key is not None:
        noise = jax.random.uniform(key, x.shape, jnp.float32) - 0.5
        q = jnp.clip(jnp.round(x + noise), -127, 127).astype(jnp.int8)
    else:
        q = jnp.clip(jnp.round(x), -127, 127).astype(jnp.int8)
    new_residual = g32 - q.astype(jnp.float32) * scale
    return CompressedGrad(q, scale), new_residual


def dequantize(c: CompressedGrad) -> jax.Array:
    return c.q.astype(jnp.float32) * c.scale


def compressed_psum(c: CompressedGrad, axis_name: str) -> jax.Array:
    """All-reduce a compressed gradient inside shard_map: int8 payloads are
    summed in int32 (wire = 1 byte/elem), scales are maxed, result dequantized
    against the max scale.  Conservative (scale-max) variant: bias-free with
    error feedback on each worker."""
    # payload travels as int8; accumulate in int32 to avoid overflow
    total = jax.lax.psum(c.q.astype(jnp.int32), axis_name)
    # each worker used its own scale; sum of (q_i * s_i) is approximated by
    # psum(q_i * (s_i / s_max)) * s_max — rescale before the reduction
    s_max = jax.lax.pmax(c.scale, axis_name)
    rescaled = jax.lax.psum(
        (c.q.astype(jnp.float32) * (c.scale / s_max)), axis_name)
    return rescaled * s_max, total  # (value, raw int sum for tests)


def tree_quantize(grads, residuals=None):
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    res_leaves = (jax.tree_util.tree_leaves(residuals)
                  if residuals is not None else [None] * len(leaves))
    out, new_res = [], []
    for g, r in zip(leaves, res_leaves):
        c, nr = quantize(g, r)
        out.append(c)
        new_res.append(nr)
    return (jax.tree_util.tree_unflatten(treedef, out),
            jax.tree_util.tree_unflatten(treedef, new_res))


def tree_dequantize(ctree):
    return jax.tree_util.tree_map(dequantize, ctree,
                                  is_leaf=lambda x: isinstance(x, CompressedGrad))


def compression_ratio(grads) -> float:
    """Wire bytes (int8 + scale) / fp32 bytes."""
    import numpy as np
    n = sum(int(np.prod(g.shape)) for g in jax.tree_util.tree_leaves(grads))
    n_t = len(jax.tree_util.tree_leaves(grads))
    return (n * 1 + n_t * 4) / (n * 4)
