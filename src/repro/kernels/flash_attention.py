"""Pallas TPU flash attention: blocked online-softmax with causal/local
block masking and GQA via index-map head folding.

Layout: q (B,H,S,hd), k/v (B,KV,S,hd).  Grid (B, H, nq, nk) with the kv
dimension "arbitrary" (sequential) so the (m, l, acc) VMEM scratch carries
across kv blocks.  Block sizes default to (512, 512) — MXU-aligned, and the
working set  q(512,hd) + k/v(512,hd) + p(512,512)  fits VMEM at hd<=256.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  causal: bool, window: int, bq: int, bk: int, s_valid: int,
                  scale: float):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = i * bq
    k_start = j * bk
    needed = k_start < s_valid
    if causal:
        needed &= k_start <= q_start + bq - 1
    if window:
        needed &= k_start + bk > q_start - window

    @pl.when(needed)
    def _block():
        q = q_ref[0, 0].astype(jnp.float32)                  # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qi = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        ki = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        ok = ki < s_valid
        if causal:
            ok &= ki <= qi
        if window:
            ok &= ki > qi - window
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_scr[:, :1]                                # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = jnp.broadcast_to(
            l_scr[:, :1] * corr + jnp.sum(p, axis=1, keepdims=True),
            l_scr.shape)
        v = v_ref[0, 0].astype(jnp.float32)                  # (bk, hd)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)

    @pl.when(j == pl.num_programs(3) - 1)
    def _emit():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[:, :1], 1e-30)).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True, window: int = 0,
                         block_q: int = 512, block_kv: int = 512,
                         interpret: bool = False):
    """q: (B,H,S,hd), k/v: (B,KV,S,hd) -> (B,H,S,hd)."""
    B, H, S, hd = q.shape
    KV = k.shape[1]
    G = H // KV
    bq = min(block_q, S)
    bk = min(block_kv, S)
    pad_q = (-S) % bq
    pad_k = (-S) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nq = (S + pad_q) // bq
    nk = (S + pad_k) // bk

    kernel = functools.partial(_flash_kernel, causal=causal, window=window,
                               bq=bq, bk=bk, s_valid=S,
                               scale=1.0 / math.sqrt(hd))
    grid = (B, H, nq, nk)
    try:
        cparams = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"))
    except Exception:  # older API spelling
        cparams = pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S + pad_q, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=cparams,
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :S]
