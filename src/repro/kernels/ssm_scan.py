"""Pallas TPU chunked selective-scan kernel (Mamba / RG-LRU style diagonal
recurrence  h_t = a_t * h_{t-1} + b_t).

Grid (B, n_channel_blocks, n_chunks) with the chunk dimension sequential:
the carry h lives in VMEM scratch across chunks; within a chunk the
recurrence closes with an associative scan over the loaded block, so the
sequential depth is n_chunks, not S.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(a_ref, b_ref, h0_ref, hs_ref, hT_ref, h_scr, *, chunk: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)                     # (chunk, bD, N)
    b = b_ref[0].astype(jnp.float32)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    acc_a, acc_b = jax.lax.associative_scan(combine, (a, b), axis=0)
    h_all = acc_a * h_scr[...][None] + acc_b             # (chunk, bD, N)
    hs_ref[0] = h_all.astype(hs_ref.dtype)
    h_scr[...] = h_all[-1]

    @pl.when(c == pl.num_programs(2) - 1)
    def _emit():
        hT_ref[0] = h_scr[...].astype(hT_ref.dtype)


def ssm_scan_blocked(a_bar, b_bar, h0, *, chunk: int = 64,
                     block_d: int = 512, interpret: bool = False):
    """a_bar,b_bar: (B,S,D,N) fp32; h0: (B,D,N).  Returns (h_seq, h_final)."""
    B, S, D, N = a_bar.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        a_bar = jnp.pad(a_bar, ((0, 0), (0, pad), (0, 0), (0, 0)),
                        constant_values=1.0)
        b_bar = jnp.pad(b_bar, ((0, 0), (0, pad), (0, 0), (0, 0)))
    bD = min(block_d, D)
    nc = (S + pad) // chunk
    grid = (B, D // bD, nc)
    kernel = functools.partial(_scan_kernel, chunk=chunk)
    try:
        cparams = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    except Exception:
        cparams = pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    hs, hT = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, bD, N), lambda b, d, c: (b, c, d, 0)),
            pl.BlockSpec((1, chunk, bD, N), lambda b, d, c: (b, c, d, 0)),
            pl.BlockSpec((1, bD, N), lambda b, d, c: (b, d, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, bD, N), lambda b, d, c: (b, c, d, 0)),
            pl.BlockSpec((1, bD, N), lambda b, d, c: (b, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S + pad, D, N), jnp.float32),
            jax.ShapeDtypeStruct((B, D, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bD, N), jnp.float32)],
        compiler_params=cparams,
        interpret=interpret,
    )(a_bar, b_bar, h0)
    return hs[:, :S], hT
