"""Pallas TPU flash-decode: single-query attention over a KV cache, split
across the cache length so the memory-bound cache read parallelizes over
grid cells; per-split (m, l, acc) partials are merged by a cheap log-sum-exp
combine in the ops wrapper.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, acc_ref, ml_ref, *,
                   ls: int, scale: float):
    s_idx = pl.program_id(2)
    q = q_ref[0, 0].reshape(1, -1).astype(jnp.float32)        # (1, hd)
    k = k_ref[0, 0].astype(jnp.float32)                       # (ls, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale  # (1, ls)
    length = len_ref[0, 0]
    pos = s_idx * ls + jax.lax.broadcasted_iota(jnp.int32, (1, ls), 1)
    s = jnp.where(pos < length, s, NEG_INF)
    m = jnp.max(s)
    p = jnp.exp(s - m)
    l = jnp.sum(p)
    v = v_ref[0, 0].astype(jnp.float32)                       # (ls, hd)
    acc = jax.lax.dot(p, v, preferred_element_type=jnp.float32)  # (1, hd)
    acc_ref[0, 0, 0] = acc[0]
    # lanes [0:64) carry m, lanes [64:128) carry l
    ml_ref[0, 0, 0] = jnp.concatenate(
        [jnp.full((64,), m, jnp.float32), jnp.full((64,), l, jnp.float32)])


def decode_attention_bhd(q, k, v, lengths, *, n_splits: int = 8,
                         interpret: bool = False):
    """q: (B,H,hd); k,v: (B,KV,L,hd); lengths: (B,) -> (B,H,hd)."""
    B, H, hd = q.shape
    KV, L = k.shape[1], k.shape[2]
    G = H // KV
    while L % n_splits:
        n_splits //= 2
    n_splits = max(n_splits, 1)
    ls = L // n_splits
    kernel = functools.partial(_decode_kernel, ls=ls,
                               scale=1.0 / math.sqrt(hd))
    grid = (B, H, n_splits)
    try:
        cparams = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel"))
    except Exception:
        cparams = pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel"))
    acc, ml = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, hd), lambda b, h, s: (b, h, 0)),
            pl.BlockSpec((1, 1, ls, hd), lambda b, h, s: (b, h // G, s, 0)),
            pl.BlockSpec((1, 1, ls, hd), lambda b, h, s: (b, h // G, s, 0)),
            pl.BlockSpec((1, 1), lambda b, h, s: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, hd), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, 1, 128), lambda b, h, s: (b, h, s, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, n_splits, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, H, n_splits, 128), jnp.float32),
        ],
        compiler_params=cparams,
        interpret=interpret,
    )(q, k, v, lengths.reshape(B, 1).astype(jnp.int32))

    m = ml[..., 0]                                            # (B,H,ns)
    l = ml[..., 64]
    m_g = jnp.max(m, axis=-1, keepdims=True)
    w = jnp.exp(m - m_g)
    l_g = jnp.sum(l * w, axis=-1)
    out = jnp.sum(acc * w[..., None], axis=2) / jnp.maximum(
        l_g[..., None], 1e-30)
    return out.astype(q.dtype)
