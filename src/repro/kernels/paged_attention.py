"""Pallas TPU paged decode attention: single-query attention over a
block-pooled KV cache, gathered *inside* the kernel through a per-sequence
block table.

The dense flash-decode kernel (``decode_attention.py``) reads a contiguous
``(B, L, KV, hd)`` cache; here K/V live in one shared pool
``(num_blocks, block_size, KV, hd)`` and each sequence names its blocks in
``block_tables (B, nb)``.  The block table and the valid lengths ride in as
*scalar prefetch* operands, so the grid's last (sequential) dimension walks
a sequence's blocks and the BlockSpec ``index_map`` resolves the physical
pool row **before** the kernel body runs — the DMA engine fetches exactly
the blocks the sequence owns, never a dense ``max_len`` stripe.  Per-block
``(m, l, acc)`` partials accumulate across the sequential grid dimension in
VMEM scratch (the standard online-softmax pattern), and blocks past the
sequence's length are skipped entirely with ``@pl.when``.

On CPU (tests) this runs with ``interpret=True`` against
``ref.paged_decode_attention_ref``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _paged_decode_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref, *, bs: int, scale: float):
    """Grid (B, KV, nb); the last dimension is sequential per (b, h).

    q_ref: (1, 1, G, hd) queries of this kv head's group
    k_ref/v_ref: (1, bs, 1, hd) — the pool block named by bt[b, j]
    o_ref: (1, 1, G, hd); m/l/acc: VMEM scratch carried across j.
    """
    b = pl.program_id(0)
    j = pl.program_id(2)
    n_blocks = pl.num_programs(2)
    length = len_ref[b]

    @pl.when(j == 0)
    def _init():
        m_ref[:] = jnp.full_like(m_ref, NEG_INF)
        l_ref[:] = jnp.zeros_like(l_ref)
        acc_ref[:] = jnp.zeros_like(acc_ref)

    @pl.when(j * bs < length)
    def _block():
        q = q_ref[0, 0].astype(jnp.float32)                   # (G, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)                # (bs, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
        s = jnp.where(pos < length, s, NEG_INF)               # (G, bs)
        m_prev = m_ref[:, :1]                                 # (G, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[:, :1] = l_ref[:, :1] * corr + \
            jnp.sum(p, axis=-1, keepdims=True)
        v = v_ref[0, :, 0].astype(jnp.float32)                # (bs, hd)
        acc_ref[:] = acc_ref[:] * corr + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[:, :1] = m_new

    @pl.when(j == n_blocks - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[:] /
                       jnp.maximum(l_ref[:, :1], 1e-30)).astype(o_ref.dtype)


def paged_decode_attention_bkgd(q, k_pool, v_pool, block_tables, lengths, *,
                                interpret: bool = False):
    """q: (B, KV, G, hd); k_pool/v_pool: (num_blocks, bs, KV, hd);
    block_tables: (B, nb) int32; lengths: (B,) int32 -> (B, KV, G, hd)."""
    B, KV, G, hd = q.shape
    bs = k_pool.shape[1]
    nb = block_tables.shape[1]
    kernel = functools.partial(_paged_decode_kernel, bs=bs,
                               scale=1.0 / math.sqrt(hd))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,           # block_tables, lengths
        grid=(B, KV, nb),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, j, bt, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, hd),
                         lambda b, h, j, bt, ln: (bt[b, j], 0, h, 0)),
            pl.BlockSpec((1, bs, 1, hd),
                         lambda b, h, j, bt, ln: (bt[b, j], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda b, h, j, bt, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 128), jnp.float32),       # running max (col 0)
            pltpu.VMEM((G, 128), jnp.float32),       # running sum (col 0)
            pltpu.VMEM((G, hd), jnp.float32),        # output accumulator
        ],
    )
    try:
        cparams = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    except Exception:
        cparams = pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        compiler_params=cparams,
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
      q, k_pool, v_pool)


def _paged_extend_kernel(bt_ref, pos0_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref, *, bs: int, S: int, G: int,
                         scale: float):
    """Grid (B, KV, nb); the last dimension is sequential per (b, h).

    The extend sibling of :func:`_paged_decode_kernel`: ``S`` suffix
    queries per sequence (absolute positions ``pos0[b] + s``) run online
    softmax over the prefix blocks *and* the in-flight suffix (already
    scattered into the pool), masked causally over absolute positions —
    key position p is visible to query s iff ``p <= pos0[b] + s``, the
    dense oracle's mask.  Scratch rows are the S*G flattened
    (query, group-head) pairs carried across j.

    q_ref: (1, S, 1, G, hd); k_ref/v_ref: (1, bs, 1, hd) — pool block
    bt[b, j]; o_ref: (1, S, 1, G, hd).
    """
    b = pl.program_id(0)
    j = pl.program_id(2)
    n_blocks = pl.num_programs(2)
    p0 = pos0_ref[b]
    hd = q_ref.shape[-1]

    @pl.when(j == 0)
    def _init():
        m_ref[:] = jnp.full_like(m_ref, NEG_INF)
        l_ref[:] = jnp.zeros_like(l_ref)
        acc_ref[:] = jnp.zeros_like(acc_ref)

    @pl.when(j * bs < p0 + S)
    def _block():
        q = q_ref[0, :, 0].astype(jnp.float32).reshape(S * G, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)                # (bs, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        key_pos = j * bs + jax.lax.broadcasted_iota(
            jnp.int32, (S * G, bs), 1)
        q_pos = p0 + jax.lax.broadcasted_iota(
            jnp.int32, (S * G, bs), 0) // G
        s = jnp.where(key_pos <= q_pos, s, NEG_INF)           # (S*G, bs)
        m_prev = m_ref[:, :1]                                 # (S*G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[:, :1] = l_ref[:, :1] * corr + \
            jnp.sum(p, axis=-1, keepdims=True)
        v = v_ref[0, :, 0].astype(jnp.float32)                # (bs, hd)
        acc_ref[:] = acc_ref[:] * corr + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[:, :1] = m_new

    @pl.when(j == n_blocks - 1)
    def _finish():
        o_ref[0, :, 0] = (acc_ref[:] /
                          jnp.maximum(l_ref[:, :1], 1e-30)
                          ).reshape(S, G, hd).astype(o_ref.dtype)


def paged_extend_attention_bkgd(q, k_pool, v_pool, block_tables, pos0, *,
                                interpret: bool = False):
    """q: (B, S, KV, G, hd) suffix queries; k_pool/v_pool:
    (num_blocks, bs, KV, hd); block_tables: (B, nb) int32; pos0: (B,)
    int32 absolute position of each row's first query
    -> (B, S, KV, G, hd).  Suffix K/V must already be scattered into the
    pool (the kernel reads them back through the table like any prefix
    block — one code path, no separate in-flight operand)."""
    B, S, KV, G, hd = q.shape
    bs = k_pool.shape[1]
    nb = block_tables.shape[1]
    kernel = functools.partial(_paged_extend_kernel, bs=bs, S=S, G=G,
                               scale=1.0 / math.sqrt(hd))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,           # block_tables, pos0
        grid=(B, KV, nb),
        in_specs=[
            pl.BlockSpec((1, S, 1, G, hd),
                         lambda b, h, j, bt, p0: (b, 0, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, hd),
                         lambda b, h, j, bt, p0: (bt[b, j], 0, h, 0)),
            pl.BlockSpec((1, bs, 1, hd),
                         lambda b, h, j, bt, p0: (bt[b, j], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, S, 1, G, hd),
                               lambda b, h, j, bt, p0: (b, 0, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((S * G, 128), jnp.float32),   # running max (col 0)
            pltpu.VMEM((S * G, 128), jnp.float32),   # running sum (col 0)
            pltpu.VMEM((S * G, hd), jnp.float32),    # output accumulator
        ],
    )
    try:
        cparams = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    except Exception:
        cparams = pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, S, KV, G, hd), q.dtype),
        compiler_params=cparams,
        interpret=interpret,
    )(block_tables.astype(jnp.int32), pos0.astype(jnp.int32),
      q, k_pool, v_pool)
