"""Pure-jnp oracles for every Pallas kernel (the ground truth the kernels
are validated against in tests, shape/dtype-swept)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B,S,H,hd)  k,v: (B,S,KV,hd).  Masked full attention, fp32 math."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    qi = jnp.arange(S)[:, None]
    si = jnp.arange(S)[None, :]
    ok = jnp.ones((S, S), bool)
    if causal:
        ok &= si <= qi
    if window:
        ok &= si > qi - window
    s = jnp.where(ok[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, v.shape[-1]).astype(q.dtype)


def decode_attention_ref(q, k, v, lengths):
    """q: (B,H,hd) single query; k,v: (B,L,KV,hd); lengths: (B,) valid prefix.
    Returns (B,H,hd)."""
    B, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    L = k.shape[1]
    ok = jnp.arange(L)[None, :] < lengths[:, None]
    s = jnp.where(ok[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskh->bkgh", p, v.astype(jnp.float32))
    return o.reshape(B, H, hd).astype(q.dtype)


def paged_decode_attention_ref(q, k_pool, v_pool, block_tables, lengths):
    """Single-query decode attention through a block table.

    q: (B,H,hd); k_pool/v_pool: (num_blocks, bs, KV, hd) — the shared
    device pool; block_tables: (B, nb) int32 physical block ids backing
    each sequence's virtual positions (padded with the null block);
    lengths: (B,) valid prefix length.  Returns (B,H,hd).

    The gather ``pool[bt]`` materializes each sequence's virtual cache
    ``(B, nb*bs, KV, hd)`` and then this is exactly
    :func:`decode_attention_ref` — which is what makes it both the
    XLA fallback inside the model and the oracle for the Pallas kernel.
    """
    B = q.shape[0]
    bs = k_pool.shape[1]
    nb = block_tables.shape[1]
    k = k_pool[block_tables].reshape(B, nb * bs, *k_pool.shape[2:])
    v = v_pool[block_tables].reshape(B, nb * bs, *v_pool.shape[2:])
    return decode_attention_ref(q, k, v, lengths)


def paged_extend_attention_ref(q, k_pool, v_pool, block_tables, pos0):
    """Suffix-extend attention through a block table.

    q: (B,S,H,hd) queries at absolute positions ``pos0 + s``; pools and
    tables as in :func:`paged_decode_attention_ref`; pos0: (B,) absolute
    position of each row's first query.  Key at virtual position p is
    visible to query s iff ``p <= pos0 + s`` — causal over absolute
    positions, exactly the dense extend mask.  Returns (B,S,H,hd)."""
    B, S, H, hd = q.shape
    bs = k_pool.shape[1]
    nb = block_tables.shape[1]
    KV = k_pool.shape[2]
    G = H // KV
    k = k_pool[block_tables].reshape(B, nb * bs, *k_pool.shape[2:])
    v = v_pool[block_tables].reshape(B, nb * bs, *v_pool.shape[2:])
    qg = q.reshape(B, S, KV, G, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    L = nb * bs
    positions = pos0[:, None] + jnp.arange(S)[None, :]
    ok = jnp.arange(L)[None, None, :] <= positions[:, :, None]
    s = jnp.where(ok[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, v.shape[-1]).astype(q.dtype)


def pair_score_ref(claims, evidence, W, w_c, w_e, bias):
    """The paper's phase-2 Cartesian scoring: (N,d) x (M,d) -> (N,M)."""
    bil = (claims.astype(jnp.float32) @ W.astype(jnp.float32)) @ evidence.astype(jnp.float32).T
    lin = (claims.astype(jnp.float32) @ w_c)[:, None] + (evidence.astype(jnp.float32) @ w_e)[None, :]
    return bil + lin + bias


def ssm_scan_ref(a_bar, b_bar, h0):
    """Diagonal SSM recurrence h_t = a_t * h_{t-1} + b_t.
    a_bar, b_bar: (B,S,D,N) fp32; h0: (B,D,N).  Returns (h_seq, h_final)."""
    def step(h, ab):
        a, b = ab
        h = a * h + b
        return h, h
    hT, hs = jax.lax.scan(step, h0, (a_bar.transpose(1, 0, 2, 3),
                                     b_bar.transpose(1, 0, 2, 3)))
    return hs.transpose(1, 0, 2, 3), hT
