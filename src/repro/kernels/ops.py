"""jit'd public wrappers around the Pallas kernels.

On CPU (this container) they run with interpret=True and are validated
against ref.py / the pure-jnp model paths; on TPU interpret=False lowers to
Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.decode_attention import decode_attention_bhd
from repro.kernels.paged_attention import (paged_decode_attention_bkgd,
                                           paged_extend_attention_bkgd)
from repro.kernels.pair_score import pair_score_blocked
from repro.kernels.ssm_scan import ssm_scan_blocked


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_kv", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 512, block_kv: int = 512,
                    interpret: bool = False):
    """q: (B,S,H,hd), k/v: (B,S,KV,hd) -> (B,S,H,hd)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                               block_q=block_q, block_kv=block_kv,
                               interpret=interpret)
    return out.transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("n_splits", "interpret"))
def decode_attention(q, k, v, lengths, *, n_splits: int = 8,
                     interpret: bool = False):
    """q: (B,H,hd); k/v: (B,L,KV,hd) caches; lengths: (B,) -> (B,H,hd)."""
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    return decode_attention_bhd(q, kt, vt, lengths, n_splits=n_splits,
                                interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q, k_pool, v_pool, block_tables, lengths, *,
                           interpret: bool = False):
    """q: (B,H,hd); k_pool/v_pool: (num_blocks, bs, KV, hd) shared pools;
    block_tables: (B, nb); lengths: (B,) -> (B,H,hd).

    The kernel gathers K/V through the block table inside the grid (scalar
    prefetch resolves physical pool rows), so no dense per-sequence cache
    is ever materialized."""
    B, H, hd = q.shape
    KV = k_pool.shape[2]
    G = H // KV
    out = paged_decode_attention_bkgd(q.reshape(B, KV, G, hd),
                                      k_pool, v_pool, block_tables, lengths,
                                      interpret=interpret)
    return out.reshape(B, H, hd)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_extend_attention(q, k_pool, v_pool, block_tables, pos0, *,
                           interpret: bool = False):
    """q: (B,S,H,hd) suffix queries at absolute positions ``pos0 + s``;
    k_pool/v_pool: (num_blocks, bs, KV, hd) shared pools (suffix K/V
    already scattered in); block_tables: (B, nb); pos0: (B,)
    -> (B,S,H,hd).

    The paged-prefill/extend sibling of :func:`paged_decode_attention`:
    online softmax over the prefix blocks + in-flight suffix, block
    tables scalar-prefetched, masked like the dense oracle
    (key p visible to query s iff p <= pos0 + s)."""
    B, S, H, hd = q.shape
    KV = k_pool.shape[2]
    G = H // KV
    out = paged_extend_attention_bkgd(q.reshape(B, S, KV, G, hd),
                                      k_pool, v_pool, block_tables, pos0,
                                      interpret=interpret)
    return out.reshape(B, S, H, hd)


@functools.partial(jax.jit, static_argnames=("block_n", "block_m", "interpret"))
def pair_score(link_params, claims, evidence, *, block_n: int = 128,
               block_m: int = 128, interpret: bool = False):
    """Blocked bilinear pair scoring; same contract as
    svm.link_score_matrix (full-rank W form)."""
    d = claims.shape[-1]
    return pair_score_blocked(claims, evidence, link_params["W"],
                              link_params["w"][:d], link_params["w"][d:],
                              link_params["bias"], block_n=block_n,
                              block_m=block_m, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "block_d", "interpret"))
def ssm_scan(xc, dt, Bc, Cc, A, D, h0=None, *, chunk: int = 64,
             block_d: int = 512, interpret: bool = False):
    """Same contract as models.ssm.selective_scan (returns (y, h_final))."""
    Bsz, S, di = xc.shape
    a_bar = jnp.exp(dt[..., None] * A[None, None])
    b_bar = (dt * xc)[..., None] * Bc[:, :, None, :]
    if h0 is None:
        h0 = jnp.zeros((Bsz, di, A.shape[-1]), jnp.float32)
    h_seq, h_fin = ssm_scan_blocked(a_bar, b_bar, h0, chunk=chunk,
                                    block_d=min(block_d, di),
                                    interpret=interpret)
    y = jnp.einsum("bsdn,bsn->bsd", h_seq, Cc) + xc * D[None, None]
    return y, h_fin
