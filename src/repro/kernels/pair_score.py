"""Pallas TPU kernel for the paper's phase-2 hot spot: Cartesian pairwise
link scoring  score[i,j] = c_i^T W e_j + w_c.c_i + w_e.e_j + b  over the
compacted claim/evidence buffers (Listing 2's mapPartitions body).

Grid (n_claim_blocks, n_evid_blocks) with the evidence dimension sequential:
the per-claim-block projection  CW = C_blk @ W  is computed once per claim
block (at j == 0) into VMEM scratch and reused across evidence blocks — the
kernel-level analogue of the paper's "load the model once per partition".
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _pair_kernel(c_ref, e_ref, w_ref, wc_ref, we_ref, b_ref, o_ref, cw_scr,
                 *, bn: int, bm: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _project():
        c = c_ref[...].astype(jnp.float32)                    # (bn, d)
        cw_scr[...] = jax.lax.dot(c, w_ref[...].astype(jnp.float32),
                                  preferred_element_type=jnp.float32)

    c = c_ref[...].astype(jnp.float32)
    e = e_ref[...].astype(jnp.float32)                        # (bm, d)
    bil = jax.lax.dot_general(cw_scr[...], e, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (bn, bm)
    lin_c = jax.lax.dot(c, wc_ref[...].astype(jnp.float32),
                        preferred_element_type=jnp.float32)   # (bn, 1)
    lin_e = jax.lax.dot(e, we_ref[...].astype(jnp.float32),
                        preferred_element_type=jnp.float32)   # (bm, 1)
    o_ref[...] = bil + lin_c + lin_e.T + b_ref[0, 0]


def pair_score_blocked(claims, evidence, W, w_c, w_e, bias, *,
                       block_n: int = 128, block_m: int = 128,
                       interpret: bool = False):
    """claims: (N,d)  evidence: (M,d)  W: (d,d)  w_c/w_e: (d,)  -> (N,M)."""
    N, d = claims.shape
    M = evidence.shape[0]
    bn = min(block_n, N)
    bm = min(block_m, M)
    pad_n = (-N) % bn
    pad_m = (-M) % bm
    if pad_n:
        claims = jnp.pad(claims, ((0, pad_n), (0, 0)))
    if pad_m:
        evidence = jnp.pad(evidence, ((0, pad_m), (0, 0)))
    grid = ((N + pad_n) // bn, (M + pad_m) // bm)
    kernel = functools.partial(_pair_kernel, bn=bn, bm=bm)
    try:
        cparams = pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"))
    except Exception:
        cparams = pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary"))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, d), lambda i, j: (j, 0)),
            pl.BlockSpec((d, d), lambda i, j: (0, 0)),
            pl.BlockSpec((d, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((d, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((N + pad_n, M + pad_m), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bn, d), jnp.float32)],
        compiler_params=cparams,
        interpret=interpret,
    )(claims, evidence, W, w_c.reshape(d, 1), w_e.reshape(d, 1),
      jnp.asarray(bias, jnp.float32).reshape(1, 1))
    return out[:N, :M]
