"""Mamba-1 SSM block (falcon-mamba-7b): in_proj -> causal depthwise conv ->
selective scan -> gate -> out_proj.

The selective scan runs chunked: a ``lax.scan`` over sequence chunks with an
``associative_scan`` inside each chunk, so peak memory is
O(B * chunk * d_inner * state) instead of O(B * S * d_inner * state).
A Pallas kernel (kernels/ssm_scan.py) implements the same chunked schedule for
TPU; this module is the jnp reference path used by dry-run and smoke tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.sharding import Param, shard
from repro.models.layers import dense_init, zeros_init, ones_init

SCAN_CHUNK = 64


def init_ssm(key, cfg):
    d, di, N, dtr, K = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank, cfg.conv_k
    dt = cfg.p_dtype
    ks = jax.random.split(key, 7)
    a_init = jnp.log(jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None, :], (di, 1)))
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di), ("embed", "inner"), dt),
        "conv_w": dense_init(ks[1], (K, di), (None, "inner"), dt, scale=0.5),
        "conv_b": zeros_init((di,), ("inner",), dt),
        "x_proj": dense_init(ks[2], (di, dtr + 2 * N), ("inner", None), dt),
        "dt_proj": dense_init(ks[3], (dtr, di), (None, "inner"), dt),
        "dt_bias": zeros_init((di,), ("inner",), dt),
        "A_log": Param(a_init, ("inner", None)),
        "D": ones_init((di,), ("inner",), dt),
        "out_proj": dense_init(ks[4], (di, d), ("inner", "embed"), dt),
    }


def _conv1d_causal(x, w, b):
    """x: (B,S,di), depthwise causal conv, kernel (K,di)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None, :] for i in range(K))
    return out + b[None, None, :]


def _ssm_params(params, xc, cfg):
    """Per-token dt, B, C from the conv output xc (B,S,di)."""
    N, dtr = cfg.ssm_state, cfg.dt_rank
    proj = xc @ params["x_proj"]                       # (B,S,dtr+2N)
    dt_in, Bc, Cc = jnp.split(proj, [dtr, dtr + N], axis=-1)
    dt = jax.nn.softplus(dt_in @ params["dt_proj"] + params["dt_bias"])  # (B,S,di)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))  # (di,N)
    return dt.astype(jnp.float32), Bc.astype(jnp.float32), Cc.astype(jnp.float32), A


def selective_scan(xc, dt, Bc, Cc, A, D, h0=None, chunk: int = SCAN_CHUNK):
    """xc: (B,S,di)  dt: (B,S,di)  Bc,Cc: (B,S,N)  A: (di,N)  D: (di,)

    Returns (y (B,S,di), h_final (B,di,N)).
    """
    from repro.core import flags
    Bsz, S, di = xc.shape
    if flags.COST_MODE:
        chunk = max(chunk, S // 32)
    N = Bc.shape[-1]
    xf = xc.astype(jnp.float32)
    a_bar = jnp.exp(dt[..., None] * A[None, None])                   # (B,S,di,N)
    b_bar = (dt * xf)[..., None] * Bc[:, :, None, :]                  # (B,S,di,N)

    pad = (-S) % chunk
    if pad:
        a_bar = jnp.pad(a_bar, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
        b_bar = jnp.pad(b_bar, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (S + pad) // chunk
    a_c = a_bar.reshape(Bsz, nc, chunk, di, N).transpose(1, 0, 2, 3, 4)
    b_c = b_bar.reshape(Bsz, nc, chunk, di, N).transpose(1, 0, 2, 3, 4)

    if h0 is None:
        h0 = jnp.zeros((Bsz, di, N), jnp.float32)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    def chunk_step(h, ab):
        a, b = ab                                                     # (B,chunk,di,N)
        acc_a, acc_b = jax.lax.associative_scan(combine, (a, b), axis=1)
        h_all = acc_a * h[:, None] + acc_b                            # (B,chunk,di,N)
        return h_all[:, -1], h_all

    from repro.core import flags
    if flags.COST_MODE:
        h, hs = h0, []
        for i in range(nc):
            h, h_all = chunk_step(h, (a_c[i], b_c[i]))
            hs.append(h_all)
        h_fin, h_seq = h, jnp.stack(hs)
    else:
        h_fin, h_seq = jax.lax.scan(chunk_step, h0, (a_c, b_c))
    h_seq = h_seq.transpose(1, 0, 2, 3, 4).reshape(Bsz, nc * chunk, di, N)[:, :S]
    y = jnp.einsum("bsdn,bsn->bsd", h_seq, Cc) + xf * D[None, None].astype(jnp.float32)
    return y, h_fin


def ssm_forward(params, x, cfg, state=None):
    """x: (B,S,d) -> (out, new_state).  state = {"conv": (B,K-1,di), "h": (B,di,N)}"""
    B, S, d = x.shape
    di, K = cfg.d_inner, cfg.conv_k
    xz = x @ params["in_proj"]
    xs, z = jnp.split(xz, 2, axis=-1)
    xs = shard(xs, "batch", "seq", "inner")
    if state is not None:
        xs_ext = jnp.concatenate([state["conv"].astype(xs.dtype), xs], axis=1)
        conv_full = _conv1d_causal(xs_ext, params["conv_w"], params["conv_b"])
        xc = conv_full[:, K - 1:]
    else:
        xc = _conv1d_causal(xs, params["conv_w"], params["conv_b"])
    xc = jax.nn.silu(xc)
    dt, Bc, Cc, A = _ssm_params(params, xc, cfg)
    h0 = state["h"] if state is not None else None
    if cfg.use_kernels and S >= 128:
        from repro.kernels import ops as kops
        y, h_fin = kops.ssm_scan(xc.astype(jnp.float32), dt, Bc, Cc, A,
                                 params["D"].astype(jnp.float32),
                                 h0=h0, interpret=True)
    else:
        y, h_fin = selective_scan(xc, dt, Bc, Cc, A, params["D"], h0=h0)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = shard(y @ params["out_proj"], "batch", "seq", None)
    new_state = {
        "conv": xs[:, -(K - 1):].astype(jnp.float32) if S >= K - 1 else
                jnp.concatenate([state["conv"], xs], 1)[:, -(K - 1):] if state is not None
                else jnp.pad(xs, ((0, 0), (K - 1 - S, 0), (0, 0))).astype(jnp.float32),
        "h": h_fin,
    }
    return out, new_state


def init_ssm_state(cfg, batch: int):
    return {
        "conv": jnp.zeros((batch, cfg.conv_k - 1, cfg.d_inner), jnp.float32),
        "h": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
    }


def ssm_decode(params, x, state, cfg):
    """Single-token step.  x: (B,1,d)."""
    B = x.shape[0]
    di, K, N = cfg.d_inner, cfg.conv_k, cfg.ssm_state
    xz = x @ params["in_proj"]
    xs, z = jnp.split(xz, 2, axis=-1)                   # (B,1,di)
    conv_in = jnp.concatenate([state["conv"].astype(xs.dtype), xs], axis=1)  # (B,K,di)
    xc = jnp.einsum("bkd,kd->bd", conv_in, params["conv_w"]) + params["conv_b"]
    xc = jax.nn.silu(xc)[:, None]                       # (B,1,di)
    dt, Bc, Cc, A = _ssm_params(params, xc, cfg)
    a_bar = jnp.exp(dt[:, 0, :, None] * A[None])        # (B,di,N)
    b_bar = (dt[:, 0] * xc[:, 0].astype(jnp.float32))[..., None] * Bc[:, 0, None, :]
    h = a_bar * state["h"] + b_bar
    y = jnp.einsum("bdn,bn->bd", h, Cc[:, 0]) + xc[:, 0].astype(jnp.float32) * params["D"].astype(jnp.float32)
    y = y[:, None].astype(x.dtype) * jax.nn.silu(z)
    out = y @ params["out_proj"]
    return out, {"conv": conv_in[:, 1:].astype(jnp.float32), "h": h}
