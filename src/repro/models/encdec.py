"""Encoder-decoder backbone (whisper-base).  The conv/audio frontend is a
STUB per the assignment: ``input_specs`` provides precomputed frame
embeddings (B, S_enc, d_model).  Positions use fixed sinusoids (whisper uses
sinusoidal encoder positions; we use them on both sides — noted in DESIGN.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.sharding import shard
from repro.models import attention as attn
from repro.models.layers import (dense_init, embed, init_embedding,
                                 init_mlp, apply_mlp, mask_padded_logits)
from repro.models.transformer import apply_norm, init_norm, _remat_wrap


def _scan(cfg, body, init, xs):
    """lax.scan or unrolled python loop (dry-run cost pass)."""
    if cfg.scan_layers:
        return jax.lax.scan(body, init, xs)
    n = jax.tree_util.tree_leaves(xs)[0].shape[0]
    carry, ys_list = init, []
    for r in range(n):
        carry, y = body(carry, jax.tree_util.tree_map(lambda a: a[r], xs))
        ys_list.append(y)
    ys = (jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *ys_list)
          if ys_list and ys_list[0] is not None else None)
    return carry, ys


def sinusoid(seq: int, d: int, dtype):
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-dim * (jnp.log(10000.0) / max(d // 2 - 1, 1)))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# ----------------------------------------------------------------------
def init_enc_layer(key, cfg):
    ks = jax.random.split(key, 2)
    return {"ln1": init_norm(cfg), "self": attn.init_attn(ks[0], cfg),
            "ln2": init_norm(cfg), "ffn": init_mlp(ks[1], cfg)}


def init_dec_layer(key, cfg):
    ks = jax.random.split(key, 3)
    return {"ln1": init_norm(cfg), "self": attn.init_attn(ks[0], cfg),
            "ln_x": init_norm(cfg), "cross": attn.init_attn(ks[1], cfg),
            "ln2": init_norm(cfg), "ffn": init_mlp(ks[2], cfg)}


def init_params(key, cfg):
    from repro.models.transformer import _stack_params
    ks = jax.random.split(key, 4)
    enc = _stack_params([init_enc_layer(jax.random.fold_in(ks[0], i), cfg)
                         for i in range(cfg.enc_layers)])
    dec = _stack_params([init_dec_layer(jax.random.fold_in(ks[1], i), cfg)
                         for i in range(cfg.dec_layers)])
    return {
        "embedding": init_embedding(ks[2], cfg),
        "enc": enc, "dec": dec,
        "enc_norm": init_norm(cfg), "dec_norm": init_norm(cfg),
        "lm_head": dense_init(ks[3], (cfg.d_model, cfg.padded_vocab),
                              ("embed", "vocab"), cfg.p_dtype),
    }


# ----------------------------------------------------------------------
def encode(params, frames, cfg):
    """frames: (B, S_enc, d) stub frame embeddings -> encoder states."""
    x = frames.astype(cfg.act_dtype) + sinusoid(frames.shape[1], cfg.d_model,
                                                cfg.act_dtype)[None]
    x = shard(x, "batch", "seq", "embed")

    def body(xx, lp):
        h = apply_norm(lp["ln1"], xx, cfg)
        xx = xx + attn.attn_forward(lp["self"], h, cfg, kind="bidir")
        h = apply_norm(lp["ln2"], xx, cfg)
        return xx + apply_mlp(lp["ffn"], h, cfg), None

    body = _remat_wrap(body, cfg)
    x, _ = _scan(cfg, body, x, params["enc"])
    return apply_norm(params["enc_norm"], x, cfg)


def decode_full(params, tokens, enc_states, cfg):
    """Teacher-forced decoder pass (train / prefill-score)."""
    x = embed(params["embedding"], tokens, cfg)
    x = x + sinusoid(tokens.shape[1], cfg.d_model, cfg.act_dtype)[None]
    x = shard(x, "batch", "seq", "embed")

    def body(xx, lp):
        h = apply_norm(lp["ln1"], xx, cfg)
        xx = xx + attn.attn_forward(lp["self"], h, cfg, kind="causal")
        h = apply_norm(lp["ln_x"], xx, cfg)
        xx = xx + attn.attn_forward(lp["cross"], h, cfg, kind="cross",
                                    encoder_kv=enc_states)
        h = apply_norm(lp["ln2"], xx, cfg)
        return xx + apply_mlp(lp["ffn"], h, cfg), None

    body = _remat_wrap(body, cfg)
    x, _ = _scan(cfg, body, x, params["dec"])
    x = apply_norm(params["dec_norm"], x, cfg)
    logits = mask_padded_logits(x @ params["lm_head"], cfg)
    return shard(logits, "batch", "seq", "vocab")


def loss(params, cfg, frames, tokens):
    enc = encode(params, frames, cfg)
    logits = decode_full(params, tokens, enc, cfg)
    targets = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)))
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll), (jnp.mean(nll), jnp.zeros((), jnp.float32))


# ----------------------------------------------------------------------
# decode with caches: self-attn KV cache + precomputed cross K/V.
def init_caches(cfg, batch: int, max_len: int, enc_len: int):
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    L = cfg.dec_layers
    return {
        "self_k": jnp.zeros((L, batch, max_len, KV, hd), cfg.act_dtype),
        "self_v": jnp.zeros((L, batch, max_len, KV, hd), cfg.act_dtype),
        "cross_k": jnp.zeros((L, batch, enc_len, KV, hd), cfg.act_dtype),
        "cross_v": jnp.zeros((L, batch, enc_len, KV, hd), cfg.act_dtype),
    }


def prefill(params, tokens, frames, cfg, caches):
    """Encode + teacher-forced decoder prefill, filling self+cross caches."""
    enc = encode(params, frames, cfg)
    B, S = tokens.shape
    x = embed(params["embedding"], tokens, cfg)
    x = x + sinusoid(S, cfg.d_model, cfg.act_dtype)[None]
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    pos = jnp.arange(S)[None, :]
    pos_enc = jnp.arange(enc.shape[1])[None, :]

    def body(xx, per):
        lp, _ = per
        h = apply_norm(lp["ln1"], xx, cfg)
        q, k, v = attn._project_qkv(lp["self"], h, h, cfg, pos, pos, 0.0)
        xx = xx + attn.attn_forward(lp["self"], h, cfg, kind="causal", qkv=(q, k, v))
        h = apply_norm(lp["ln_x"], xx, cfg)
        ck = (enc @ lp["cross"]["wk"]).reshape(B, -1, KV, hd)
        cv = (enc @ lp["cross"]["wv"]).reshape(B, -1, KV, hd)
        xx = xx + attn.attn_forward(lp["cross"], h, cfg, kind="cross", encoder_kv=enc)
        h = apply_norm(lp["ln2"], xx, cfg)
        xx = xx + apply_mlp(lp["ffn"], h, cfg)
        return xx, (k, v, ck, cv)

    x, (ks, vs, cks, cvs) = _scan(cfg, body, x, (params["dec"], jnp.arange(cfg.dec_layers)))
    caches = dict(caches)
    caches["self_k"] = caches["self_k"].at[:, :, :S].set(ks)
    caches["self_v"] = caches["self_v"].at[:, :, :S].set(vs)
    caches["cross_k"] = cks
    caches["cross_v"] = cvs
    x = apply_norm(params["dec_norm"], x[:, -1:], cfg)
    return mask_padded_logits(x @ params["lm_head"], cfg), caches


def decode_step(params, tokens, caches, pos, cfg):
    """tokens: (B,1); pos: (B,)."""
    B = tokens.shape[0]
    x = embed(params["embedding"], tokens, cfg)
    x = x + sinusoid_at(pos, cfg.d_model, cfg.act_dtype)[:, None, :]
    bidx = jnp.arange(B)

    def body(xx, per):
        lp, sk, sv, ck, cv = per
        h = apply_norm(lp["ln1"], xx, cfg)
        q, k, v = attn._project_qkv(lp["self"], h, h, cfg, pos[:, None], pos[:, None], 0.0)
        sk = attn.batched_cache_update(sk, k[:, 0], pos)
        sv = attn.batched_cache_update(sv, v[:, 0], pos)
        L = sk.shape[1]
        valid = jnp.arange(L)[None, :] <= pos[:, None]
        o = attn.mha(q, sk, sv, valid[:, None, None, :], cfg.attn_softcap)
        xx = xx + o.reshape(B, 1, -1) @ lp["self"]["wo"]
        h = apply_norm(lp["ln_x"], xx, cfg)
        qc = (h @ lp["cross"]["wq"]).reshape(B, 1, cfg.n_heads, cfg.head_dim)
        oc = attn.mha(qc, ck, cv, None, cfg.attn_softcap)
        xx = xx + oc.reshape(B, 1, -1) @ lp["cross"]["wo"]
        h = apply_norm(lp["ln2"], xx, cfg)
        xx = xx + apply_mlp(lp["ffn"], h, cfg)
        return xx, (sk, sv)

    x, (nsk, nsv) = _scan(
        cfg, body, x, (params["dec"], caches["self_k"], caches["self_v"],
                       caches["cross_k"], caches["cross_v"]))
    caches = dict(caches)
    caches["self_k"], caches["self_v"] = nsk, nsv
    x = apply_norm(params["dec_norm"], x, cfg)
    return mask_padded_logits(x @ params["lm_head"], cfg), caches


def sinusoid_at(pos, d: int, dtype):
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-dim * (jnp.log(10000.0) / max(d // 2 - 1, 1)))
    ang = pos.astype(jnp.float32)[:, None] * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)
