"""Attention: full / sliding-window (chunked, sub-quadratic) / decode, with
GQA-MQA, optional dual-base RoPE (gemma3), qk-norm, MLA (DeepSeek), and
cross-attention (enc-dec).  Pure-jnp reference paths; perf-critical paths can
be routed through Pallas kernels (cfg.use_kernels) which target TPU and are
validated in interpret mode against these same functions.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.sharding import shard
from repro.models.layers import apply_rope, dense_init, ones_init, rms_norm

NEG_INF = -2.0e38
FLASH_MIN_SEQ = 1024          # switch to chunked online-softmax attention


# ----------------------------------------------------------------------
def init_attn(key, cfg, *, cross: bool = False):
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = cfg.p_dtype
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], (d, H * hd), ("embed", "heads"), dt),
        "wk": dense_init(ks[1], (d, KV * hd), ("embed", "kv_heads"), dt),
        "wv": dense_init(ks[2], (d, KV * hd), ("embed", "kv_heads"), dt),
        "wo": dense_init(ks[3], (H * hd, d), ("heads", "embed"), dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = ones_init((hd,), (None,), dt)
        p["k_norm"] = ones_init((hd,), (None,), dt)
    return p


def _project_qkv(params, xq, xkv, cfg, positions_q, positions_kv, rope_base):
    B, Sq, _ = xq.shape
    Skv = xkv.shape[1]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    # pin head sharding immediately so qk-norm/rope (fp32 element-wise) stay
    # local to each head shard instead of tempting GSPMD into reshards
    q = shard((xq @ params["wq"]).reshape(B, Sq, H, hd),
              "batch", "seq", "heads", None)
    k = shard((xkv @ params["wk"]).reshape(B, Skv, KV, hd),
              "batch", "seq", "kv_heads", None)
    v = shard((xkv @ params["wv"]).reshape(B, Skv, KV, hd),
              "batch", "seq", "kv_heads", None)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    if rope_base:
        q = apply_rope(q, positions_q, rope_base)
        k = apply_rope(k, positions_kv, rope_base)
    return q, k, v


def mha(q, k, v, mask, softcap: float = 0.0):
    """q: (B,Sq,H,hd)  k,v: (B,Skv,KV,hd)  mask: broadcastable (B,1,Sq,Skv)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    if mask is not None:
        scores = jnp.where(mask[:, :, None] if mask.ndim == 4 else mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
    return out.reshape(B, Sq, H, v.shape[-1])


def causal_mask(Sq: int, Skv: int, offset: int = 0):
    """mask[q, s] = s <= q + offset (offset = Skv - Sq for suffix queries)."""
    qi = jnp.arange(Sq)[:, None]
    si = jnp.arange(Skv)[None, :]
    return si <= qi + offset


# ----------------------------------------------------------------------
# Chunked flash-style attention in pure jnp: online softmax over kv chunks,
# EXACT block skipping for causal/window patterns (a python loop over query
# chunks gives each q-chunk a static kv range, so HLO FLOPs match the true
# sub-quadratic cost — no masked-waste).  This is both the XLA path used by
# the dry-run at long sequence and the oracle for kernels/flash_attention.
def flash_attention_jnp(q, k, v, *, causal: bool = True, window: int = 0,
                        q_chunk: int = 512, kv_chunk: int = 1024,
                        softcap: float = 0.0, kv_offset: int = 0,
                        q_offset_dynamic=None, kv_valid=None):
    """q: (B,Sq,H,hd)  k,v: (B,Skv,KV,hd) -> (B,Sq,H,hd).  fp32 accumulation.

    kv_offset: STATIC position of kv[0] relative to q[0] (e.g. -window for a
      halo-prefixed kv) — keeps the causal/window block ranges static/exact.
    q_offset_dynamic: traced scalar added to q positions in MASKS only (used
      by the gathered-KV ring path where ranges must stay full).
    kv_valid: optional traced bool (Skv,) ANDed into the mask (halo validity).
    """
    from repro.core import flags
    B, S, H, hd = q.shape
    Skv_in = k.shape[1]
    KV = k.shape[2]
    hd_v = v.shape[-1]                                 # may differ (MLA)
    G = H // KV
    if flags.COST_MODE:
        # kernel-realistic block granularity, python-unrolled kv loop
        q_chunk = kv_chunk = (window if window else 2048)
    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, Skv_in)
    pad_q = (-S) % q_chunk
    pad_k = (-Skv_in) % kv_chunk
    Sq, Sk = S + pad_q, Skv_in + pad_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        if kv_valid is not None:
            kv_valid = jnp.pad(kv_valid, (0, pad_k))
    nq, nk = Sq // q_chunk, Sk // kv_chunk
    kc = k.reshape(B, nk, kv_chunk, KV, hd)
    vc = v.reshape(B, nk, kv_chunk, KV, hd_v)
    scale = 1.0 / math.sqrt(hd)
    dynamic_ranges = q_offset_dynamic is not None

    def one_q_chunk(qi_idx: int, q_i, q_off):
        """q_i: (B,C,KV,G,hd); returns (B,C,KV,G,hd)."""
        C = q_chunk
        q_pos = qi_idx * C + jnp.arange(C)
        if q_off is not None:
            q_pos = q_pos + q_off
        # static kv chunk range for this q chunk (exact block skipping);
        # with a dynamic q offset the range must stay full
        if causal and not dynamic_ranges:
            hi = min(nk, ((qi_idx + 1) * C - 1 - kv_offset) // kv_chunk + 1)
        else:
            hi = nk
        lo = 0
        if window and not dynamic_ranges:
            lo = max(0, (qi_idx * C - window - kv_offset) // kv_chunk)

        def kv_step(carry, j):
            m, l, acc = carry
            k_j = jax.lax.dynamic_index_in_dim(kc, j, axis=1, keepdims=False)
            v_j = jax.lax.dynamic_index_in_dim(vc, j, axis=1, keepdims=False)
            s = jnp.einsum("bqkgh,bskh->bkgqs", q_i, k_j).astype(jnp.float32)
            s = s * scale
            if softcap:
                s = jnp.tanh(s / softcap) * softcap
            kv_pos = j * kv_chunk + jnp.arange(kv_chunk) + kv_offset
            ok = kv_pos[None, :] < Skv_in + kv_offset
            if causal:
                ok = ok & (kv_pos[None, :] <= q_pos[:, None])
            if window:
                ok = ok & (kv_pos[None, :] > q_pos[:, None] - window)
            if kv_valid is not None:
                vmask = jax.lax.dynamic_index_in_dim(
                    kv_valid.reshape(nk, kv_chunk), j, axis=0, keepdims=False)
                ok = ok & vmask[None, :]
            s = jnp.where(ok[None, None, None], s, NEG_INF)
            m2 = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m2[..., None])
            corr = jnp.exp(m - m2)
            l2 = l * corr + jnp.sum(p, axis=-1)
            acc2 = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", p.astype(q_i.dtype), v_j).astype(jnp.float32)
            return (m2, l2, acc2), None

        m0 = jnp.full((B, KV, G, C), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, C), jnp.float32)
        a0 = jnp.zeros((B, KV, G, C, hd_v), jnp.float32)
        if flags.COST_MODE:
            carry = (m0, l0, a0)
            for j in range(lo, hi):
                carry, _ = kv_step(carry, jnp.asarray(j))
            m, l, acc = carry
        else:
            (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                          jnp.arange(lo, hi))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.transpose(0, 3, 1, 2, 4)            # (B,C,KV,G,hd)

    qg = q.reshape(B, nq, q_chunk, KV, G, hd)
    outs = []
    for i in range(nq):
        fn = jax.checkpoint(functools.partial(one_q_chunk, i))
        outs.append(fn(qg[:, i], q_offset_dynamic))
    out = jnp.concatenate(outs, axis=1)[:, :S]
    return out.reshape(B, S, H, hd_v).astype(q.dtype)


# ----------------------------------------------------------------------
# Context-parallel (sequence-sharded) attention for prefill/scoring under the
# paper's broadcast placement: weights replicated, the sequence split over
# the `model` axis (shard_map).  Local-window layers exchange only a
# window-sized halo (collective_permute); global layers all-gather K/V and
# flash over the gathered cache.  This is the TPU-native form of the paper's
# "ship the model once, split the instances" — see EXPERIMENTS.md §Perf.
def seqshard_attn_forward(params, x, cfg, *, kind: str, mesh, batch_axes):
    from jax.sharding import PartitionSpec as P
    from repro.core.sharding import shard_map_compat

    B, S, _ = x.shape
    n = mesh.shape["model"]
    S_loc = S // n
    rope_base = cfg.rope_local_base if kind == "local" else cfg.rope_base
    W = cfg.window
    b_ax = batch_axes if batch_axes else None

    def local_fn(p, xl):
        # xl: (B_loc, S_loc, d).  shard() constraints must no-op inside the
        # manual-sharding region:
        from repro.core.sharding import use_sharding
        with use_sharding(None):
            return _local_body(p, xl)

    def _local_body(p, xl):
        r = jax.lax.axis_index("model")
        off = r * S_loc
        pos = off + jnp.arange(S_loc)[None, :]
        q, k, v = _project_qkv(p, xl, xl, cfg, pos, pos, rope_base)
        if kind == "local" and W and W <= S_loc:
            # halo: previous rank's last W keys/values (rank 0 gets zeros)
            perm = [(i, i + 1) for i in range(n - 1)]
            k_h = jax.lax.ppermute(k[:, -W:], "model", perm)
            v_h = jax.lax.ppermute(v[:, -W:], "model", perm)
            kk = jnp.concatenate([k_h, k], axis=1)
            vv = jnp.concatenate([v_h, v], axis=1)
            kv_ok = (off - W + jnp.arange(W + S_loc)) >= 0
            out = flash_attention_jnp(q, kk, vv, causal=True, window=W,
                                      softcap=cfg.attn_softcap, kv_offset=-W,
                                      kv_valid=kv_ok)
        else:
            kk = jax.lax.all_gather(k, "model", axis=1, tiled=True)
            vv = jax.lax.all_gather(v, "model", axis=1, tiled=True)
            out = flash_attention_jnp(q, kk, vv, causal=True,
                                      softcap=cfg.attn_softcap,
                                      q_offset_dynamic=off)
        out = out.reshape(xl.shape[0], S_loc, -1) @ p["wo"]
        return out, k, v

    fn = shard_map_compat(local_fn, mesh=mesh,
                          in_specs=(P(), P(b_ax, "model", None)),
                          out_specs=(P(b_ax, "model", None),
                                     P(b_ax, "model", None, None),
                                     P(b_ax, "model", None, None)))
    return fn(params, x)


# ----------------------------------------------------------------------
# Full-sequence forward (train / prefill).
def attn_forward(params, x, cfg, *, kind: str, positions=None, encoder_kv=None,
                 qkv=None):
    """kind: "causal" | "local" | "global" | "bidir" | "cross"."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    rope_base = 0.0 if kind in ("bidir", "cross") else (
        cfg.rope_local_base if kind == "local" else cfg.rope_base)

    if kind == "cross":
        xkv = encoder_kv
        pos_kv = jnp.arange(xkv.shape[1])[None, :]
        q, k, v = _project_qkv(params, x, xkv, cfg, positions, pos_kv, 0.0)
        if S >= FLASH_MIN_SEQ or xkv.shape[1] >= FLASH_MIN_SEQ:
            out = flash_attention_jnp(q, k, v, causal=False,
                                      softcap=cfg.attn_softcap)
        else:
            out = mha(q, k, v, None, cfg.attn_softcap)
        return out.reshape(B, S, -1) @ params["wo"]

    q, k, v = qkv if qkv is not None else _project_qkv(
        params, x, x, cfg, positions, positions, rope_base)

    window = cfg.window if kind == "local" else 0
    if cfg.use_kernels and kind in ("causal", "global", "local") and S >= 128:
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, k, v, causal=True, window=window,
                                   interpret=True)
    elif S >= FLASH_MIN_SEQ:
        out = flash_attention_jnp(q, k, v, causal=kind != "bidir",
                                  window=window, softcap=cfg.attn_softcap)
    elif kind == "local" and cfg.window and S > cfg.window:
        out = _local_attention(q, k, v, cfg.window, cfg.attn_softcap)
    else:
        mask = None
        if kind in ("causal", "global"):
            mask = causal_mask(S, S)[None, None]
        elif kind == "local":
            m = causal_mask(S, S)
            if cfg.window:
                si = jnp.arange(S)
                m = m & (si[None, :] > si[:, None] - cfg.window)
            mask = m[None, None]
        out = mha(q, k, v, mask, cfg.attn_softcap)
    out = shard(out.reshape(B, S, -1), "batch", "seq", "heads")
    return shard(out @ params["wo"], "batch", "seq", None)


def _local_attention(q, k, v, window: int, softcap: float):
    """Chunked sliding-window attention: O(S * 2W) compute.

    Token t attends to s in (t - window, t].  Chunk size C == window; each
    query chunk attends to (previous chunk ++ own chunk) with a banded mask.
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    C = window
    pad = (-S) % C
    if pad:
        padw = ((0, 0), (0, pad), (0, 0), (0, 0))
        q, k, v = (jnp.pad(t, padw) for t in (q, k, v))
        S2 = S + pad
    else:
        S2 = S
    nc = S2 // C
    qc = q.reshape(B, nc, C, H, hd)
    kc = k.reshape(B, nc, C, KV, hd)
    vc = v.reshape(B, nc, C, KV, hd)
    kprev = jnp.pad(kc, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :-1]
    vprev = jnp.pad(vc, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :-1]
    kk = jnp.concatenate([kprev, kc], axis=2)            # (B,nc,2C,KV,hd)
    vv = jnp.concatenate([vprev, vc], axis=2)
    G = H // KV
    qg = qc.reshape(B, nc, C, KV, G, hd)
    scores = jnp.einsum("bnqkgh,bnskh->bnkgqs", qg, kk).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    # positions within the 2C strip: query i (0..C-1) sits at absolute C + i.
    qi = jnp.arange(C)[:, None] + C
    si = jnp.arange(2 * C)[None, :]
    band = (si <= qi) & (si > qi - window)
    # first chunk has no previous chunk: mask strip [0, C) there.
    first = (jnp.arange(nc) == 0)[:, None, None]
    band = band[None] & ~(first & (si < C)[None])
    scores = jnp.where(band[None, :, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bnkgqs,bnskh->bnqkgh", probs, vv)
    out = out.reshape(B, S2, H, hd)
    return out[:, :S]


# ----------------------------------------------------------------------
# Decode with caches.
def init_kv_cache(cfg, batch: int, max_len: int, *, ring: bool = False):
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    L = min(max_len, cfg.window) if ring and cfg.window else max_len
    c = {
        "k": jnp.zeros((batch, L, KV, hd), cfg.act_dtype),
        "v": jnp.zeros((batch, L, KV, hd), cfg.act_dtype),
    }
    if ring:
        c["pos"] = jnp.full((batch, L), -1, jnp.int32)
    return c


def cache_axes(cache):
    """Logical axes for cache pytrees (for sharding specs)."""
    def ax(path_leaf):
        arr = path_leaf
        if arr.ndim == 4:
            return ("batch", None, "kv_heads", None)
        if arr.ndim == 3:
            return ("batch", None, None)
        return ("batch", None)
    return jax.tree_util.tree_map(ax, cache)


def batched_cache_update(cache_arr, new_row, slot):
    """cache_arr: (B, L, ...); new_row: (B, ...); slot: (B,).

    Per-batch dynamic_update_slice (vmapped) instead of a gather/scatter —
    GSPMD keeps the update local to each batch shard, where a fancy-indexed
    scatter forces a cache all-gather (measured: 2 GB/layer at decode_32k).
    """
    def upd(c, row, s):
        return jax.lax.dynamic_update_slice_in_dim(c, row[None], s, axis=0)
    return jax.vmap(upd)(cache_arr, new_row, slot)


def attn_decode(params, x, cache, pos, cfg, *, kind: str):
    """x: (B,1,d).  pos: (B,) current absolute position.  Returns (out, cache)."""
    B = x.shape[0]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    rope_base = cfg.rope_local_base if kind == "local" else cfg.rope_base
    q, k, v = _project_qkv(params, x, x, cfg, pos[:, None], pos[:, None], rope_base)

    ring = kind == "local" and cfg.window and cache["k"].shape[1] <= cfg.window
    L = cache["k"].shape[1]
    slot = (pos % L) if ring else pos                    # (B,)
    cache = dict(cache)
    cache["k"] = batched_cache_update(cache["k"], k[:, 0], slot)
    cache["v"] = batched_cache_update(cache["v"], v[:, 0], slot)
    if ring:
        cache["pos"] = batched_cache_update(cache["pos"], pos, slot)
        valid = (cache["pos"] >= 0) & (cache["pos"] > (pos[:, None] - cfg.window)) \
            & (cache["pos"] <= pos[:, None])
    else:
        valid = jnp.arange(L)[None, :] <= pos[:, None]
    mask = valid[:, None, None, :]                        # (B,1,1,L)
    out = mha(q, cache["k"], cache["v"], mask, cfg.attn_softcap)
    out = out.reshape(B, 1, -1) @ params["wo"]
    return out, cache


def attn_extend(params, x, cache, pos0, cfg, *, kind: str):
    """Dense-cache analogue of :func:`paged_attn_extend`: append ``S``
    tokens at absolute positions ``pos0 + j`` (per row) and attend
    causally over absolute positions.  The speculative verify step runs
    this over gather-hoisted virtual caches — one batched extend scores a
    whole draft window.  KV writes use ``mode="drop"`` so a frozen slot's
    window hanging past the cache edge writes nothing (a clamped write
    would corrupt the last live row)."""
    B, S, _ = x.shape
    rope_base = cfg.rope_local_base if kind == "local" else cfg.rope_base
    positions = pos0[:, None] + jnp.arange(S)[None, :]       # (B, S)
    q, k, v = _project_qkv(params, x, x, cfg, positions, positions,
                           rope_base)
    L = cache["k"].shape[1]
    bidx = jnp.arange(B)[:, None]
    cache = dict(cache)
    cache["k"] = cache["k"].at[bidx, positions].set(
        k.astype(cache["k"].dtype), mode="drop")
    cache["v"] = cache["v"].at[bidx, positions].set(
        v.astype(cache["v"].dtype), mode="drop")
    valid = jnp.arange(L)[None, None, :] <= positions[:, :, None]
    out = mha(q, cache["k"], cache["v"], valid[:, None], cfg.attn_softcap)
    out = out.reshape(B, S, -1) @ params["wo"]
    return out, cache


def prefill_into_cache(params_unused, k, v, cache, cfg, *, kind: str):
    """Write full-seq K/V (B,S,KV,hd) into a fresh cache."""
    S = k.shape[1]
    L = cache["k"].shape[1]
    if "pos" in cache:                                    # ring: keep last L
        take = min(S, L)
        idx = (jnp.arange(L) + (S - take)) % L if S >= L else jnp.arange(L)
        ks = k[:, -take:]
        vs = v[:, -take:]
        pos = jnp.arange(S - take, S)
        slots = pos % L
        cache = dict(cache)
        cache["k"] = cache["k"].at[:, slots].set(ks)
        cache["v"] = cache["v"].at[:, slots].set(vs)
        cache["pos"] = cache["pos"].at[:, slots].set(pos[None, :])
        return cache
    cache = dict(cache)
    cache["k"] = cache["k"].at[:, :S].set(k)
    cache["v"] = cache["v"].at[:, :S].set(v)
    return cache


# ----------------------------------------------------------------------
# Paged KV cache: K/V live in one shared block pool per layer instead of a
# dense (B, max_len) stripe per slot; each sequence names its blocks in a
# block table (serving/kvpool.py owns the host-side allocator).  Physical
# block 0 is the reserved null block: table padding points at it and
# masked/pad writes are redirected into it, so a stale entry can corrupt
# nothing.  Gather-through-the-table + masked mha is the exact jnp path
# (and the parity oracle); ``cfg.use_kernels`` routes decode through the
# Pallas paged kernel, which resolves pool rows via scalar-prefetched
# block tables and never materializes a dense per-sequence cache.

def init_paged_kv_cache(cfg, num_blocks: int, block_size: int):
    """Per-layer block pool; ``num_blocks`` usable + 1 reserved null row."""
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "kp": jnp.zeros((num_blocks + 1, block_size, KV, hd), cfg.act_dtype),
        "vp": jnp.zeros((num_blocks + 1, block_size, KV, hd), cfg.act_dtype),
    }


def is_paged_cache(cache) -> bool:
    return isinstance(cache, dict) and "kp" in cache


def _paged_scatter(cache, k, v, vpos, bt):
    """Write per-position K/V rows into the pool through the block table.

    k/v: (B, S, KV, hd); vpos: (B, S) virtual positions; bt: (B, nb).
    Positions beyond the table (prompt pads past ``nb*bs``) redirect to
    the null block."""
    bs = cache["kp"].shape[1]
    nb = bt.shape[1]
    vblock = vpos // bs
    phys = jnp.take_along_axis(bt, jnp.minimum(vblock, nb - 1), axis=1)
    phys = jnp.where(vblock < nb, phys, 0)
    off = vpos % bs
    cache = dict(cache)
    cache["kp"] = cache["kp"].at[phys, off].set(k.astype(cache["kp"].dtype))
    cache["vp"] = cache["vp"].at[phys, off].set(v.astype(cache["vp"].dtype))
    return cache


def _paged_gather(cache, bt):
    """(B, nb*bs, KV, hd) virtual caches, materialized via the table."""
    B, nb = bt.shape
    bs = cache["kp"].shape[1]
    k = cache["kp"][bt].reshape(B, nb * bs, *cache["kp"].shape[2:])
    v = cache["vp"][bt].reshape(B, nb * bs, *cache["vp"].shape[2:])
    return k, v


def paged_attn_decode(params, x, cache, pos, bt, cfg, *, kind: str):
    """Single decode step over a paged cache.

    x: (B,1,d); pos: (B,) absolute write position; bt: (B, nb) block
    table.  Same math as :func:`attn_decode` on a dense cache holding the
    same tokens — validity is ``index <= pos`` either way."""
    B = x.shape[0]
    rope_base = cfg.rope_local_base if kind == "local" else cfg.rope_base
    q, k, v = _project_qkv(params, x, x, cfg, pos[:, None], pos[:, None],
                           rope_base)
    cache = _paged_scatter(cache, k, v, pos[:, None], bt)
    if cfg.use_kernels:
        from repro.kernels import ops as kops
        out = kops.paged_decode_attention(q[:, 0], cache["kp"], cache["vp"],
                                          bt, pos + 1, interpret=True)
        out = out[:, None]
    else:
        kg, vg = _paged_gather(cache, bt)
        L = kg.shape[1]
        valid = jnp.arange(L)[None, :] <= pos[:, None]
        out = mha(q, kg, vg, valid[:, None, None, :], cfg.attn_softcap)
    out = out.reshape(B, 1, -1) @ params["wo"]
    return out, cache


def paged_attn_extend(params, x, cache, pos0, bt, cfg, *, kind: str):
    """Prefill a suffix into a paged cache: S tokens starting at absolute
    position ``pos0`` (per row), attending to the cached prefix blocks
    *and* causally within the suffix.  This is the paged admit path — a
    prefix-cache hit makes ``pos0 > 0`` and only the un-cached suffix is
    computed.  x: (B,S,d); pos0: (B,); bt: (B, nb)."""
    B, S, _ = x.shape
    rope_base = cfg.rope_local_base if kind == "local" else cfg.rope_base
    positions = pos0[:, None] + jnp.arange(S)[None, :]       # (B, S)
    q, k, v = _project_qkv(params, x, x, cfg, positions, positions,
                           rope_base)
    cache = _paged_scatter(cache, k, v, positions, bt)
    if cfg.use_kernels:
        # Pallas sibling of the decode kernel: online softmax over prefix
        # blocks + the just-scattered suffix, block tables scalar-prefetched
        # — no dense per-sequence materialization
        from repro.kernels import ops as kops
        out = kops.paged_extend_attention(q, cache["kp"], cache["vp"], bt,
                                          pos0, interpret=True)
    else:
        kg, vg = _paged_gather(cache, bt)
        L = kg.shape[1]
        # causal over absolute positions: cache index l holds virtual pos l
        valid = jnp.arange(L)[None, None, :] <= positions[:, :, None]
        out = mha(q, kg, vg, valid[:, None], cfg.attn_softcap)
    out = out.reshape(B, S, -1) @ params["wo"]
    return out, cache


# ----------------------------------------------------------------------
# MLA (DeepSeek-V2): low-rank compressed KV; absorbed decode.
def init_mla(key, cfg):
    d, H = cfg.d_model, cfg.n_heads
    r, rh, nh, vh = cfg.kv_lora_rank, cfg.rope_head_dim, cfg.nope_head_dim, cfg.v_head_dim
    dt = cfg.p_dtype
    ks = jax.random.split(key, 8)
    return {
        "wq": dense_init(ks[0], (d, H * (nh + rh)), ("embed", "heads"), dt),
        "w_dkv": dense_init(ks[1], (d, r), ("embed", None), dt),
        "w_krope": dense_init(ks[2], (d, rh), ("embed", None), dt),
        "kv_norm": ones_init((r,), (None,), dt),
        "w_uk": dense_init(ks[3], (r, H * nh), (None, "heads"), dt),
        "w_uv": dense_init(ks[4], (r, H * vh), (None, "heads"), dt),
        "wo": dense_init(ks[5], (H * vh, d), ("heads", "embed"), dt),
    }


def _mla_q(params, x, cfg, positions):
    B, S, _ = x.shape
    H, rh, nh = cfg.n_heads, cfg.rope_head_dim, cfg.nope_head_dim
    q = (x @ params["wq"]).reshape(B, S, H, nh + rh)
    q_nope, q_rope = q[..., :nh], q[..., nh:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_base)
    return q_nope, q_rope


def mla_forward(params, x, cfg, positions=None):
    B, S, _ = x.shape
    H, rh, nh, vh = cfg.n_heads, cfg.rope_head_dim, cfg.nope_head_dim, cfg.v_head_dim
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q_nope, q_rope = _mla_q(params, x, cfg, positions)
    ckv = rms_norm(x @ params["w_dkv"], params["kv_norm"], cfg.norm_eps)
    krope = apply_rope((x @ params["w_krope"])[:, :, None, :], positions,
                       cfg.rope_base)                     # (B,S,1,rh)
    k_nope = (ckv @ params["w_uk"]).reshape(B, S, H, nh)
    v = (ckv @ params["w_uv"]).reshape(B, S, H, vh)
    # assemble per-head q/k of width nh+rh; flash/mha scale 1/sqrt(nh+rh)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)        # (B,S,H,nh+rh)
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(krope, (B, S, H, rh))], axis=-1)
    if S >= FLASH_MIN_SEQ:
        out = flash_attention_jnp(q, k, v, causal=True)
    else:
        out = mha(q, k, v, causal_mask(S, S)[None, None])
    out = out.reshape(B, S, H * vh)
    return out @ params["wo"], (ckv, krope[:, :, 0, :])


def init_mla_cache(cfg, batch: int, max_len: int):
    return {
        "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), cfg.act_dtype),
        "krope": jnp.zeros((batch, max_len, cfg.rope_head_dim), cfg.act_dtype),
    }


def mla_decode(params, x, cache, pos, cfg):
    """Absorbed decode: scores and context in the compressed (r)-space."""
    B = x.shape[0]
    H, rh, nh, vh = cfg.n_heads, cfg.rope_head_dim, cfg.nope_head_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    q_nope, q_rope = _mla_q(params, x, cfg, pos[:, None])  # (B,1,H,·)
    ckv_t = rms_norm(x @ params["w_dkv"], params["kv_norm"], cfg.norm_eps)  # (B,1,r)
    krope_t = apply_rope((x @ params["w_krope"])[:, :, None, :], pos[:, None],
                         cfg.rope_base)[:, 0, 0]           # (B,rh)
    cache = dict(cache)
    cache["ckv"] = batched_cache_update(cache["ckv"], ckv_t[:, 0], pos)
    cache["krope"] = batched_cache_update(cache["krope"], krope_t, pos)
    # absorb: q_eff[h] = q_nope[h] @ w_uk[:, h]^T  -> (B,H,r)
    w_uk = params["w_uk"].reshape(r, H, nh)
    q_eff = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], w_uk)
    L = cache["ckv"].shape[1]
    scale = 1.0 / math.sqrt(nh + rh)
    s = (jnp.einsum("bhr,bsr->bhs", q_eff, cache["ckv"])
         + jnp.einsum("bhd,bsd->bhs", q_rope[:, 0], cache["krope"])).astype(jnp.float32) * scale
    valid = jnp.arange(L)[None, :] <= pos[:, None]
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    ctx_c = jnp.einsum("bhs,bsr->bhr", p, cache["ckv"])    # (B,H,r)
    w_uv = params["w_uv"].reshape(r, H, vh)
    out = jnp.einsum("bhr,rhd->bhd", ctx_c, w_uv).reshape(B, 1, H * vh)
    return out @ params["wo"], cache
