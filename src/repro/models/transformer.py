"""Unified decoder LM over heterogeneous ScanGroups.

Layers are stacked per (group, pattern-position) and iterated with
``jax.lax.scan`` so compiled HLO size (and compile time) is independent of
depth; remat policy wraps the scan body.  Supports:

  kinds A/L/G (attention: full / sliding-window / dual-rope-global),
  M (attention+MoE; MLA attention if cfg.kv_lora_rank), D (dense layer in a
  MoE model), S (Mamba-1), R (RG-LRU recurrent block).

Three modes share one code path: ``full`` (train / scoring), ``prefill``
(full pass that also fills caches), ``decode`` (single-token step with
caches).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.sharding import Param, shard, split_params
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (dense_init, embed, init_embedding, init_mlp,
                                 apply_mlp, layer_norm, mask_padded_logits,
                                 ones_init, rms_norm, unembed, zeros_init)

ATTN_KINDS = ("A", "L", "G", "M", "D")


# ----------------------------------------------------------------------
# norms
def init_norm(cfg):
    if cfg.norm == "layernorm":
        return {"w": ones_init((cfg.d_model,), (None,), cfg.p_dtype),
                "b": zeros_init((cfg.d_model,), (None,), cfg.p_dtype)}
    w = jnp.zeros if cfg.rms_plus_one else jnp.ones
    return {"w": Param(w((cfg.d_model,), cfg.p_dtype), (None,))}


def apply_norm(p, x, cfg):
    if cfg.norm == "layernorm":
        return layer_norm(x, p["w"], p["b"], cfg.norm_eps)
    return rms_norm(x, p["w"], cfg.norm_eps, plus_one=cfg.rms_plus_one)


# ----------------------------------------------------------------------
# per-layer init
def init_layer(key, cfg, kind: str):
    ks = jax.random.split(key, 4)
    p = {"ln1": init_norm(cfg)}
    if kind == "S":
        p["mixer"] = ssm_mod.init_ssm(ks[0], cfg)
        return p
    if kind == "R":
        p["mixer"] = rglru_mod.init_rglru(ks[0], cfg)
    elif kind == "M" and cfg.kv_lora_rank:
        p["mixer"] = attn.init_mla(ks[0], cfg)
    else:
        p["mixer"] = attn.init_attn(ks[0], cfg)
    p["ln2"] = init_norm(cfg)
    if kind == "M":
        p["ffn"] = moe_mod.init_moe(ks[1], cfg)
    elif kind == "D":
        p["ffn"] = init_mlp(ks[1], cfg, d_ff=cfg.dense_d_ff or cfg.d_ff)
    else:
        p["ffn"] = init_mlp(ks[1], cfg)
    return p


def init_layer_cache(cfg, kind: str, batch: int, max_len: int):
    if kind == "S":
        return ssm_mod.init_ssm_state(cfg, batch)
    if kind == "R":
        return rglru_mod.init_rglru_state(cfg, batch)
    if kind == "M" and cfg.kv_lora_rank:
        return attn.init_mla_cache(cfg, batch, max_len)
    ring = kind == "L" and cfg.window and cfg.window < max_len
    return attn.init_kv_cache(cfg, batch, max_len, ring=bool(ring))


def paged_supported(cfg, max_len: int) -> bool:
    """Can this arch serve from a paged KV block pool?

    Attention layers with a standard (non-ring) KV cache page naturally:
    the cache is position-addressed, so positions can live in scattered
    physical blocks.  SSM ("S") / RG-LRU ("R") carry *recurrent state*,
    not a position-addressed cache — nothing to page; MLA ("M" with
    ``kv_lora_rank``) uses its own compressed cache format; a ring cache
    ("L" with ``window < max_len``) aliases positions modulo the window.
    Those families keep the dense path.
    """
    for g in cfg.groups:
        for kind in g.pattern:
            if kind in ("S", "R"):
                return False
            if kind == "M" and cfg.kv_lora_rank:
                return False
            if kind == "L" and cfg.window and cfg.window < max_len:
                return False
    return True


def init_paged_caches(cfg, num_blocks: int, block_size: int):
    """Block-pool caches: one shared ``(num_blocks+1, bs, KV, hd)`` K/V
    pool per layer (row 0 reserved as the null block) instead of a dense
    per-slot stripe.  Layout mirrors :func:`init_caches` so the scan
    machinery is unchanged."""
    if not paged_supported(cfg, max_len=1 << 30):
        raise ValueError(f"{cfg.name}: family holds non-pageable state "
                         f"(SSM/RG-LRU/MLA/ring) — use the dense cache")
    caches = []
    for g in cfg.groups:
        pos_caches = []
        for kind in g.pattern:
            c = attn.init_paged_kv_cache(cfg, num_blocks, block_size)
            c = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a[None], (g.repeats,) + a.shape), c)
            pos_caches.append(c)
        caches.append(pos_caches)
    return caches


# ----------------------------------------------------------------------
# per-layer apply
def apply_layer(p, x, cfg, kind: str, mode: str, cache, pos, bt=None):
    """Returns (x, aux, new_cache).  ``bt`` is the (B, nb) block table
    when ``cache`` is paged (decode/extend modes)."""
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(p["ln1"], x, cfg)

    if kind == "S":
        if mode == "decode":
            mix, cache = ssm_mod.ssm_decode(p["mixer"], h, cache, cfg)
        else:
            mix, new_state = ssm_mod.ssm_forward(
                p["mixer"], h, cfg, state=None)
            cache = new_state if mode == "prefill" else cache
        return x + mix, aux, cache

    if kind == "R":
        if mode == "decode":
            mix, cache = rglru_mod.rglru_decode(p["mixer"], h, cache, cfg)
        else:
            mix, new_state = rglru_mod.rglru_forward(p["mixer"], h, cfg, state=None)
            cache = new_state if mode == "prefill" else cache
    elif kind == "M" and cfg.kv_lora_rank:
        if mode == "decode":
            mix, cache = attn.mla_decode(p["mixer"], h, cache, pos, cfg)
        else:
            mix, (ckv, krope) = attn.mla_forward(p["mixer"], h, cfg)
            if mode == "prefill":
                S = ckv.shape[1]
                cache = dict(cache)
                cache["ckv"] = cache["ckv"].at[:, :S].set(ckv.astype(cache["ckv"].dtype))
                cache["krope"] = cache["krope"].at[:, :S].set(krope.astype(cache["krope"].dtype))
    else:
        akind = {"A": "causal", "G": "global", "L": "local",
                 "M": "causal", "D": "causal"}[kind]
        from repro.core.sharding import current_ctx
        ctx = current_ctx()
        S = h.shape[1]
        use_seqshard = (ctx is not None and ctx.policy == "seqtp"
                        and mode != "decode" and S >= attn.FLASH_MIN_SEQ
                        and S % ctx.mesh.shape.get("model", 1) == 0)
        if mode == "decode" and attn.is_paged_cache(cache):
            mix, cache = attn.paged_attn_decode(p["mixer"], h, cache, pos,
                                                bt, cfg, kind=akind)
        elif mode == "extend" and attn.is_paged_cache(cache):
            # paged suffix prefill: S tokens appended at absolute position
            # `pos` (per row), attending through the block table
            mix, cache = attn.paged_attn_extend(p["mixer"], h, cache, pos,
                                                bt, cfg, kind=akind)
        elif mode == "extend":
            # dense-cache extend: the speculative verify window
            mix, cache = attn.attn_extend(p["mixer"], h, cache, pos, cfg,
                                          kind=akind)
        elif mode == "decode":
            mix, cache = attn.attn_decode(p["mixer"], h, cache, pos, cfg, kind=akind)
        elif use_seqshard:
            mix, k, v = attn.seqshard_attn_forward(
                p["mixer"], h, cfg, kind=akind, mesh=ctx.mesh,
                batch_axes=ctx.rules.get("batch"))
            if mode == "prefill":
                cache = attn.prefill_into_cache(None, k, v, cache, cfg,
                                                kind=akind)
        elif mode == "prefill":
            B, S, _ = h.shape
            positions = jnp.arange(S)[None, :]
            rope_base = cfg.rope_local_base if akind == "local" else cfg.rope_base
            q, k, v = attn._project_qkv(p["mixer"], h, h, cfg,
                                        positions, positions, rope_base)
            cache = attn.prefill_into_cache(None, k, v, cache, cfg, kind=akind)
            mix = attn.attn_forward(p["mixer"], h, cfg, kind=akind, qkv=(q, k, v))
        else:
            mix = attn.attn_forward(p["mixer"], h, cfg, kind=akind)
    x = x + mix

    h2 = apply_norm(p["ln2"], x, cfg)
    if kind == "M":
        f, aux = moe_mod.apply_moe(p["ffn"], h2, cfg)
    elif kind == "D":
        f = apply_mlp(p["ffn"], h2, cfg)
    else:
        f = apply_mlp(p["ffn"], h2, cfg)
    return x + f, aux, cache


# ----------------------------------------------------------------------
# parameter trees
def _stack_params(trees):
    def stack(*leaves):
        if isinstance(leaves[0], Param):
            return Param(jnp.stack([l.value for l in leaves]),
                         ("layers",) + leaves[0].axes)
        return jnp.stack(leaves)
    return jax.tree_util.tree_map(stack, *trees,
                                  is_leaf=lambda l: isinstance(l, Param))


def init_group_params(key, cfg, group):
    """list over pattern positions; each a Param tree stacked over repeats."""
    out = []
    for pidx, kind in enumerate(group.pattern):
        reps = [init_layer(jax.random.fold_in(key, pidx * 4096 + r), cfg, kind)
                for r in range(group.repeats)]
        out.append(_stack_params(reps) if group.repeats > 1 else
                   jax.tree_util.tree_map(
                       lambda p: Param(p.value[None], ("layers",) + p.axes),
                       reps[0], is_leaf=lambda l: isinstance(l, Param)))
    return out


def init_params(key, cfg):
    ks = jax.random.split(key, 2 + len(cfg.groups))
    p = {"embedding": init_embedding(ks[0], cfg),
         "final_norm": init_norm(cfg),
         "groups": [init_group_params(ks[2 + i], cfg, g)
                    for i, g in enumerate(cfg.groups)]}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[1], (cfg.d_model, cfg.padded_vocab),
                                  ("embed", "vocab"), cfg.p_dtype)
    return p


def init_caches(cfg, batch: int, max_len: int):
    caches = []
    for g in cfg.groups:
        pos_caches = []
        for kind in g.pattern:
            c = init_layer_cache(cfg, kind, batch, max_len)
            c = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a[None], (g.repeats,) + a.shape), c)
            pos_caches.append(c)
        caches.append(pos_caches)
    return caches


# ----------------------------------------------------------------------
# backbone runner
def _remat_wrap(fn, cfg):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        pol = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)


def run_backbone(params, x, cfg, mode: str, caches=None, pos=None, bt=None):
    """x: (B,S,d) embedded input.  Returns (x, aux, new_caches).
    ``bt``: (B, nb) block table for paged caches (loop-invariant)."""
    aux0 = jnp.zeros((), jnp.float32)
    new_caches = []
    for gi, g in enumerate(cfg.groups):
        gp = params["groups"][gi]
        gc = caches[gi] if caches is not None else [None] * len(g.pattern)

        def body(carry, per_rep, _pattern=g.pattern):
            xx, aux = carry
            layer_ps, layer_cs = per_rep
            ncs = []
            for pi, kind in enumerate(_pattern):
                cc = layer_cs[pi] if layer_cs is not None else None
                xx, a, nc = apply_layer(layer_ps[pi], xx, cfg, kind, mode,
                                        cc, pos, bt)
                aux = aux + a
                ncs.append(nc)
            return (xx, aux), (tuple(ncs) if layer_cs is not None else None)

        body = _remat_wrap(body, cfg)
        xs_cache = tuple(gc) if caches is not None else None
        if cfg.scan_layers:
            (x, aux0), ys = jax.lax.scan(body, (x, aux0), (gp, xs_cache))
        else:
            # unrolled (dry-run cost pass; also useful for debugging)
            ys_list = []
            for r in range(g.repeats):
                take = lambda t: jax.tree_util.tree_map(lambda a: a[r], t)
                (x, aux0), y = body((x, aux0), (take(gp),
                                                take(xs_cache) if xs_cache is not None else None))
                ys_list.append(y)
            ys = (jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *ys_list)
                  if ys_list and ys_list[0] is not None else None)
        new_caches.append(list(ys) if ys is not None else None)
    return x, aux0, new_caches


# ----------------------------------------------------------------------
# public entry points
def forward(params, cfg, tokens=None, embeds=None):
    """Full-sequence causal LM forward.  Returns (logits, aux)."""
    if embeds is None:
        x = embed(params["embedding"], tokens, cfg)
    else:
        x = embeds.astype(cfg.act_dtype)
    x = shard(x, "batch", "seq", "embed")
    x, aux, _ = run_backbone(params, x, cfg, "full")
    x = apply_norm(params["final_norm"], x, cfg)
    return _head(params, x, cfg), aux


def prefill(params, cfg, tokens, caches, embeds=None, last_index=None):
    """Fill caches with a full pass; returns (logits at `last_index`
    (default: final position), caches)."""
    if embeds is None:
        x = embed(params["embedding"], tokens, cfg)
    else:
        x = embeds.astype(cfg.act_dtype)
    x = shard(x, "batch", "seq", "embed")
    x, aux, caches = run_backbone(params, x, cfg, "prefill", caches,
                                  pos=None)
    if last_index is None:
        x = x[:, -1:]
    else:
        li = jnp.asarray(last_index)
        if li.ndim == 0:
            x = jax.lax.dynamic_slice_in_dim(x, last_index, 1, axis=1)
        else:
            # per-row last positions: bucketed batch prefill pads prompts to
            # a shared length, so each row's true final token sits at its
            # own index
            x = jnp.take_along_axis(x, li.astype(jnp.int32)[:, None, None],
                                    axis=1)
    x = apply_norm(params["final_norm"], x, cfg)
    logits = _head(params, x, cfg)
    return logits, caches


def decode_step(params, cfg, tokens, caches, pos, bt=None):
    """tokens: (B,1) int32; pos: (B,) absolute position being written;
    ``bt``: (B, nb) block table when ``caches`` are paged."""
    x = embed(params["embedding"], tokens, cfg)
    x = shard(x, "batch", "seq", "embed")
    x, aux, caches = run_backbone(params, x, cfg, "decode", caches, pos=pos,
                                  bt=bt)
    x = apply_norm(params["final_norm"], x, cfg)
    logits = _head(params, x, cfg)
    return logits, caches


def extend_paged(params, cfg, tokens, caches, pos0, bt, last_index):
    """Paged admit pass: append ``tokens (B,S)`` to sequences whose first
    ``pos0 (B,)`` positions are already cached in the block pool (a
    prefix-cache hit), writing suffix K/V through the block table ``bt``
    and returning logits at per-row ``last_index`` (into the suffix) plus
    the updated pool caches.  With ``pos0 == 0`` this is a full paged
    prefill."""
    x = embed(params["embedding"], tokens, cfg)
    x = shard(x, "batch", "seq", "embed")
    x, aux, caches = run_backbone(params, x, cfg, "extend", caches,
                                  pos=pos0, bt=bt)
    li = jnp.asarray(last_index).astype(jnp.int32)
    x = jnp.take_along_axis(x, li[:, None, None], axis=1)
    x = apply_norm(params["final_norm"], x, cfg)
    logits = _head(params, x, cfg)
    return logits, caches


def sample_tokens(logits, temperature: float = 0.0, rng=None):
    """In-jit sampling.  logits: (B, V) -> (B,) int32.

    ``temperature`` is a *static* policy: 0.0 compiles to greedy argmax (the
    parity-tested default), anything else to categorical sampling at that
    temperature (``rng`` required)."""
    if temperature and temperature > 0.0:
        if rng is None:
            raise ValueError("temperature sampling needs an rng key")
        return jax.random.categorical(
            rng, logits.astype(jnp.float32) / temperature, axis=-1
        ).astype(jnp.int32)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def decode_fused(params, cfg, tokens, caches, pos, *, temperature: float = 0.0,
                 rng=None, bt=None):
    """One decode step that never ships logits to the host: embed -> backbone
    -> head -> sample, returning only the (B,) sampled token ids (instead of
    the (B, vocab) logits) plus the updated caches."""
    logits, caches = decode_step(params, cfg, tokens, caches, pos, bt=bt)
    return sample_tokens(logits[:, 0], temperature, rng), caches


# ----------------------------------------------------------------------
# Paged virtual caches.  The fused K-step loop over a paged pool used to
# resolve the block table on EVERY decode step (a scatter + full gather
# per layer per step, all inside the jit).  Hoisting the indirection out
# of the loop — materialize each sequence's blocks once as a dense-layout
# "virtual" cache, run the unchanged dense loop body on it, scatter back
# only the rows the loop can have written — removes all per-step table
# resolution at bitwise-identical math: the gather is an exact copy, and
# rows the two layouts zero-fill differently are masked out of the
# softmax either way (exp(NEG_INF - m) == 0.0 exactly).  Bonus: the
# virtual width is the engine's *bucketed live-sequence width* (nb * bs
# for the widest table in use), not max_len, so attention reads shrink
# with the actual context — which is how paged decode gets to beat dense.

def gather_paged_virtual(caches, bt):
    """Materialize per-slot dense caches from the block pools.

    ``bt (B, nb)`` may be narrower than the full table (width-bucketed by
    the engine); the result leaves are ``{"k","v"} (R, B, nb*bs, KV, hd)``
    — exactly the layout :func:`init_caches` builds, so every dense
    decode path runs on them unchanged."""
    B, nb = bt.shape
    out = []
    for gc in caches:
        row = []
        for c in gc:
            bs = c["kp"].shape[2]
            row.append({
                "k": c["kp"][:, bt].reshape(c["kp"].shape[0], B, nb * bs,
                                            *c["kp"].shape[3:]),
                "v": c["vp"][:, bt].reshape(c["vp"].shape[0], B, nb * bs,
                                            *c["vp"].shape[3:]),
            })
        out.append(row)
    return out


def refresh_paged_virtual(virt, caches, bt_rows, slot_idx):
    """Surgically re-gather ``len(slot_idx)`` slots of a resident virtual
    cache from the block pools, leaving every other slot's rows untouched.

    The admit path uses this instead of a full regather: freshly admitted
    slots' pool rows were just written by the admit prefill, while the
    *other* slots' resident rows may be ahead of the pool (lazy
    writeback) and must NOT be re-read from it.  ``bt_rows (n, vw)`` is
    each admitted slot's table cut to the resident width; duplicate
    ``slot_idx`` entries (batch padding) write identical values."""
    n, vw = bt_rows.shape
    out = []
    for gv, gc in zip(virt, caches):
        row = []
        for cv, c in zip(gv, gc):
            bs = c["kp"].shape[2]
            row.append({
                "k": cv["k"].at[:, slot_idx].set(
                    c["kp"][:, bt_rows].reshape(
                        c["kp"].shape[0], n, vw * bs, *c["kp"].shape[3:]
                    ).astype(cv["k"].dtype)),
                "v": cv["v"].at[:, slot_idx].set(
                    c["vp"][:, bt_rows].reshape(
                        c["vp"].shape[0], n, vw * bs, *c["vp"].shape[3:]
                    ).astype(cv["v"].dtype)),
            })
        out.append(row)
    return out


def scatter_paged_back(caches, virt, bt, start, width: int, stop=None):
    """Write rows ``[start, start + width)`` of the virtual caches back
    into the block pools — the only rows a loop starting at ``start`` can
    have written.  Rows past a sequence's table redirect to the null
    block (so a frozen slot's junk writes and a finished slot's nulled
    table persist nothing real); rows past the virtual width clamp on
    read but are likewise null-redirected.  ``stop (B,)`` additionally
    null-redirects rows ``>= stop[s]`` — the lazy-writeback flush uses it
    to clamp each slot to its own written count, so one slot's pending
    width can't push another slot's junk tail into a still-shared
    (not-yet-COWed) block."""
    B, nb = bt.shape
    bs = caches[0][0]["kp"].shape[2]
    L = virt[0][0]["k"].shape[2]
    rows = start[:, None] + jnp.arange(width)[None, :]           # (B, W)
    take = jnp.minimum(rows, L - 1)[None, :, :, None, None]
    vblock = rows // bs
    phys = jnp.take_along_axis(bt, jnp.minimum(vblock, nb - 1), axis=1)
    phys = jnp.where(vblock < nb, phys, 0)
    if stop is not None:
        phys = jnp.where(rows < stop[:, None], phys, 0)
    off = rows % bs
    out = []
    for gc, gv in zip(caches, virt):
        row_out = []
        for c, cv in zip(gc, gv):
            kr = jnp.take_along_axis(cv["k"], take, axis=2)
            vr = jnp.take_along_axis(cv["v"], take, axis=2)
            row_out.append({
                "kp": c["kp"].at[:, phys, off].set(kr.astype(c["kp"].dtype)),
                "vp": c["vp"].at[:, phys, off].set(vr.astype(c["vp"].dtype)),
            })
        out.append(row_out)
    return out


def decode_loop(params, cfg, caches, pos, last, active, remaining, rng, *,
                k: int, max_len: int, temperature: float = 0.0, bt=None):
    """K fused decode steps with one host sync at the end.

    All loop state lives on device: ``pos`` (B,) next write position,
    ``last`` (B,) last sampled token, ``active`` (B,) bool slot liveness,
    ``remaining`` (B,) decode-token budget.  Per-slot stop is honored
    *exactly* via masking — an exhausted slot's pos/last/budget freeze and
    its tokens stop being emitted, while the batch keeps stepping (batch
    elements never interact inside a step, so frozen slots cannot perturb
    live ones).  Returns ``(out (B,k) int32, emitted (B,) int32, caches,
    pos, last, active, remaining, rng)``; ``out[s, :emitted[s]]`` are slot
    s's real tokens (liveness is monotone within the loop, so they form a
    prefix).

    With ``bt`` (paged caches) the jnp path runs gather-hoisted: virtual
    dense caches once per K steps, the identical dense body inside, one
    bounded scatter-back at the end.  ``cfg.use_kernels`` keeps the
    per-step pool path (the Pallas decode kernel reads the pool directly
    and would gain nothing from a materialized dense copy).
    """
    if bt is not None and not cfg.use_kernels:
        start = pos
        out, emitted, virt, pos, last, active, remaining, rng = decode_loop(
            params, cfg, gather_paged_virtual(caches, bt), pos, last,
            active, remaining, rng, k=k, max_len=max_len,
            temperature=temperature)
        caches = scatter_paged_back(caches, virt, bt, start, k)
        return out, emitted, caches, pos, last, active, remaining, rng

    def body(i, carry):
        caches, pos, last, active, remaining, rng, out, emitted = carry
        rng, sub = jax.random.split(rng)
        nxt, caches = decode_fused(params, cfg, last[:, None], caches, pos,
                                   temperature=temperature, rng=sub, bt=bt)
        nxt = jnp.where(active, nxt, last)
        out = jax.lax.dynamic_update_index_in_dim(out, nxt, i, 1)
        emitted = emitted + active.astype(jnp.int32)
        live = active.astype(jnp.int32)
        pos = pos + live
        remaining = remaining - live
        active = active & (remaining > 0) & (pos < max_len - 1)
        # a slot that just went inactive feeds token 0 from here on, exactly
        # like the reference loop's zero-fill for empty slots — keeps the
        # batch composition identical for archs where rows couple (MoE)
        last = jnp.where(active, nxt, jnp.zeros_like(nxt))
        return caches, pos, last, active, remaining, rng, out, emitted

    out0 = jnp.zeros((pos.shape[0], k), jnp.int32)
    em0 = jnp.zeros((pos.shape[0],), jnp.int32)
    caches, pos, last, active, remaining, rng, out, emitted = jax.lax.fori_loop(
        0, k, body, (caches, pos, last, active, remaining, rng, out0, em0))
    return out, emitted, caches, pos, last, active, remaining, rng


# ----------------------------------------------------------------------
# Speculative multi-token decode (paged engines, greedy only).
def ngram_draft(hist, pos, last, d: int):
    """Bigram n-gram draft: find the most recent earlier occurrence of
    the (previous token, last token) bigram in the on-device history and
    propose the ``d`` tokens that followed it; with no match, repeat the
    last token.  One masked scan plus one gather over ``hist`` — free
    next to a backbone pass, and surprisingly effective on repetitive
    output (which greedy LM decode produces in abundance)."""
    B, L = hist.shape
    prev = jnp.take_along_axis(hist, jnp.maximum(pos - 1, 0)[:, None],
                               axis=1)[:, 0]
    i = jnp.arange(1, L)
    ok = (hist[:, :-1] == prev[:, None]) & (hist[:, 1:] == last[:, None]) \
        & (i[None, :] < pos[:, None])
    m = jnp.max(jnp.where(ok, i[None, :], -1), axis=1)
    cont = jnp.where(m >= 0, m + 1, pos)
    idx = jnp.minimum(cont[:, None] + jnp.arange(d)[None, :], pos[:, None])
    return jnp.take_along_axis(hist, idx, axis=1)


def verify_extend(params, cfg, tokens, caches, pos0):
    """Speculative verify: one batched dense-cache extend of the (B, d+1)
    window ``[last] ++ draft`` at absolute positions ``pos0 + j``,
    returning greedy argmax targets at EVERY window position plus the
    updated caches.  Position j's logits are computed from exactly the
    tokens a non-speculative loop would have in cache when sampling the
    token for position ``pos0 + j + 1`` — provided tokens[0..j] all match
    what that loop would have emitted, which is precisely the accepted
    prefix the caller keeps."""
    x = embed(params["embedding"], tokens, cfg)
    x = shard(x, "batch", "seq", "embed")
    x, _, caches = run_backbone(params, x, cfg, "extend", caches,
                                pos=pos0, bt=None)
    x = apply_norm(params["final_norm"], x, cfg)
    logits = _head(params, x, cfg)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32), caches


def spec_decode_loop(params, cfg, caches, hist, pos, last, active, remaining,
                     rng, *, k: int, d: int, max_len: int, bt,
                     draft_fn=None, virt=None):
    """K speculative verify iterations over a paged cache, one host sync.

    Each iteration drafts ``d`` tokens (``draft_fn(hist, pos, last, d)``,
    default :func:`ngram_draft`), verifies ``[last] ++ draft`` in ONE
    batched extend over the gather-hoisted virtual caches, and emits the
    accepted draft prefix plus the first correction — between 1 and d+1
    tokens per backbone pass.  Token-exact vs the non-speculative loop:
    every emitted token is the greedy argmax of a context consisting
    entirely of previously-emitted tokens (acceptance stops at the first
    draft/target mismatch, so no unverified token ever conditions an
    emitted one).  Greedy only — the engine enforces temperature == 0.

    ``hist (B, max_len)`` is the device token history (``hist[p]`` = the
    token at position p for every p <= pos); paged admits seed it and
    this loop maintains it.  Returns ``(out (B, k*(d+1)), emitted (B,),
    stats (2,) int32 [extra tokens accepted, drafts proposed], caches,
    virt, hist, pos, last, active, remaining, rng)``.

    ``virt`` may carry a still-valid virtual cache from a previous sync
    (the engine keeps it device-resident and invalidates on admit/fork/
    width change); ``None`` gathers a fresh one from the pool.  With
    ``caches=None`` (requires ``virt``) the pool scatter-back is skipped
    entirely — the engine's lazy-writeback mode, where the pool is made
    authoritative only when something needs to read it.
    """
    if draft_fn is None:
        draft_fn = ngram_draft
    start = pos
    if virt is None:
        virt = gather_paged_virtual(caches, bt)
    B = pos.shape[0]
    W = k * (d + 1)

    def body(i, carry):
        (virt, hist, pos, last, active, remaining, out, emitted,
         acc, prop) = carry
        draft = draft_fn(hist, pos, last, d)                    # (B, d)
        window = jnp.concatenate([last[:, None], draft], axis=1)
        targets, virt = verify_extend(params, cfg, window, virt, pos)
        match = (draft == targets[:, :d]).astype(jnp.int32)
        a = jnp.sum(jnp.cumprod(match, axis=1), axis=1)         # (B,)
        cap = jnp.minimum(remaining, jnp.maximum(max_len - 1 - pos, 0))
        e = jnp.where(active, jnp.minimum(a + 1, cap), 0).astype(jnp.int32)
        # write the whole d+1 window at column `emitted`: entries past the
        # accepted count are junk that the next iteration's window (which
        # starts exactly at the new `emitted`) overwrites; a frozen slot's
        # writes land in [emitted, emitted+d+1) which never reaches W
        # because inactivity at iteration j implies emitted <= (d+1)(j+1)
        out = jax.vmap(
            lambda o, t, s: jax.lax.dynamic_update_slice_in_dim(o, t, s, 0)
        )(out, targets, emitted)
        # history rows pos+1 .. pos+d+1 get the verified targets; rows
        # beyond the accepted count are junk above the new pos — never
        # read (the draft clips reads at pos) and overwritten by the next
        # iteration before pos reaches them.  mode="drop" so a window
        # hanging past max_len can't clamp-corrupt a live row.
        hidx = pos[:, None] + 1 + jnp.arange(d + 1)[None, :]
        hist = hist.at[jnp.arange(B)[:, None], hidx].set(targets,
                                                         mode="drop")
        acc = acc + jnp.sum(jnp.where(active, e - 1, 0))
        prop = prop + jnp.sum(jnp.where(active, d, 0))
        emitted = emitted + e
        pos = pos + e
        remaining = remaining - e
        active = active & (remaining > 0) & (pos < max_len - 1)
        last_new = jnp.take_along_axis(
            targets, jnp.maximum(e - 1, 0)[:, None], axis=1)[:, 0]
        last = jnp.where(active, last_new, jnp.zeros_like(last))
        return (virt, hist, pos, last, active, remaining, out, emitted,
                acc, prop)

    out0 = jnp.zeros((B, W), jnp.int32)
    em0 = jnp.zeros((B,), jnp.int32)
    z = jnp.zeros((), jnp.int32)
    (virt, hist, pos, last, active, remaining, out, emitted, acc, prop) = \
        jax.lax.fori_loop(0, k, body, (virt, hist, pos, last, active,
                                       remaining, out0, em0, z, z))
    # the last verify's speculative rows reach start + emitted + d, so the
    # scatter-back window is d+1 wider than the emission bound
    if caches is not None:
        L = virt[0][0]["k"].shape[2]
        caches = scatter_paged_back(caches, virt, bt, start,
                                    min(W + d + 1, L))
    return (out, emitted, jnp.stack([acc, prop]), caches, virt, hist, pos,
            last, active, remaining, rng)


def _head(params, x, cfg):
    if cfg.tie_embeddings:
        logits = unembed(params["embedding"], x, cfg)
    else:
        logits = x @ params["lm_head"]
        if cfg.logit_softcap:
            logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
        logits = mask_padded_logits(logits, cfg)
    return shard(logits, "batch", "seq", "vocab")


# ----------------------------------------------------------------------
# loss
def lm_loss(params, cfg, tokens, targets=None, embeds=None):
    """Next-token cross-entropy (mean over tokens) + router aux."""
    logits, aux = forward(params, cfg, tokens=tokens, embeds=embeds)
    if targets is None:
        targets = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)))
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    mask = jnp.ones_like(nll)
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss + aux, (loss, aux)
