"""VLM backbone (internvl2-1b): the ViT frontend is a STUB — ``input_specs``
provides precomputed patch embeddings (B, n_patches, d_model) which are
prepended to the token embeddings; the LM backbone is the standard decoder.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models import transformer as tfm
from repro.models.layers import embed


def mixed_embeds(params, cfg, patch_embeds, tokens):
    tok = embed(params["embedding"], tokens, cfg)
    return jnp.concatenate([patch_embeds.astype(tok.dtype), tok], axis=1)


def forward(params, cfg, patch_embeds, tokens):
    x = mixed_embeds(params, cfg, patch_embeds, tokens)
    return tfm.forward(params, cfg, embeds=x)


def loss(params, cfg, patch_embeds, tokens):
    """Next-token CE on the text positions only."""
    logits, aux = forward(params, cfg, patch_embeds, tokens)
    P = patch_embeds.shape[1]
    text_logits = logits[:, P:, :]
    targets = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)))
    import jax
    logp = jax.nn.log_softmax(text_logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    l = jnp.mean(nll)
    return l + aux, (l, aux)


def prefill(params, cfg, patch_embeds, tokens, caches):
    x = mixed_embeds(params, cfg, patch_embeds, tokens)
    return tfm.prefill(params, cfg, None, caches, embeds=x)
