"""Family-dispatching model API.

Every architecture exposes the same step functions regardless of family
(dense / moe / ssm / hybrid / encdec / vlm):

  init(key, cfg)                    -> (params, logical_axes)
  loss_fn(params, cfg, batch)       -> (loss, (ce, aux))     [train_step]
  prefill_fn(params, cfg, batch, caches) -> (logits, caches)
  decode_fn(params, cfg, batch, caches)  -> (logits, caches)
  init_caches(cfg, batch, max_len)  -> cache pytree
  input_batch / input_specs         -> concrete / ShapeDtypeStruct inputs

``input_specs`` provides the modality-frontend STUBS: whisper gets
precomputed frame embeddings, internvl gets patch embeddings.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core.sharding import split_params
from repro.models import encdec, transformer as tfm, vlm


def _raw_init(key, cfg):
    if cfg.family == "encdec":
        return encdec.init_params(key, cfg)
    return tfm.init_params(key, cfg)


def init(key, cfg):
    return split_params(_raw_init(key, cfg))


def abstract_params(cfg):
    """Shapes-only params (no allocation) for dry-run lowering."""
    tree = jax.eval_shape(lambda key: _raw_init(key, cfg), jax.random.PRNGKey(0))
    return split_params(tree)


# ----------------------------------------------------------------------
def loss_fn(params, cfg, batch):
    if cfg.family == "encdec":
        return encdec.loss(params, cfg, batch["frames"], batch["tokens"])
    if cfg.family == "vlm":
        return vlm.loss(params, cfg, batch["patches"], batch["tokens"])
    return tfm.lm_loss(params, cfg, batch["tokens"], targets=batch.get("targets"))


def forward_fn(params, cfg, batch):
    if cfg.family == "encdec":
        enc = encdec.encode(params, batch["frames"], cfg)
        return encdec.decode_full(params, batch["tokens"], enc, cfg)
    if cfg.family == "vlm":
        return vlm.forward(params, cfg, batch["patches"], batch["tokens"])[0]
    return tfm.forward(params, cfg, tokens=batch["tokens"])[0]


def init_caches(cfg, batch: int, max_len: int, enc_len: int = 0):
    if cfg.family == "encdec":
        return encdec.init_caches(cfg, batch, max_len, enc_len or max_len)
    return tfm.init_caches(cfg, batch, max_len)


def prefill_fn(params, cfg, batch, caches):
    if cfg.family == "encdec":
        return encdec.prefill(params, batch["tokens"], batch["frames"], cfg, caches)
    if cfg.family == "vlm":
        return vlm.prefill(params, cfg, batch["patches"], batch["tokens"], caches)
    return tfm.prefill(params, cfg, batch["tokens"], caches)


def decode_fn(params, cfg, batch, caches):
    if cfg.family == "encdec":
        return encdec.decode_step(params, batch["tokens"], caches, batch["pos"], cfg)
    return tfm.decode_step(params, cfg, batch["tokens"], caches, batch["pos"])


# ----------------------------------------------------------------------
def input_batch(cfg, shape_kind: str, batch: int, seq: int, rng=None) -> Dict[str, Any]:
    """Concrete random inputs (smoke tests / examples)."""
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(rng)
    out: Dict[str, Any] = {}
    if cfg.family == "encdec":
        out["frames"] = jax.random.normal(k1, (batch, seq, cfg.d_model), jnp.float32)
        out["tokens"] = jax.random.randint(k2, (batch, seq), 0, cfg.vocab)
    elif cfg.family == "vlm":
        npatch = min(cfg.n_patches, seq)
        out["patches"] = jax.random.normal(k1, (batch, npatch, cfg.d_model), jnp.float32)
        out["tokens"] = jax.random.randint(k2, (batch, max(seq - npatch, 1)), 0, cfg.vocab)
    else:
        out["tokens"] = jax.random.randint(k2, (batch, seq), 0, cfg.vocab)
    if shape_kind == "decode":
        out["tokens"] = out["tokens"][:, :1]
        out["pos"] = jnp.full((batch,), seq - 1, jnp.int32)
    return out


def input_specs(cfg, shape_kind: str, batch: int, seq: int,
                batch_sharding=None) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins (no allocation) for dry-run lowering."""
    def sds(shape, dtype):
        return jax.ShapeDtypeStruct(shape, dtype, sharding=batch_sharding(len(shape))
                                    if batch_sharding else None)
    out: Dict[str, Any] = {}
    tok_seq = seq
    if shape_kind == "decode":
        # decode consumes caches + a single token; no frontend inputs
        out["tokens"] = sds((batch, 1), jnp.int32)
        out["pos"] = sds((batch,), jnp.int32)
        return out
    if cfg.family == "encdec":
        out["frames"] = sds((batch, seq, cfg.d_model), jnp.float32)
    elif cfg.family == "vlm":
        npatch = min(cfg.n_patches, seq)
        out["patches"] = sds((batch, npatch, cfg.d_model), jnp.float32)
        tok_seq = max(seq - npatch, 1)
    out["tokens"] = sds((batch, tok_seq), jnp.int32)
    return out
