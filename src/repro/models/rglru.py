"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Temporal mixing: x -> {branch: linear -> causal conv(k=4) -> RG-LRU,
gate: linear -> gelu} -> elementwise product -> out projection.
The RG-LRU recurrence is diagonal:  h_t = a_t * h_{t-1} + sqrt(1-a_t^2) * (i_t * x_t)
with a_t = exp(-c * softplus(Lambda) * sigmoid(W_a x_t + b_a)), c = 8.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.sharding import Param, shard
from repro.models.layers import dense_init, zeros_init

RG_C = 8.0
SCAN_CHUNK = 256


def init_rglru(key, cfg):
    d, w = cfg.d_model, cfg.lru_width
    dt = cfg.p_dtype
    ks = jax.random.split(key, 6)
    lam = jnp.log(jnp.expm1(jnp.linspace(0.9, 0.999, w)) ** (1.0 / RG_C))  # softplus^-1
    return {
        "in_x": dense_init(ks[0], (d, w), ("embed", "lru"), dt),
        "in_gate": dense_init(ks[1], (d, w), ("embed", "lru"), dt),
        "conv_w": dense_init(ks[2], (cfg.conv_k_rg, w), (None, "lru"), dt, scale=0.5),
        "conv_b": zeros_init((w,), ("lru",), dt),
        "w_a": dense_init(ks[3], (w, w), ("lru", None), dt),
        "b_a": zeros_init((w,), (None,), dt),
        "w_i": dense_init(ks[4], (w, w), ("lru", None), dt),
        "b_i": zeros_init((w,), (None,), dt),
        "lambda": Param(lam, (None,)),
        "out": dense_init(ks[5], (w, d), ("lru", "embed"), dt),
    }


def _conv1d_causal(x, w, b, prev=None):
    K = w.shape[0]
    if prev is not None:
        x_ext = jnp.concatenate([prev.astype(x.dtype), x], axis=1)
    else:
        x_ext = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(x_ext[:, i:i + x.shape[1]] * w[i][None, None, :] for i in range(K))
    return out + b[None, None, :]


def _gates(params, xc):
    """a_t (log-space) and gated input, fp32.  xc: (B,S,w)."""
    xf = xc.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ params["w_a"].astype(jnp.float32) + params["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(xf @ params["w_i"].astype(jnp.float32) + params["b_i"].astype(jnp.float32))
    log_a = -RG_C * jax.nn.softplus(params["lambda"]) * r          # (B,S,w)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xf)
    return a, gated


def diag_scan(a, b, h0=None, chunk: int = SCAN_CHUNK):
    """h_t = a_t h_{t-1} + b_t, elementwise.  a,b: (B,S,w) fp32."""
    from repro.core import flags
    B, S, w = a.shape
    if flags.COST_MODE:
        chunk = max(chunk, S // 16)
    pad = (-S) % chunk
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
    nc = (S + pad) // chunk
    a_c = a.reshape(B, nc, chunk, w).transpose(1, 0, 2, 3)
    b_c = b.reshape(B, nc, chunk, w).transpose(1, 0, 2, 3)
    if h0 is None:
        h0 = jnp.zeros((B, w), jnp.float32)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    def step(h, ab):
        ac, bc = ab
        acc_a, acc_b = jax.lax.associative_scan(combine, (ac, bc), axis=1)
        h_all = acc_a * h[:, None] + acc_b
        return h_all[:, -1], h_all

    if flags.COST_MODE:
        h, hs = h0, []
        for i in range(nc):
            h, h_all = step(h, (a_c[i], b_c[i]))
            hs.append(h_all)
        h_fin, h_seq = h, jnp.stack(hs)
    else:
        h_fin, h_seq = jax.lax.scan(step, h0, (a_c, b_c))
    h_seq = h_seq.transpose(1, 0, 2, 3).reshape(B, nc * chunk, w)[:, :S]
    return h_seq, h_fin


def rglru_forward(params, x, cfg, state=None):
    """x: (B,S,d) -> (out, new_state); state = {"conv": (B,K-1,w), "h": (B,w)}."""
    B, S, _ = x.shape
    K = cfg.conv_k_rg
    xb = x @ params["in_x"]
    gate = x @ params["in_gate"]
    xb = shard(xb, "batch", "seq", "lru")
    xc = _conv1d_causal(xb, params["conv_w"], params["conv_b"],
                        prev=state["conv"] if state is not None else None)
    a, gated = _gates(params, xc)
    h0 = state["h"] if state is not None else None
    h_seq, h_fin = diag_scan(a, gated, h0)
    y = h_seq.astype(x.dtype) * jax.nn.gelu(gate, approximate=True)
    out = shard(y @ params["out"], "batch", "seq", None)
    new_conv = (xb[:, -(K - 1):] if S >= K - 1 else
                jnp.concatenate([state["conv"].astype(xb.dtype), xb], 1)[:, -(K - 1):]
                if state is not None else
                jnp.pad(xb, ((0, 0), (K - 1 - S, 0), (0, 0))))
    return out, {"conv": new_conv.astype(jnp.float32), "h": h_fin}


def init_rglru_state(cfg, batch: int):
    return {
        "conv": jnp.zeros((batch, cfg.conv_k_rg - 1, cfg.lru_width), jnp.float32),
        "h": jnp.zeros((batch, cfg.lru_width), jnp.float32),
    }


def rglru_decode(params, x, state, cfg):
    """x: (B,1,d)."""
    xb = x @ params["in_x"]
    gate = x @ params["in_gate"]
    conv_in = jnp.concatenate([state["conv"].astype(xb.dtype), xb], axis=1)  # (B,K,w)
    xc = (jnp.einsum("bkw,kw->bw", conv_in, params["conv_w"]) + params["conv_b"])[:, None]
    a, gated = _gates(params, xc)
    h = a[:, 0] * state["h"] + gated[:, 0]
    y = h[:, None].astype(x.dtype) * jax.nn.gelu(gate, approximate=True)
    out = y @ params["out"]
    return out, {"conv": conv_in[:, 1:].astype(jnp.float32), "h": h}
