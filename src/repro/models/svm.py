"""The paper's own predictors, in JAX: SVM claim/evidence scorers and the
pairwise link scorer (MARGOT, §4–5).

MARGOT uses SubSet-Tree-Kernel SVMs over Stanford constituency parses plus
bag-of-words vectors.  The Stanford parser and the C tree-kernel package have
no TPU analogue, so the tree kernel is replaced by a polynomial kernel over
hashed n-gram features — same computational shape (score = Σ α_i K(sv_i, x)),
same scaling behaviour in the number of support vectors (the paper's Test 3
variable).  The link model is a bilinear pair scorer, the MXU-friendly form
of MARGOT's pair SVM; its blocked Pallas kernel lives in kernels/pair_score.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.sharding import Param, shard


def init_svm(key, n_sv: int, feat_dim: int, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {
        "sv": Param(jax.random.normal(k1, (n_sv, feat_dim), dtype) *
                    jnp.asarray(1.0 / jnp.sqrt(feat_dim), dtype), ("sv", "feat")),
        "alpha": Param(jax.random.normal(k2, (n_sv,), dtype) *
                       jnp.asarray(1.0 / jnp.sqrt(n_sv), dtype), ("sv",)),
        "bias": Param(jnp.zeros((), dtype), ()),
    }


def svm_score(params, x, *, gamma: float = 0.1, coef0: float = 1.0,
              degree: int = 2):
    """x: (N, d) -> (N,) decision scores.  Polynomial kernel, or linear when
    params carry a primal weight vector "w"."""
    if "w" in params:
        return x @ params["w"] + params["bias"]
    k = (gamma * (x @ params["sv"].T) + coef0) ** degree      # (N, n_sv)
    return k @ params["alpha"] + params["bias"]


def init_linear_svm(w, bias: float, dtype=jnp.float32):
    return {"w": Param(jnp.asarray(w, dtype), ("feat",)),
            "bias": Param(jnp.asarray(bias, dtype), ())}


def init_link(key, feat_dim: int, rank: int = 0, dtype=jnp.float32):
    """Bilinear pair scorer; optional low-rank factorization of W."""
    k1, k2, k3 = jax.random.split(key, 3)
    s = 1.0 / jnp.sqrt(feat_dim)
    if rank:
        return {
            "U": Param(jax.random.normal(k1, (feat_dim, rank), dtype) * s, ("feat", None)),
            "V": Param(jax.random.normal(k2, (feat_dim, rank), dtype) * s, ("feat", None)),
            "w": Param(jax.random.normal(k3, (2 * feat_dim,), dtype) * s, (None,)),
            "bias": Param(jnp.zeros((), dtype), ()),
        }
    return {
        "W": Param(jax.random.normal(k1, (feat_dim, feat_dim), dtype) * s, ("feat", None)),
        "w": Param(jax.random.normal(k3, (2 * feat_dim,), dtype) * s, (None,)),
        "bias": Param(jnp.zeros((), dtype), ()),
    }


def link_score_matrix(params, claims, evidence):
    """claims: (N,d), evidence: (M,d) -> (N,M) scores — the paper's Cartesian
    product (phase 2), computed as blocked bilinear matmuls."""
    if "U" in params:
        left = claims @ params["U"]                         # (N,r)
        right = evidence @ params["V"]                      # (M,r)
        bil = left @ right.T
    else:
        bil = (claims @ params["W"]) @ evidence.T           # (N,M)
    d = claims.shape[-1]
    lin = (claims @ params["w"][:d])[:, None] + (evidence @ params["w"][d:])[None, :]
    return bil + lin + params["bias"]
