"""Shared neural layers (pure-functional, pytree params + logical axes)."""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.sharding import Param, shard


# ----------------------------------------------------------------------
# init helpers
def dense_init(key, shape, axes, dtype, scale: Optional[float] = None) -> Param:
    fan_in = shape[0] if len(shape) >= 2 else max(shape[0], 1)
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return Param(jax.random.normal(key, shape, dtype) * jnp.asarray(std, dtype), axes)


def zeros_init(shape, axes, dtype) -> Param:
    return Param(jnp.zeros(shape, dtype), axes)


def ones_init(shape, axes, dtype) -> Param:
    return Param(jnp.ones(shape, dtype), axes)


# ----------------------------------------------------------------------
# norms
def rms_norm(x, weight, eps: float = 1e-6, plus_one: bool = False):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    w = weight.astype(jnp.float32)
    if plus_one:                       # gemma-style (1 + w) scaling
        w = 1.0 + w
    return (x * w).astype(dt)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# ----------------------------------------------------------------------
# RoPE
def rope_freqs(head_dim: int, base: float) -> jax.Array:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (base ** exponent)                      # (head_dim/2,)


def apply_rope(x: jax.Array, positions: jax.Array, base: float) -> jax.Array:
    """x: (..., seq, heads, head_dim), positions: (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, base)                   # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    cos = jnp.cos(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# MLPs
def init_mlp(key, cfg, d_ff: Optional[int] = None, d_model: Optional[int] = None):
    d = d_model or cfg.d_model
    f = d_ff or cfg.d_ff
    dt = cfg.p_dtype
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.mlp in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(k1, (d, f), ("embed", "ff"), dt),
            "w_up": dense_init(k2, (d, f), ("embed", "ff"), dt),
            "w_down": dense_init(k3, (f, d), ("ff", "embed"), dt),
        }
    return {                                             # plain 2-layer MLP
        "w_up": dense_init(k1, (d, f), ("embed", "ff"), dt),
        "b_up": zeros_init((f,), ("ff",), dt),
        "w_down": dense_init(k2, (f, d), ("ff", "embed"), dt),
        "b_down": zeros_init((d,), ("embed",), dt),
    }


def apply_mlp(params, x, cfg):
    # the output constraint forces the TP all-reduce to happen HERE, on the
    # bf16 matmul result, instead of being hoisted past later fp32 casts
    if cfg.mlp in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp == "swiglu" else lambda v: jax.nn.gelu(v, approximate=True)
        g = act(x @ params["w_gate"])
        h = g * (x @ params["w_up"])
        h = shard(h, "batch", "seq", "ff")
        return shard(h @ params["w_down"], "batch", "seq", None)
    h = jax.nn.gelu(x @ params["w_up"] + params["b_up"], approximate=True)
    h = shard(h, "batch", "seq", "ff")
    return shard(h @ params["w_down"] + params["b_down"], "batch", "seq", None)


# ----------------------------------------------------------------------
# embeddings / unembedding
def init_embedding(key, cfg):
    # vocab dim padded to shard evenly under TP; tail rows are never indexed
    # and their logits are masked in mask_padded_logits().
    return {"table": dense_init(key, (cfg.padded_vocab, cfg.d_model),
                                ("vocab", "embed"), cfg.p_dtype, scale=1.0)}


def embed(params, tokens, cfg):
    x = jnp.take(params["table"], tokens, axis=0).astype(cfg.act_dtype)
    if cfg.emb_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), cfg.act_dtype)
    return x


def mask_padded_logits(logits, cfg):
    if cfg.padded_vocab == cfg.vocab:
        return logits
    keep = jnp.arange(cfg.padded_vocab) < cfg.vocab
    return jnp.where(keep, logits, jnp.asarray(-1e30, logits.dtype))


def unembed(params, x, cfg, table=None):
    t = table if table is not None else params["table"]
    logits = jnp.einsum("...d,vd->...v", x, t.astype(x.dtype))
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = jnp.tanh(logits / c) * c
    return mask_padded_logits(logits, cfg)
