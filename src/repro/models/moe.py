"""Mixture-of-Experts FFN: top-k router + capacity-based gather dispatch.

Dispatch uses gather/scatter (no one-hot einsum), so compiled HLO FLOPs stay
close to *active* FLOPs — important for honest roofline accounting.  Experts
are sharded over the ``model`` mesh axis (EP); GSPMD inserts the all-to-all.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.sharding import shard
from repro.models.layers import dense_init, init_mlp, apply_mlp


def init_moe(key, cfg):
    d, E, f = cfg.d_model, cfg.n_experts, cfg.expert_d_ff
    dt = cfg.p_dtype
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, E), ("embed", None), dt, scale=0.02),
        "w_gate": dense_init(ks[1], (E, d, f), ("experts", "embed", "ff"), dt),
        "w_up": dense_init(ks[2], (E, d, f), ("experts", "embed", "ff"), dt),
        "w_down": dense_init(ks[3], (E, f, d), ("experts", "ff", "embed"), dt),
    }
    if cfg.n_shared_experts:
        shared_ff = cfg.shared_d_ff or cfg.n_shared_experts * cfg.expert_d_ff
        p["shared"] = init_mlp(ks[4], cfg, d_ff=shared_ff)
    return p


def apply_moe(params, x, cfg):
    """x: (B,S,d) -> (out, aux_loss)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    xf = x.reshape(T, d)

    logits = (xf @ params["router"]).astype(jnp.float32)        # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                       # (T,k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch-style)
    density = jnp.mean(jax.nn.one_hot(top_e[:, 0], E, dtype=jnp.float32), axis=0)
    aux = jnp.mean(density * jnp.mean(probs, axis=0)) * (E * E) * cfg.router_aux_weight

    # capacity-based slotting via sort-based ranking: within-expert position
    # = stable arrival order.  (No (T*k, E) one-hot cumsum — that tensor is
    # O(T*E) memory and XLA costs the wide cumsum quadratically.)
    cap = int(max(1, (T * k) // E * cfg.capacity_factor))
    flat_e = top_e.reshape(-1)                                   # (T*k,) in token order
    order = jnp.argsort(flat_e, stable=True)                     # group by expert
    sorted_e = flat_e[order]
    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1].astype(jnp.int32)])
    slot_sorted = jnp.arange(T * k, dtype=jnp.int32) - starts[sorted_e]
    slot = jnp.zeros((T * k,), jnp.int32).at[order].set(slot_sorted)
    keep = slot < cap
    slot = jnp.clip(slot, 0, cap - 1)

    # scatter tokens into (E*cap, d) expert buffers
    dest = flat_e * cap + slot
    src = jnp.repeat(jnp.arange(T), k)
    contrib = jnp.where(keep[:, None], xf[src], 0.0)
    buf = jnp.zeros((E * cap, d), x.dtype).at[dest].add(contrib)
    buf = buf.reshape(E, cap, d)
    buf = shard(buf, "experts", None, None)

    # expert FFN (swiglu), batched over experts
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"]))
    h = g * jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    eout = jnp.einsum("ecf,efd->ecd", h, params["w_down"])       # (E,cap,d)
    eout = eout.reshape(E * cap, d)

    # combine: out[token] += weight * expert_out[slot]
    gathered = eout[dest] * (top_p.reshape(-1)[:, None] * keep[:, None]).astype(x.dtype)
    out = jnp.zeros((T, d), x.dtype).at[src].add(gathered)
    out = shard(out.reshape(B, S, d), "batch", "seq", None)

    if cfg.n_shared_experts:
        out = out + apply_mlp(params["shared"], x, cfg)
    return out, aux
