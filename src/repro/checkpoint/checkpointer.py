"""Sharded, atomic, optionally-async checkpointing (no orbax dependency).

Layout:  <dir>/step_<n>.tmp/  ->  atomic rename  ->  <dir>/step_<n>/
           arrays.npz          (flattened leaves, keyed by tree path)
           meta.json           (treedef repr, step, wall time)
         <dir>/LATEST          (text file with the last committed step)

Restore supports *resharding*: pass target shardings (e.g. from a different
mesh after elastic rescale) and leaves are device_put accordingly — this is
the checkpoint/restart path for node failures and elastic scaling.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out, treedef


class Checkpointer:
    def __init__(self, directory: str, *, async_save: bool = False,
                 keep: int = 3):
        self.dir = directory
        self.async_save = async_save
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any):
        host = jax.device_get(tree)
        if self.async_save:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host), daemon=True)
            self._thread.start()
        else:
            self._write(step, host)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree: Any):
        tmp = os.path.join(self.dir, f"step_{step}.tmp")
        final = os.path.join(self.dir, f"step_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves, treedef = _flatten_with_paths(host_tree)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{k: np.asarray(v) for k, v in leaves.items()})
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"step": step, "time": time.time(),
                       "keys": sorted(leaves)}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                       # atomic commit
        with open(os.path.join(self.dir, "LATEST.tmp"), "w") as f:
            f.write(str(step))
        os.replace(os.path.join(self.dir, "LATEST.tmp"),
                   os.path.join(self.dir, "LATEST"))
        self._gc()

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # ------------------------------------------------------------------
    def steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        p = os.path.join(self.dir, "LATEST")
        if not os.path.exists(p):
            return None
        with open(p) as f:
            return int(f.read().strip())

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of `like`; optionally device_put with
        `shardings` (same treedef) for cross-mesh restore."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        path = os.path.join(self.dir, f"step_{step}", "arrays.npz")
        data = np.load(path)
        like_leaves, treedef = _flatten_with_paths(like)
        restored = {}
        for k, ref in like_leaves.items():
            arr = data[k]
            restored[k] = arr
        leaves = [restored[k] for k in like_leaves]
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.device_put(tree, shardings)
        return tree
