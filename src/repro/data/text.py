"""Text substrate for the MARGOT case study: sentence splitting (the paper's
``split("[.!?]")``), hashed bag-of-words featurization (stand-in for the
Stanford-parse + BoW features), and a deterministic synthetic essay corpus
standing in for the Project Gutenberg essays (DS1-DS4, Table 1).
"""
from __future__ import annotations

import hashlib
import re
from typing import Iterator, List, Sequence, Tuple

import numpy as np

_SENT_SPLIT = re.compile(r"[.!?]")
_TOKEN = re.compile(r"[a-z']+")

# vocabulary flavoring so that synthetic "claims"/"evidence" are learnable
_CLAIM_MARKERS = ["should", "must", "believe", "argue", "clearly", "therefore"]
_EVID_MARKERS = ["survey", "study", "shows", "data", "example", "percent"]
_FILLER = ("the of a to and in that it for on with as at by from up about into "
           "over after beneath under above society people energy policy nature "
           "history science market culture region water matter").split()


def split_sentences(text: str) -> List[str]:
    """The paper's splitter: fileContent.split("[.!?]")."""
    return [s.strip() for s in _SENT_SPLIT.split(text) if s.strip()]


def _hash_idx(token: str, dim: int) -> int:
    return int.from_bytes(hashlib.md5(token.encode()).digest()[:4], "little") % dim


def featurize(sentences: Sequence[str], dim: int = 1024) -> np.ndarray:
    """Hashed binary bag-of-words (B, dim), L2-normalized."""
    X = np.zeros((len(sentences), dim), np.float32)
    for i, s in enumerate(sentences):
        for tok in _TOKEN.findall(s.lower()):
            X[i, _hash_idx(tok, dim)] = 1.0
    norms = np.linalg.norm(X, axis=1, keepdims=True)
    return X / np.maximum(norms, 1e-6)


# ----------------------------------------------------------------------
def synthetic_corpus(n_docs: int, sentences_per_doc: int,
                     seed: int = 0) -> List[List[str]]:
    """Deterministic Gutenberg-essay stand-in: ~12% claim-ish, ~30%
    evidence-ish sentences (matching Table 1's DS ratios)."""
    rng = np.random.RandomState(seed)
    docs = []
    for d in range(n_docs):
        doc = []
        for s in range(sentences_per_doc):
            r = rng.rand()
            words = list(rng.choice(_FILLER, size=rng.randint(6, 14)))
            if r < 0.12:
                words.insert(rng.randint(len(words)), rng.choice(_CLAIM_MARKERS))
                words.insert(rng.randint(len(words)), rng.choice(_CLAIM_MARKERS))
            elif r < 0.42:
                words.insert(rng.randint(len(words)), rng.choice(_EVID_MARKERS))
                words.insert(rng.randint(len(words)), rng.choice(_EVID_MARKERS))
            doc.append(" ".join(words))
        docs.append(doc)
    return docs


def corpus_arrays(docs: List[List[str]], dim: int = 1024):
    """Flatten a corpus into (X, doc_ids, sentences)."""
    sents, keys = [], []
    for d, doc in enumerate(docs):
        sents.extend(doc)
        keys.extend([d] * len(doc))
    return featurize(sents, dim), np.asarray(keys, np.int32), sents


def stream_generator(docs: List[List[str]], rate: float, dim: int = 1024,
                     seed: int = 0) -> Iterator[Tuple[float, int, np.ndarray]]:
    """Yield (timestamp, doc_id, feature_row) at `rate` sentences/sec."""
    t = 0.0
    for d, doc in enumerate(docs):
        X = featurize(doc, dim)
        for i in range(len(doc)):
            yield t, d, X[i]
            t += 1.0 / rate


# ----------------------------------------------------------------------
def margot_models(pcfg, link_seed: int = 7):
    """Deterministic, *discriminative* MARGOT models: linear claim/evidence
    SVMs whose weights are the hashed marker indicators (stand-ins for the
    trained tree-kernel SVMs), plus a link model biased toward
    marker-bearing pairs."""
    import jax
    from repro.core.sharding import split_params
    from repro.models import svm as svm_mod

    def marker_w(markers):
        w = np.zeros((pcfg.feat_dim,), np.float32)
        for m in markers:
            w[_hash_idx(m, pcfg.feat_dim)] = 1.0
        return w

    tree = {
        "claim": svm_mod.init_linear_svm(marker_w(_CLAIM_MARKERS), -0.15),
        "evidence": svm_mod.init_linear_svm(marker_w(_EVID_MARKERS), -0.15),
        "link": svm_mod.init_link(jax.random.PRNGKey(link_seed), pcfg.feat_dim,
                                  rank=pcfg.link_rank),
    }
    return split_params(tree)


def synthetic_tokens(rng_seed: int, batch: int, seq: int, vocab: int,
                     n_batches: int) -> Iterator[np.ndarray]:
    """Deterministic LM token stream (Zipf-ish) for training examples."""
    rng = np.random.RandomState(rng_seed)
    ranks = np.arange(1, vocab + 1)
    p = 1.0 / ranks ** 1.1
    p /= p.sum()
    for _ in range(n_batches):
        yield rng.choice(vocab, size=(batch, seq), p=p).astype(np.int32)
