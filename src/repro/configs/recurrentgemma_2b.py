"""recurrentgemma-2b [arXiv:2402.19427; hf] — Griffin hybrid: RG-LRU + local
attention in a (R,R,L) pattern.  26L d_model=2560 10H (MQA kv=1 head_dim=256)
d_ff=7680 lru_width=2560, local window 2048.
"""
from repro.configs.base import ArchConfig, ScanGroup

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab=256_000,
    groups=(ScanGroup(("R", "R", "L"), 8), ScanGroup(("R", "R"), 1)),
    lru_width=2560,
    conv_k_rg=4,
    window=2048,
    rope_base=10_000.0,
    rope_local_base=10_000.0,
    mlp="geglu",
    rms_plus_one=True,
    emb_scale=True,
    tie_embeddings=True,
)
