"""The paper's own system — MARGOT (Lippi & Torroni 2016) as served by the
two-phase pipeline: claim/evidence SVM detectors + pairwise link scorer.

Presets mirror the paper's experimental setup: the M1/M2/M3 link-model sizes
of Table 2 (support-vector counts), the batch datasets of Table 1 (sentence
counts, scaled), and the stream micro-batch period of §6.2.
"""
from repro.core.pipeline import PipelineConfig
from repro.core.stream import StreamConfig

# phase-1/phase-2 pipeline configuration (feature dim = hashed BoW space)
PIPELINE = PipelineConfig(
    feat_dim=1024,
    claim_capacity=256,
    evid_capacity=512,
    threshold=0.0,            # the paper keeps score > 0 (Listing 1 line 30)
    svm_gamma=0.1,
    svm_coef0=1.0,
    svm_degree=2,             # poly kernel standing in for the SSTK
)

# Table 2: link models (support vectors); scaled 10x down for CPU benches
MODELS_SV = {"M1": 7_085, "M2": 18_604, "M3": 30_363}
MODELS_SV_SCALED = {k: v // 10 for k, v in MODELS_SV.items()}

# Table 1: datasets (sentences); scaled ~75x down for CPU benches
DATASETS = {"DS1": 9_783, "DS2": 67_917, "DS3": 233_254, "DS4": 466_483}

# §6.2: stream evaluation (100 s micro-batches; windows 100/1000/5000 s),
# scaled 400x for CPU benches (period 0.25 s; windows 1/5/25 s)
STREAM = StreamConfig(period=0.25, capacity=1024, scope="window",
                      window=5.0, ring_capacity=1024)
STREAM_WINDOWS_S = (1.0, 5.0, 25.0)

CONFIG = PIPELINE   # registry-style access
