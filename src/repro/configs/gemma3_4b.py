"""gemma3-4b [hf:google/gemma-3-4b-pt; unverified] — dense, 5:1 local:global.
34L d_model=2560 8H (kv=4) d_ff=10240 vocab=262144, head_dim=256,
sliding window 1024 on local layers, dual rope base (10k local / 1M global),
qk-norm, GeGLU, gemma-style (1+w) RMSNorm, tied + scaled embeddings.
"""
from repro.configs.base import ArchConfig, ScanGroup

CONFIG = ArchConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab=262_144,
    groups=(ScanGroup(("L", "L", "L", "L", "L", "G"), 5),
            ScanGroup(("L", "L", "L", "L"), 1)),
    window=1024,
    rope_base=1_000_000.0,
    rope_local_base=10_000.0,
    qk_norm=True,
    mlp="geglu",
    rms_plus_one=True,
    emb_scale=True,
    tie_embeddings=True,
)
