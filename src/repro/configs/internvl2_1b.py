"""internvl2-1b [arXiv:2404.16821; hf] — VLM: ViT frontend STUB + LM backbone.
24L d_model=896 14H (kv=2) d_ff=4864 vocab=151655.  input_specs provides
precomputed patch embeddings (B, n_patches, d_model) prepended to tokens.
"""
from repro.configs.base import ArchConfig, ScanGroup

CONFIG = ArchConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab=151655,
    groups=(ScanGroup(("A",), 24),),
    rope_base=1_000_000.0,
    mlp="swiglu",
    tie_embeddings=True,
    frontend="vision_patches",
    n_patches=256,
)
