"""qwen3-moe-30b-a3b [hf:Qwen/Qwen3-30B-A3B] — MoE decoder.
48L d_model=2048 32H (kv=4, head_dim=128) vocab=151936,
128 routed experts top-8 (no shared), expert d_ff=768, qk-norm.
"""
from repro.configs.base import ArchConfig, ScanGroup

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=768,
    vocab=151_936,
    groups=(ScanGroup(("M",), 48),),
    n_experts=128,
    top_k=8,
    expert_d_ff=768,
    qk_norm=True,
    rope_base=1_000_000.0,
    mlp="swiglu",
)
