"""internlm2-1.8b [arXiv:2403.17297; hf] — dense GQA decoder.
24L d_model=2048 16H (kv=8) d_ff=8192 vocab=92544, RoPE 1e6, SwiGLU, RMSNorm.
"""
from repro.configs.base import ArchConfig, ScanGroup

CONFIG = ArchConfig(
    name="internlm2-1.8b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=92544,
    groups=(ScanGroup(("A",), 24),),
    rope_base=1_000_000.0,
    mlp="swiglu",
)
