"""Architecture registry: ``get_config("starcoder2-3b")`` / ``--arch`` ids."""
from __future__ import annotations

import importlib

from repro.configs.base import ArchConfig, ScanGroup, ShapeCase, SHAPES, SHAPE_BY_NAME, reduced

_MODULES = {
    "starcoder2-3b": "starcoder2_3b",
    "gemma3-4b": "gemma3_4b",
    "internlm2-1.8b": "internlm2_1_8b",
    "gemma-7b": "gemma_7b",
    "whisper-base": "whisper_base",
    "internvl2-1b": "internvl2_1b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "falcon-mamba-7b": "falcon_mamba_7b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(name: str) -> ArchConfig:
    key = name.replace("_", "-") if name not in _MODULES else name
    if key not in _MODULES:
        # allow module-style names too
        inv = {v: k for k, v in _MODULES.items()}
        if name in inv:
            key = inv[name]
        else:
            raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[key]}")
    return mod.CONFIG


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}
