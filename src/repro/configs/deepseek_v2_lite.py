"""deepseek-v2-lite-16b [arXiv:2405.04434; hf] — MoE with MLA attention.
27L d_model=2048 16H, MLA kv_lora=512 (rope 64 / nope 128 / v 128),
layer 0 dense (d_ff=10944), layers 1..26 MoE: 2 shared + 64 routed top-6,
expert d_ff=1408, vocab=102400.

NOTE: the assignment bracket says "160 routed" which is DeepSeek-V2 (236B);
the primary spec line says "MoE 64e top-6" which is the -Lite config we build
(see DESIGN.md §5).
"""
from repro.configs.base import ArchConfig, ScanGroup

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab=102_400,
    groups=(ScanGroup(("D",), 1), ScanGroup(("M",), 26)),
    dense_d_ff=10944,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    expert_d_ff=1408,
    shared_d_ff=2816,
    kv_lora_rank=512,
    q_lora_rank=0,
    rope_head_dim=64,
    nope_head_dim=128,
    v_head_dim=128,
    rope_base=10_000.0,
    mlp="swiglu",
)
