"""whisper-base [arXiv:2212.04356; unverified] — encoder-decoder backbone.
6L enc + 6L dec, d_model=512 8H d_ff=2048 vocab=51865.  The conv audio
frontend is a STUB: input_specs provides precomputed frame embeddings.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="encdec",
    n_layers=12,
    enc_layers=6,
    dec_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab=51865,
    rope_base=0.0,              # sinusoidal positions, no rope
    mlp="gelu_mlp",
    norm="layernorm",
    norm_eps=1e-5,
    frontend="audio_frames",
)
