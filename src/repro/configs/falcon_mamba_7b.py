"""falcon-mamba-7b [arXiv:2410.05355; unverified] — pure Mamba-1 SSM, attn-free.
64L d_model=4096, d_inner=8192, ssm_state=16, dt_rank=256, conv_k=4,
vocab=65024.
"""
from repro.configs.base import ArchConfig, ScanGroup

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab=65024,
    groups=(ScanGroup(("S",), 64),),
    ssm_state=16,
    d_inner=8192,
    dt_rank=256,
    conv_k=4,
    mlp="swiglu",
    tie_embeddings=True,
)
