"""Architecture configuration system.

Every assigned architecture is described by one :class:`ArchConfig`.  A config
is a *complete* static description of the model: the layer pattern (including
heterogeneous hybrids like Griffin's (R,R,A) blocks), attention flavor, MoE
routing, SSM dimensions, and the modality frontend stubs.

Layer patterns are expressed as ``ScanGroup``s: ``pattern`` is a tuple of
layer-kind codes and the group is scanned ``repeats`` times, so a 34-layer
Gemma-3 (5 local : 1 global) is ``[ScanGroup(("L",)*5 + ("G",), 5),
ScanGroup(("L",)*4, 1)]``.  Kind codes:

  ``A`` full (causal) attention block      ``L`` local sliding-window attention
  ``G`` global attention (dual-rope base)  ``R`` RG-LRU recurrent block
  ``M`` MoE block (attention + routed FFN) ``S`` Mamba-1 SSM block
  ``D`` dense block in a MoE model (attention + dense FFN)
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ScanGroup:
    pattern: Tuple[str, ...]
    repeats: int

    @property
    def n_layers(self) -> int:
        return len(self.pattern) * self.repeats


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    groups: Tuple[ScanGroup, ...] = ()

    # --- attention ---
    rope_base: float = 10_000.0
    rope_local_base: float = 10_000.0   # for "L" layers when dual-rope (gemma3)
    window: int = 0                     # sliding window for "L" layers
    qk_norm: bool = False               # qwen3 / gemma3 style
    logit_softcap: float = 0.0          # final-logit soft capping (gemma family)
    attn_softcap: float = 0.0

    # --- MLP ---
    mlp: str = "swiglu"                 # swiglu | geglu | gelu_mlp
    emb_scale: bool = False             # multiply embeddings by sqrt(d_model)
    tie_embeddings: bool = False

    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    shared_d_ff: int = 0
    dense_d_ff: int = 0                 # d_ff of "D" layers in MoE models
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001

    # --- MLA (deepseek) ---
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 0
    nope_head_dim: int = 0
    v_head_dim: int = 0

    # --- SSM (mamba-1) ---
    ssm_state: int = 0
    d_inner: int = 0
    conv_k: int = 4
    dt_rank: int = 0

    # --- RG-LRU (griffin/recurrentgemma) ---
    lru_width: int = 0
    conv_k_rg: int = 4

    # --- encoder-decoder (whisper backbone) ---
    enc_layers: int = 0
    dec_layers: int = 0

    # --- modality frontend stubs ---
    frontend: str = "none"              # none | audio_frames | vision_patches
    n_patches: int = 0                  # prepended patch embeddings (vlm)

    # --- numerics ---
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    norm: str = "rmsnorm"               # rmsnorm | layernorm
    rms_plus_one: bool = False          # gemma-style (1 + w) rmsnorm scale

    # --- runtime knobs (hillclimb surface) ---
    remat: str = "none"                 # none | full | dots
    use_kernels: bool = False           # route attention through Pallas kernels
    scan_layers: bool = True

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if not self.groups and self.n_layers:
            kind = {"moe": "M", "ssm": "S"}.get(self.family, "A")
            object.__setattr__(self, "groups", (ScanGroup((kind,), self.n_layers),))
        total = sum(g.n_layers for g in self.groups)
        expect = self.n_layers if self.family != "encdec" else self.enc_layers + self.dec_layers
        if self.family != "encdec":
            assert total == self.n_layers, (self.name, total, self.n_layers)

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up so the vocab dim shards evenly under TP
        (embedding-table padding, standard for production LM stacks)."""
        m = 128
        return -(-self.vocab // m) * m

    @property
    def act_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def p_dtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def sub_quadratic(self) -> bool:
        """True if a 500k-token decode context is feasible (SSM / hybrid /
        mostly-local attention).  Pure full-attention archs return False."""
        kinds = set()
        for g in self.groups:
            kinds.update(g.pattern)
        if self.family == "encdec":
            return False
        full_attn = kinds & {"A", "M", "D"}
        return not full_attn  # only L/G/R/S layers (G = few global layers, run)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


# ----------------------------------------------------------------------
# Input shapes assigned to every LM-family architecture.
@dataclasses.dataclass(frozen=True)
class ShapeCase:
    name: str
    seq_len: int
    global_batch: int
    kind: str                # train | prefill | decode


SHAPES: Tuple[ShapeCase, ...] = (
    ShapeCase("train_4k", 4_096, 256, "train"),
    ShapeCase("prefill_32k", 32_768, 32, "prefill"),
    ShapeCase("decode_32k", 32_768, 128, "decode"),
    ShapeCase("long_500k", 524_288, 1, "decode"),
)

SHAPE_BY_NAME = {s.name: s for s in SHAPES}


def reduced(cfg: ArchConfig) -> ArchConfig:
    """A tiny same-family config for CPU smoke tests."""
    groups = []
    for g in cfg.groups:
        groups.append(ScanGroup(g.pattern, min(g.repeats, 1)))
    groups = tuple(groups)
    n_layers = sum(g.n_layers for g in groups)
    kw = dict(
        n_layers=n_layers,
        groups=groups,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        head_dim=16,
        d_ff=128,
        vocab=256,
        window=min(cfg.window, 16) if cfg.window else 0,
        dtype="float32",
        param_dtype="float32",
    )
    if cfg.n_experts:
        kw.update(n_experts=8, top_k=min(cfg.top_k, 2), expert_d_ff=32,
                  shared_d_ff=64 if cfg.n_shared_experts else 0,
                  dense_d_ff=128 if cfg.dense_d_ff else 0)
    if cfg.kv_lora_rank:
        kw.update(kv_lora_rank=32, q_lora_rank=0, rope_head_dim=8,
                  nope_head_dim=16, v_head_dim=16)
    if cfg.ssm_state:
        kw.update(ssm_state=8, d_inner=128, dt_rank=8, conv_k=4)
    if cfg.lru_width:
        kw.update(lru_width=64)
    if cfg.family == "encdec":
        enc = max(1, cfg.enc_layers // 6)
        dec = max(1, cfg.dec_layers // 6)
        kw.update(enc_layers=enc, dec_layers=dec, n_layers=enc + dec, groups=())
    if cfg.n_patches:
        kw.update(n_patches=4)
    return cfg.replace(**kw)
