"""starcoder2-3b [arXiv:2402.19173; hf] — dense GQA decoder.
30L d_model=3072 24H (kv=2) d_ff=12288 vocab=49152, RoPE, LayerNorm+gelu MLP.
"""
from repro.configs.base import ArchConfig, ScanGroup

CONFIG = ArchConfig(
    name="starcoder2-3b",
    family="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    head_dim=128,
    d_ff=12288,
    vocab=49152,
    groups=(ScanGroup(("A",), 30),),
    rope_base=999_999.4,        # starcoder2 rope theta
    mlp="gelu_mlp",
    norm="layernorm",
    norm_eps=1e-5,
    tie_embeddings=True,
)
