"""gemma-7b [arXiv:2403.08295; hf] — dense MHA (kv=16), GeGLU, head_dim=256.
28L d_model=3072 16H d_ff=24576 vocab=256000, scaled+tied embeddings.
"""
from repro.configs.base import ArchConfig, ScanGroup

CONFIG = ArchConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab=256_000,
    groups=(ScanGroup(("A",), 28),),
    rope_base=10_000.0,
    mlp="geglu",
    rms_plus_one=True,
    emb_scale=True,
    tie_embeddings=True,
)
