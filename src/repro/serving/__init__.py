from repro.serving.engine import (Engine, Request, ServeConfig,  # noqa: F401
                                  make_engine_fns)
