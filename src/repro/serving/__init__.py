from repro.serving.engine import (Engine, EngineFns, Request,  # noqa: F401
                                  ServeConfig, make_engine_fns, pad_tolerant)
