from repro.serving.engine import (Engine, EngineFns, Request,  # noqa: F401
                                  ServeConfig, SessionSnapshot,
                                  make_engine_fns, pad_tolerant)
from repro.serving.kvpool import (BlockAllocator, PoolExhausted,  # noqa: F401
                                  hash_token_blocks, hash_token_blocks_memo,
                                  pack_block_arrays, padded_table,
                                  unpack_block_arrays)
