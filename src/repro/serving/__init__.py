from repro.serving.engine import (Engine, EngineFns, Request,  # noqa: F401
                                  ServeConfig, make_engine_fns, pad_tolerant)
from repro.serving.kvpool import (BlockAllocator, PoolExhausted,  # noqa: F401
                                  hash_token_blocks, hash_token_blocks_memo,
                                  padded_table)
