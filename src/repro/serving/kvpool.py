"""Paged KV-cache subsystem: host-side block bookkeeping for the engine.

The dense engine pins one ``max_len`` KV block per decode slot, so slot
*memory* — not compute — caps how many LM sessions a replica can hold.
This module is the host half of the paged alternative:

  * the device holds one **block pool** per attention layer —
    ``(num_blocks, block_size, kv_heads, head_dim)`` for K and V — shared
    by every sequence on the engine;
  * a sequence owns a **block table**: the list of physical block ids
    backing its virtual positions ``[0, pos)``, allocated on demand as
    decode advances instead of reserved up front;
  * blocks are **refcounted** so two sequences can share physical blocks
    (a prefix-cache hit, or a :meth:`BlockAllocator.fork`), with
    **copy-on-write**: a shared block is copied to a private one before a
    sequence may write into it;
  * a **content-hashed prefix cache** maps chains of full prompt blocks
    to their physical blocks, so a shared system/task prompt is prefilled
    once and reused by every later session (the cache holds its own
    reference; cached blocks evict LRU under pool pressure).

Everything here is plain host Python — the allocator never touches jax.
The engine (``serving/engine.py``) executes the device side of each
decision: scattering prefill K/V into the pool, gathering through block
tables in the decode kernel, and copying pool rows when
:meth:`BlockAllocator.cow_targets` says a write would land on a shared
block.

Physical block 0 is reserved as the **null block**: it is never
allocated, block-table padding points at it, and masked/pad writes are
redirected into it — so a stale table entry can corrupt nothing.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import struct
from collections import OrderedDict
from typing import (Callable, Dict, Iterable, List, Optional, Sequence,
                    Tuple)

NULL_BLOCK = 0

# Serialized-block wire format magic (pack_block_arrays). Bump the digit
# if the layout ever changes; unpack refuses unknown magics outright.
_PACK_MAGIC = b"KVB1"


def pack_block_arrays(arrays: Sequence) -> bytes:
    """Serialize a list of numpy arrays to one deterministic byte string.

    The format is self-describing and bit-exact: magic, count, then per
    array the dtype string (which includes byte order, e.g. ``<f4``), the
    shape, and the raw C-order buffer.  Pure numpy — no pickle, no jax —
    so the same bytes come out on every host and the sha256 of the
    payload is a stable content address for the ArtifactStore swap tier.
    """
    import numpy as np
    out = [_PACK_MAGIC, struct.pack("<I", len(arrays))]
    for a in arrays:
        a = np.ascontiguousarray(a)
        ds = a.dtype.str.encode("ascii")
        raw = a.tobytes()
        out.append(struct.pack("<H", len(ds)))
        out.append(ds)
        out.append(struct.pack("<B", a.ndim))
        out.append(struct.pack(f"<{a.ndim}q", *a.shape))
        out.append(struct.pack("<Q", len(raw)))
        out.append(raw)
    return b"".join(out)


def unpack_block_arrays(data: bytes) -> List:
    """Inverse of :func:`pack_block_arrays`; bit-exact roundtrip."""
    import numpy as np
    if data[:4] != _PACK_MAGIC:
        raise ValueError("bad kv block payload magic")
    off = 4
    (count,) = struct.unpack_from("<I", data, off)
    off += 4
    arrays: List = []
    for _ in range(count):
        (dlen,) = struct.unpack_from("<H", data, off)
        off += 2
        dtype = np.dtype(data[off:off + dlen].decode("ascii"))
        off += dlen
        (ndim,) = struct.unpack_from("<B", data, off)
        off += 1
        shape = struct.unpack_from(f"<{ndim}q", data, off)
        off += 8 * ndim
        (nbytes,) = struct.unpack_from("<Q", data, off)
        off += 8
        a = np.frombuffer(data, dtype=dtype, count=nbytes // dtype.itemsize,
                          offset=off).reshape(shape).copy()
        off += nbytes
        arrays.append(a)
    return arrays


def hash_token_blocks(tokens: Sequence[int], block_size: int) -> List[bytes]:
    """Chained content hashes of the *full* blocks of a token sequence.

    ``h_i = sha256(h_{i-1} || tokens[i*bs:(i+1)*bs])`` — chaining makes a
    block hash identify the whole prefix up to and including that block,
    which is what lets two prompts share exactly their common full-block
    prefix and nothing more.
    """
    out: List[bytes] = []
    prev = b""
    for j in range(len(tokens) // block_size):
        blk = tokens[j * block_size:(j + 1) * block_size]
        h = hashlib.sha256()
        h.update(prev)
        h.update(",".join(str(int(t)) for t in blk).encode())
        prev = h.digest()
        out.append(prev)
    return out


@functools.lru_cache(maxsize=4096)
def _hash_blocks_memo(tok_bytes: bytes, block_size: int) -> Tuple[bytes, ...]:
    import numpy as np
    tokens = np.frombuffer(tok_bytes, dtype=np.int32)
    return tuple(hash_token_blocks([int(t) for t in tokens], block_size))


def hash_token_blocks_memo(prompt, block_size: int) -> List[bytes]:
    """:func:`hash_token_blocks` over an int32 numpy prompt, memoized on
    the token bytes.  Serving workloads re-submit identical prompts (and
    identical shared prefixes hash block-by-block anyway), so the sha256
    chain — which used to run on the admit critical path every time —
    amortizes to a dict lookup.  The engine calls this at ``submit()``
    time, off the step loop entirely."""
    return list(_hash_blocks_memo(prompt.astype("int32").tobytes(),
                                  block_size))


class PoolExhausted(RuntimeError):
    """No free block and nothing evictable: the pool cannot satisfy the
    allocation.  Admission gating on :meth:`BlockAllocator.free_blocks`
    headroom exists to make this unreachable in normal operation."""


@dataclasses.dataclass
class SeqState:
    """Host view of one sequence's paged cache."""
    seq_id: int
    table: List[int] = dataclasses.field(default_factory=list)


class BlockAllocator:
    """Free list + refcounts + per-sequence block tables + COW decisions.

    ``num_blocks`` counts *usable* blocks; the device pool has
    ``num_blocks + 1`` rows because row 0 is the reserved null block.
    """

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 1:
            raise ValueError("need at least one usable block")
        self.block_size = block_size
        self.num_blocks = num_blocks
        # LIFO free list: recently-freed blocks are re-used first (warm)
        self._free: List[int] = list(range(num_blocks, 0, -1))
        self._ref: Dict[int, int] = {}
        self._seqs: Dict[int, SeqState] = {}
        self._next_seq = 0
        # prefix cache: chained block hash -> physical block id.  Ordered
        # for LRU eviction (move_to_end on hit).  The cache owns one
        # reference on every block it maps.
        self._prefix: "OrderedDict[bytes, int]" = OrderedDict()
        self.evictions = 0
        self.cow_copies = 0
        # observability: called with the evicted block id on every prefix
        # cache eviction (the engine points this at the flight recorder)
        self.on_evict: Optional[Callable[[int], None]] = None

    # -- introspection ---------------------------------------------------
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def evictable_blocks(self) -> int:
        """Cached prefix blocks held *only* by the cache (refcount 1)."""
        return sum(1 for b in self._prefix.values() if self._ref[b] == 1)

    @property
    def available_blocks(self) -> int:
        """What an allocation burst could obtain: free + evictable."""
        return self.free_blocks + self.evictable_blocks

    def available_excluding(self, pinned: Iterable[int]) -> int:
        """Allocation headroom if ``pinned`` blocks become un-evictable —
        the admit probe's view: taking shared references on its prefix
        hits removes exactly those blocks from the eviction pool, so they
        must not be double-counted as both reusable *and* evictable."""
        pin = set(pinned)
        evict = sum(1 for b in self._prefix.values()
                    if self._ref[b] == 1 and b not in pin)
        return self.free_blocks + evict

    @property
    def cached_blocks(self) -> int:
        return len(self._prefix)

    def refcount(self, block: int) -> int:
        return self._ref.get(block, 0)

    def table(self, seq_id: int) -> List[int]:
        return list(self._seqs[seq_id].table)

    # -- allocation ------------------------------------------------------
    def _pop_free(self) -> int:
        if not self._free:
            if not self._evict_one():
                raise PoolExhausted(
                    f"kv pool exhausted: {self.num_blocks} blocks all "
                    f"referenced, none cached/evictable")
        return self._free.pop()

    def _evict_one(self) -> bool:
        """Drop the least-recently-used prefix-cache entry whose block is
        not shared with any live sequence."""
        victim = next((h for h, b in self._prefix.items()
                       if self._ref[b] == 1), None)
        if victim is None:
            return False
        b = self._prefix.pop(victim)
        self._decref(b)
        self.evictions += 1
        if self.on_evict is not None:
            try:
                self.on_evict(b)
            except Exception:       # noqa: BLE001 - telemetry stays inert
                pass
        return True

    def _decref(self, block: int) -> None:
        self._ref[block] -= 1
        if self._ref[block] == 0:
            del self._ref[block]
            self._free.append(block)

    def new_seq(self) -> int:
        sid = self._next_seq
        self._next_seq += 1
        self._seqs[sid] = SeqState(sid)
        return sid

    def extend_to(self, seq_id: int, n_tokens: int) -> List[int]:
        """Grow ``seq_id``'s table to cover ``n_tokens`` positions;
        returns the newly-allocated block ids (may be empty)."""
        st = self._seqs[seq_id]
        need = -(-n_tokens // self.block_size)
        fresh: List[int] = []
        while len(st.table) < need:
            b = self._pop_free()
            self._ref[b] = 1
            st.table.append(b)
            fresh.append(b)
        return fresh

    def append_shared(self, seq_id: int, blocks: Iterable[int]) -> None:
        """Append already-referenced blocks (a prefix-cache hit) to the
        sequence's table, taking one reference per block."""
        st = self._seqs[seq_id]
        for b in blocks:
            self._ref[b] = self._ref.get(b, 0) + 1
            st.table.append(b)

    def free_seq(self, seq_id: int) -> None:
        st = self._seqs.pop(seq_id, None)
        if st is None:
            return
        for b in st.table:
            self._decref(b)

    # -- serialization pins ----------------------------------------------
    def pin(self, blocks: Iterable[int]) -> None:
        """Take one extra reference on each block for the duration of a
        serialization (swap-out / migration export).  A pinned block
        cannot reach refcount 0 — so neither :meth:`free_seq` nor a
        prefix-cache eviction can recycle it while its rows are being
        gathered off the device.  Pair with :meth:`unpin` in a finally
        block."""
        for b in blocks:
            self._ref[b] = self._ref.get(b, 0) + 1

    def unpin(self, blocks: Iterable[int]) -> None:
        """Release serialization pins taken by :meth:`pin`."""
        for b in blocks:
            self._decref(b)

    # -- sharing / COW ---------------------------------------------------
    def fork(self, seq_id: int) -> int:
        """New sequence sharing *all* of ``seq_id``'s blocks (refcounts
        bumped).  Writes by either side into a shared block must go
        through :meth:`cow_targets` first."""
        child = self.new_seq()
        self.append_shared(child, self._seqs[seq_id].table)
        return child

    def cow_targets(self, seq_id: int, lo_pos: int,
                    hi_pos: int) -> List[Tuple[int, int]]:
        """Make positions ``[lo_pos, hi_pos)`` of ``seq_id`` writable.

        Any table entry in that range with refcount > 1 is replaced by a
        fresh private block; returns ``(src, dst)`` pairs the caller must
        mirror on device (``pool[dst] = pool[src]``) before writing.
        """
        if hi_pos <= lo_pos:
            return []
        st = self._seqs[seq_id]
        copies: List[Tuple[int, int]] = []
        lo_b = lo_pos // self.block_size
        hi_b = -(-hi_pos // self.block_size)
        for j in range(lo_b, min(hi_b, len(st.table))):
            src = st.table[j]
            if self._ref.get(src, 0) > 1:
                dst = self._pop_free()
                self._ref[dst] = 1
                st.table[j] = dst
                self._decref(src)
                copies.append((src, dst))
                self.cow_copies += 1
        return copies

    # -- prefix cache ----------------------------------------------------
    def prefix_lookup(self, hashes: Sequence[bytes]) -> List[int]:
        """Longest cached chain prefix of ``hashes`` -> block ids (LRU
        refreshed).  Does NOT take references — pair with
        :meth:`append_shared`."""
        out: List[int] = []
        for h in hashes:
            b = self._prefix.get(h)
            if b is None:
                break
            self._prefix.move_to_end(h)
            out.append(b)
        return out

    def prefix_insert(self, hashes: Sequence[bytes],
                      blocks: Sequence[int]) -> int:
        """Map each hash to its (already-written, immutable) block; the
        cache takes one reference per newly-inserted entry.  Returns how
        many entries were new."""
        added = 0
        for h, b in zip(hashes, blocks):
            cur = self._prefix.get(h)
            if cur is not None:
                self._prefix.move_to_end(h)
                continue
            self._prefix[h] = b
            self._ref[b] = self._ref.get(b, 0) + 1
            added += 1
        return added

    def prefix_items(self) -> List[Tuple[bytes, int]]:
        """Prefix-cache contents as ``(hash, block)`` pairs in LRU order
        (oldest first) — the migration export's shipping manifest."""
        return list(self._prefix.items())

    def import_cached(self, h: bytes) -> Optional[int]:
        """Bind one *free* block to prefix-cache entry ``h`` (a migrated
        block about to be filled by a device import).

        Returns the bound block id, or ``None`` when the hash is already
        cached (LRU refreshed — the import is a no-op) or when no free
        block exists.  Deliberately never evicts: adopted blocks enter as
        ordinary cache entries with the cache's single reference, so they
        stay evictable and admission headroom never shrinks below what a
        cold replica would have had.
        """
        if h in self._prefix:
            self._prefix.move_to_end(h)
            return None
        if not self._free:
            return None
        b = self._free.pop()
        self._ref[b] = 1
        self._prefix[h] = b
        return b


def padded_table(table: Sequence[int], nb_max: int) -> List[int]:
    """Fixed-width device form of a block table: pad with the null block."""
    if len(table) > nb_max:
        raise ValueError(f"table of {len(table)} blocks exceeds nb_max="
                         f"{nb_max}")
    return list(table) + [NULL_BLOCK] * (nb_max - len(table))
