"""LM serving engine with continuous batching.

This is the paper's two-phase pipeline read onto LM serving (see
docs/DESIGN.md, "Two-phase pipeline -> serving"):
prefill is the per-instance *map* (each request independent), the batcher is
the *aggregation* (requests meet in a shared decode batch), and the decode
step is the parallel post-aggregation map.  Weights are placed once
(broadcast/tp policy) and reused across micro-batches — the mapPartitions
amortization.

Static shapes throughout: a fixed number of decode slots; prefill pads to
power-of-two buckets (pad-tolerant families only) to bound recompilation.

Two hot paths (``ServeConfig.fused``):

* **fused** (default): a decode iteration never leaves the device — the
  jitted step embeds, runs the backbone, and *samples in-jit* (greedy or
  temperature), returning only ``(slots,)`` token ids; caches / pos /
  last-token / liveness / budget are donated device buffers updated in
  place; a ``lax.fori_loop`` runs ``sync_every`` (K) steps per host sync
  with per-slot stop honored exactly via masking; admits run as bucketed
  batch prefill fused with a donated slot insert.
* **reference**: the original per-token loop (one host round trip and a
  ``(slots, vocab)`` logits transfer per token, full cache re-materialized
  per step and per admit).  It is the parity oracle
  (``tests/test_serving_fused.py``) and the "before" side of
  ``BENCH_serving.json``.

With ``ServeConfig.paged`` the fused loop additionally runs against a
**paged KV cache** (``serving/kvpool.py``): K/V live in a shared
per-layer block pool addressed through per-slot block tables, blocks are
allocated as decode advances (not reserved at ``max_len``), shared
system/task prompts are prefilled once via a content-hashed prefix cache,
and forks share blocks copy-on-write.  Token-exact vs the dense fused
path (``tests/test_serving_paged.py``); capacity numbers in
``BENCH_serving.json`` under ``"paged"``.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster.metrics import MetricsRegistry
from repro.cluster.tracing import (NULL_SPAN, annotate, current_recorder,
                                   current_tracer)
from repro.models import api, transformer as tfm
from repro.serving.kvpool import (NULL_BLOCK, BlockAllocator, PoolExhausted,
                                  hash_token_blocks_memo, pack_block_arrays,
                                  padded_table, unpack_block_arrays)


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 512              # cache length per slot
    slots: int = 4                  # decode batch size (continuous batching)
    fused: bool = True              # on-device K-step loop + in-jit sampling
    sync_every: int = 8             # K: decode steps per host sync (fused)
    temperature: float = 0.0        # 0.0 -> greedy argmax (in-jit either way)
    seed: int = 0                   # sampling rng seed (temperature > 0)
    # Pad prompts up to power-of-two buckets so several queued requests
    # prefill in one call.  Auto-gated: recurrent archs (SSM/RG-LRU) would
    # absorb pads into their state, MoE capacity couples batch rows, and
    # ring (windowed) caches could evict real K/V — those families keep the
    # exact-length path (same-length prompts still batch there).
    prefill_bucketing: bool = True
    min_bucket: int = 8             # smallest prefill bucket (pad-tolerant)
    # Paged KV cache (serving/kvpool.py): K/V live in a shared block pool
    # instead of one dense max_len stripe per slot, so per-replica session
    # capacity is bounded by *tokens in flight*, not slots x max_len.
    # Families holding non-pageable state (SSM/RG-LRU/MLA/ring windows)
    # silently keep the dense path (engine.paged reports the outcome).
    paged: bool = False
    block_size: int = 16            # tokens per KV block
    # usable pool blocks; 0 -> slots * (max_len / block_size), i.e. the
    # same token capacity the dense layout reserves.  Capacity gains come
    # from raising `slots` while holding kv_blocks * block_size fixed.
    kv_blocks: int = 0
    prefix_cache: bool = True       # content-hashed full-block prompt reuse
    # Speculative multi-token decode (paged + greedy only): an in-loop
    # n-gram draft proposes `spec_draft` tokens per fused step, verify is
    # one batched paged extend over the whole decode batch, and the
    # accepted prefix plus one corrected token is emitted — 1..spec_draft+1
    # tokens per backbone pass, token-exact vs the non-speculative loop.
    # MoE families silently fall back to non-speculative paged decode
    # (expert capacity couples the verify window's batch rows).
    speculative: bool = False
    spec_draft: int = 3             # drafted tokens per verify window
    # KV lifecycle (paged only): under block-pool pressure, preempt the
    # lowest-priority active session — serialize its blocks off-device,
    # free them, and re-admit later with the swapped prefix restored
    # block-exact — instead of completing it early as a
    # `kv_pool_exhausted` victim.  Turns 4x pool oversubscription into
    # routine operation; token streams are unchanged by construction
    # (the restored pool rows are the bytes that were swapped out).
    kv_swap: bool = False
    swap_tier: str = "host"         # "host" (in-request bytes) | "artifact"

    def __post_init__(self):
        if self.fused and self.sync_every < 1:
            raise ValueError(f"sync_every must be >= 1, got "
                             f"{self.sync_every}: a 0-step fused loop would "
                             f"spin without ever finishing a request")
        if not self.fused and self.temperature:
            raise ValueError("the reference (fused=False) path decodes "
                             "greedy-only; temperature sampling requires "
                             "the fused engine")
        if self.paged:
            if not self.fused:
                raise ValueError("paged=True requires the fused engine; "
                                 "the per-token reference loop is dense-"
                                 "only (it is the parity oracle)")
            if self.block_size < 1 or self.max_len % self.block_size:
                raise ValueError(
                    f"block_size ({self.block_size}) must divide max_len "
                    f"({self.max_len}): equal virtual cache length is what "
                    f"makes the paged path token-exact vs the dense oracle")
        if self.speculative:
            if not self.paged:
                raise ValueError("speculative=True requires paged=True: "
                                 "the draft/verify loop runs as a batched "
                                 "extend over the paged block pool")
            if self.temperature:
                raise ValueError("speculative decode is greedy-only: the "
                                 "accepted-prefix emission is token-exact "
                                 "only under argmax (temperature == 0)")
            if self.spec_draft < 1:
                raise ValueError(f"spec_draft must be >= 1, got "
                                 f"{self.spec_draft}")
        if self.kv_swap and not self.paged:
            raise ValueError("kv_swap=True requires paged=True: swap "
                             "serializes KV *blocks*; the dense layout "
                             "has no block granularity to preempt at")
        if self.swap_tier not in ("host", "artifact"):
            raise ValueError(f"swap_tier must be 'host' or 'artifact', "
                             f"got {self.swap_tier!r}")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (S,) int32
    max_new: int                    # decoded-token budget (prefill token free)
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    finish_reason: str = ""         # "max_new" | "max_len" once done
    submit_t: float = 0.0
    first_token_t: float = 0.0
    done_t: float = 0.0
    # streaming: called at every host sync with the tokens that sync
    # produced — on_tokens(req, new_tokens, done).  One call per K-step
    # sync on the fused/paged paths, per token on the reference path.
    on_tokens: Optional[Callable[["Request", List[int], bool], None]] = None
    # tracing: the engine-side request span (submit -> finish) and the
    # context engine batch spans parent on; under a cluster the context
    # arrives with the work item, standalone submits root their own
    trace_span: Any = None
    trace_ctx: Any = None
    # paged engines compute the chained prefix-cache block hashes at
    # submit() time (memoized across identical prompts) so the sha256
    # chain never runs on the admit critical path
    block_hashes: Optional[List[bytes]] = None
    # KV-swap preemption order: lower preempts first; ties break toward
    # the newest request (least decode work lost).  0 is the default
    # class — the future per-tenant priority plumbing lands here.
    priority: int = 0
    # set while the request is swapped out: the serialized KV state a
    # re-admit restores instead of re-prefilling (see SessionSnapshot)
    kv_snapshot: Optional["SessionSnapshot"] = None
    # resilience: absolute time.monotonic() deadline — once passed the
    # engine finishes the session (queued or mid-decode) with
    # finish_reason="deadline" and frees its KV instead of decoding
    # tokens nobody will read; ``cancel_cb()`` is polled each host sync
    # and True finishes it with finish_reason="cancelled" the same way
    deadline_s: Optional[float] = None
    cancel_cb: Optional[Callable[[], bool]] = None

    @property
    def decoded(self) -> int:
        """Tokens produced by decode steps (excludes the prefill sample)."""
        return max(len(self.out_tokens) - 1, 0)


@dataclasses.dataclass
class SessionSnapshot:
    """Everything a preempted session needs to resume block-exact.

    The device side is ``n_blocks`` pool rows covering positions
    ``[0, pos)`` — serialized via :func:`pack_block_arrays` and carried
    either inline (``data``, host swap tier) or as a content-addressed
    ``digest`` in the ArtifactStore (``swap_tier="artifact"``).  The host
    side is the three scalars the fused loop needs: the next write
    position, the remaining decode budget, and the last emitted token
    (the next step's input).  ``Request.out_tokens`` stays on the request
    itself, so emission resumes mid-stream with nothing re-emitted.
    """
    pos: int
    rem: int
    last_tok: int
    n_blocks: int
    data: Optional[bytes] = None
    digest: Optional[str] = None


def _insert_slot(big, small, slot: int):
    """Write a batch-1 cache pytree into slot `slot` of the engine cache.
    Cache leaves have batch at axis 1: (repeats, B, ...)."""
    return jax.tree_util.tree_map(
        lambda b, s: b.at[:, slot:slot + 1].set(s.astype(b.dtype)), big, small)


def pad_tolerant(cfg, max_len: int) -> bool:
    """Can this arch prefill right-padded prompts exactly?

    False for SSM ("S") / RG-LRU ("R") — the recurrent state would absorb
    pad tokens; for MoE ("M") — expert capacity couples batch rows, so pads
    can displace real tokens; and for windowed attention ("L") with a ring
    cache — writing pads into the ring can evict real K/V.  Plain causal /
    global attention is exactly invariant to right-padding (pads sit
    *after* every real token, decode masks positions beyond ``pos``, and
    each pad cache entry is overwritten before it ever becomes visible).
    """
    for g in cfg.groups:
        for kind in g.pattern:
            if kind in ("S", "R", "M"):
                return False
            if kind == "L" and cfg.window and cfg.window < max_len:
                return False
    return True


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 1).bit_length()


class _PromptTooLong(ValueError):
    """A prompt no allocation could ever satisfy (needs more blocks than
    the whole pool): rejected per-request, never raised out of step()."""


class EngineFns:
    """Jitted engine functions shareable by N engine replicas with identical
    cfg/scfg — one XLA compile for the whole pool instead of one per replica.

    Fused-path functions donate the engine's device state (caches, pos,
    last-token, liveness, budget) so XLA updates the KV caches in place
    instead of copying the full pytree every step/admit; callers must treat
    the passed-in state as consumed and adopt the returned buffers.
    """

    def __init__(self, cfg, scfg: ServeConfig):
        self.cfg, self.scfg = cfg, scfg
        self.pad_ok = pad_tolerant(cfg, scfg.max_len)
        self.paged_ok = tfm.paged_supported(cfg, scfg.max_len)
        # MoE expert capacity couples batch rows: admitting several prompts
        # (or pad-duplicated rows) in one prefill would let rows displace
        # each other's expert slots and diverge from the reference path's
        # batch-1 admits — so MoE admits stay batch-1
        self.row_coupled = any(k == "M" for g in cfg.groups
                               for k in g.pattern)
        self.decode = jax.jit(
            lambda p, t, c, pos: tfm.decode_step(p, cfg, t, c, pos))
        # jit-cache builds are locked: the bundle is shared across thread
        # replicas, and a duplicated build means a duplicated multi-second
        # XLA compile — the exact cost this class exists to amortize
        self._build_lock = threading.Lock()
        # (plen,) -> jitted exact-length batch-1 prefill (reference path)
        self.prefill_cache: Dict[int, Callable] = {}
        # (bucket, n) -> jitted fused prefill+sample+insert (fused path)
        self._admit_cache: Dict[Tuple[int, int], Callable] = {}
        k, max_len, temp = scfg.sync_every, scfg.max_len, scfg.temperature

        def loop_fn(params, caches, pos, last, active, remaining, rng):
            return tfm.decode_loop(params, cfg, caches, pos, last, active,
                                   remaining, rng, k=k, max_len=max_len,
                                   temperature=temp)

        # donate caches/pos/last/active/remaining/rng: the K-step loop
        # aliases every state buffer instead of materializing a copy
        self.decode_loop = jax.jit(loop_fn, donate_argnums=(1, 2, 3, 4, 5, 6))

        def paged_loop_fn(params, bt, caches, pos, last, active, remaining,
                          rng):
            # per-step pool path: the Pallas decode kernel reads the block
            # pool directly, so there is no virtual cache to keep resident
            out, em, caches, pos, last, active, remaining, rng = \
                tfm.decode_loop(params, cfg, caches, pos, last, active,
                                remaining, rng, k=k, max_len=max_len,
                                temperature=temp, bt=bt)
            # pack tokens + emitted counts into one array so the host sync
            # is a single device fetch (liveness/positions/budget are
            # host-derivable from the emitted counts)
            packed = jnp.concatenate([out, em[:, None]], axis=1)
            return packed, bt, caches, pos, last, active, remaining, rng

        def paged_virt_loop_fn(params, virt, pos, last, active, remaining,
                               rng):
            # resident-virtual path with lazy writeback: the engine
            # gathered `virt` from the pool once (gather_virt) and keeps
            # it device-resident; a steady-state sync is EXACTLY the
            # dense loop on it — no pool, no block table, no scatter —
            # and the pool is brought current only when something needs
            # to read it (flush_fn at admit/fork/victim boundaries)
            out, em, virt, pos, last, active, remaining, rng = \
                tfm.decode_loop(params, cfg, virt, pos, last, active,
                                remaining, rng, k=k, max_len=max_len,
                                temperature=temp)
            packed = jnp.concatenate([out, em[:, None]], axis=1)
            return packed, virt, pos, last, active, remaining, rng

        # the virtual caches are donated AND passed through as an output:
        # they stay device-resident across syncs and jit re-specializes
        # per bucketed *width*, so decode attention spans the widest live
        # sequence's whole-wave budget instead of nb_max blocks.  On the
        # kernel path the block table rides the same donate-and-return
        # contract instead (the Pallas kernel reads the pool directly).
        if cfg.use_kernels:
            self.paged_decode_loop = jax.jit(
                paged_loop_fn, donate_argnums=(1, 2, 3, 4, 5, 6, 7))
        else:
            self.paged_decode_loop = jax.jit(
                paged_virt_loop_fn, donate_argnums=(1, 2, 3, 4, 5, 6))
        self.gather_virt = jax.jit(tfm.gather_paged_virtual)
        # (width,) -> jitted lazy-writeback flush: scatter rows
        # [start, stop) of the virtual caches into the pool, per-slot
        # clamped; width-bucketed so compiles stay bounded
        self._flush_cache: Dict[int, Callable] = {}

        # speculative decode rides the paged path only: greedy-only
        # (ServeConfig enforces temperature == 0) and never on row-coupled
        # (MoE) families, whose verify windows would cross-talk through
        # expert capacity
        self.spec = scfg.speculative and self.paged_ok \
            and not self.row_coupled

        def spec_loop_fn(params, virt, hist, pos, last, active, remaining,
                         rng):
            # lazy writeback: caches=None skips the in-loop pool scatter;
            # the engine flushes the resident virtual caches on demand
            (out, em, stats, _, virt, hist, pos, last, active, remaining,
             rng) = tfm.spec_decode_loop(
                 params, cfg, None, hist, pos, last, active, remaining,
                 rng, k=k, d=scfg.spec_draft, max_len=max_len, bt=None,
                 virt=virt)
            # stats ride as two extra broadcast columns so the host sync
            # stays a single device fetch even under speculation
            st = jnp.broadcast_to(stats[None, :], (out.shape[0], 2))
            packed = jnp.concatenate([out, em[:, None], st], axis=1)
            return (packed, virt, hist, pos, last, active, remaining, rng)

        if self.spec:
            self.spec_decode_loop = jax.jit(
                spec_loop_fn, donate_argnums=(1, 2, 3, 4, 5, 6, 7))
        # (bucket, n) -> jitted paged suffix-extend + sample + slot insert
        self._paged_admit_cache: Dict[Tuple[int, int], Callable] = {}

        def cow(caches, src, dst):
            """Copy-on-write: ``pool[dst[i]] = pool[src[i]]`` for every
            layer's K/V pool (donated).  Pad pairs are (0, 0) — a
            null-block self-copy; callers pad pair counts to powers of
            two so jit's shape specialization stays bounded."""
            return jax.tree_util.tree_map(
                lambda c: c.at[:, dst].set(c[:, src]), caches)

        self.cow = jax.jit(cow, donate_argnums=(0,))

        def kv_export(caches, ids):
            """Gather pool rows ``ids`` from every layer's K/V pool (the
            swap-out / migration serialization read).  NOT donated — the
            pool stays live; ``ids`` is padded to a power of two with the
            null block and the junk pad rows are sliced off host-side."""
            return jax.tree_util.tree_map(lambda c: c[:, ids], caches)

        self.kv_export = jax.jit(kv_export)

        def kv_import(caches, ids, rows):
            """Scatter serialized rows back into pool blocks ``ids`` (the
            swap-in / migration adopt write; donated).  Pad ids are the
            null block, which absorbs the pad rows' junk by design."""
            return jax.tree_util.tree_map(
                lambda c, r: c.at[:, ids].set(r.astype(c.dtype)),
                caches, rows)

        self.kv_import = jax.jit(kv_import, donate_argnums=(0,))

    def flush_fn(self, width: int) -> Callable:
        """Jitted lazy-writeback flush: write rows ``[start[s], stop[s])``
        of the resident virtual caches into the block pool (donated),
        null-redirecting each slot's junk tail past ``stop[s]``.  One
        compile per power-of-two pending width."""
        with self._build_lock:
            fn = self._flush_cache.get(width)
            if fn is None:
                def flush(caches, virt, bt, start, stop):
                    return tfm.scatter_paged_back(caches, virt, bt, start,
                                                  width, stop=stop)
                fn = jax.jit(flush, donate_argnums=(0,))
                self._flush_cache[width] = fn
        return fn

    def bucket(self, plen: int) -> int:
        """Prefill compile bucket for a prompt of length ``plen``."""
        if not (self.scfg.prefill_bucketing and self.pad_ok):
            return plen                       # exact-length path
        return min(max(_next_pow2(plen), self.scfg.min_bucket),
                   self.scfg.max_len)

    def admit_fn(self, bucket: int, n: int) -> Callable:
        """Jitted bucketed batch prefill: prefill ``n`` prompts padded to
        ``bucket`` in one call, sample their first tokens in-jit, and insert
        caches + per-slot state via donated ``dynamic_update_slice``."""
        key = (bucket, n)
        with self._build_lock:
            return self._admit_cache.get(key) or self._build_admit_fn(key)

    def _build_admit_fn(self, key: Tuple[int, int]) -> Callable:
        bucket, n = key
        cfg, scfg = self.cfg, self.scfg

        def fn(params, tokens, last_idx, slot_idx, budget,
               caches, pos, last, active, remaining, rng):
            """tokens (n,bucket) · last_idx/slot_idx/budget (n,) ·
            engine state donated; returns (first_tokens (n,), state...)."""
            small = api.init_caches(cfg, n, scfg.max_len)
            rng, sub = jax.random.split(rng)
            logits, small = tfm.prefill(params, cfg, tokens, small,
                                        last_index=last_idx)
            toks = tfm.sample_tokens(logits[:, 0], scfg.temperature, sub)
            for j in range(n):            # static unroll over admits
                s = slot_idx[j]
                caches = jax.tree_util.tree_map(
                    lambda b, sm: jax.lax.dynamic_update_slice_in_dim(
                        b, sm[:, j:j + 1].astype(b.dtype), s, axis=1),
                    caches, small)
                act_j = (budget[j] > 0) & (last_idx[j] + 1 < scfg.max_len - 1)
                pos = jax.lax.dynamic_update_index_in_dim(
                    pos, last_idx[j] + 1, s, 0)
                # an immediately-exhausted admit parks the slot on token 0,
                # the reference loop's zero-fill for empty slots
                last = jax.lax.dynamic_update_index_in_dim(
                    last, jnp.where(act_j, toks[j], 0), s, 0)
                remaining = jax.lax.dynamic_update_index_in_dim(
                    remaining, budget[j], s, 0)
                active = jax.lax.dynamic_update_index_in_dim(
                    active, act_j, s, 0)
            return toks, caches, pos, last, active, remaining, rng

        self._admit_cache[key] = jax.jit(
            fn, donate_argnums=(5, 6, 7, 8, 9, 10))
        return self._admit_cache[key]

    def paged_admit_fn(self, bucket: int, n: int) -> Callable:
        """Jitted paged admit: extend ``n`` sequences by their (padded)
        suffix tokens through their block tables, sample first tokens
        in-jit, and update the donated slot state."""
        key = (bucket, n)
        with self._build_lock:
            return self._paged_admit_cache.get(key) or \
                self._build_paged_admit_fn(key)

    def _build_paged_admit_fn(self, key: Tuple[int, int]) -> Callable:
        bucket, n = key
        cfg, scfg = self.cfg, self.scfg
        spec = self.spec

        def fn(params, tokens, meta, bt, virt,
               caches, hist, pos, last, active, remaining, rng):
            """tokens (n,bucket) suffix ids · meta (4,n) = [pos0
            cached-prefix length; last_idx suffix-local last index;
            slot_idx; budget] packed into one upload · bt (n, nb_max)
            block tables · engine state donated.  ``hist`` is the
            speculative draft's (slots, max_len) token history and
            ``virt`` the resident virtual caches — either may be None.
            The admitted slots' virtual rows are re-gathered in here
            (one dispatch, no extra uploads) so a steady-state admit
            never flushes or fully regathers the resident view."""
            pos0, last_idx, slot_idx, budget = (meta[j] for j in range(4))
            rng, sub = jax.random.split(rng)
            logits, caches = tfm.extend_paged(params, cfg, tokens, caches,
                                              pos0, bt, last_index=last_idx)
            toks = tfm.sample_tokens(logits[:, 0], scfg.temperature, sub)
            if virt is not None:
                vw = virt[0][0]["k"].shape[2] // scfg.block_size
                virt = tfm.refresh_paged_virtual(virt, caches,
                                                 bt[:, :vw], slot_idx)
            if spec:
                # seed the draft history with the suffix tokens at their
                # absolute positions.  Bucket pads land above the row's
                # position and are overwritten before any draft can read
                # them; positions below pos0 (a prefix-cache hit) keep the
                # slot's stale contents, which can only cost draft
                # acceptance, never correctness.
                idxs = pos0[:, None] + jnp.arange(bucket)[None, :]
                hist = hist.at[slot_idx[:, None], idxs].set(tokens,
                                                            mode="drop")
            for j in range(n):            # static unroll over admits
                s = slot_idx[j]
                nxt = pos0[j] + last_idx[j] + 1     # next write position
                act_j = (budget[j] > 0) & (nxt < scfg.max_len - 1)
                pos = jax.lax.dynamic_update_index_in_dim(pos, nxt, s, 0)
                last = jax.lax.dynamic_update_index_in_dim(
                    last, jnp.where(act_j, toks[j], 0), s, 0)
                remaining = jax.lax.dynamic_update_index_in_dim(
                    remaining, budget[j], s, 0)
                active = jax.lax.dynamic_update_index_in_dim(
                    active, act_j, s, 0)
                if spec:
                    hist = hist.at[s, nxt].set(toks[j], mode="drop")
            return toks, virt, caches, hist, pos, last, active, remaining, \
                rng

        # hist/virt may arrive as None (non-speculative engines; no
        # resident view yet) — an empty pytree, so donating it is a no-op
        # and jit re-traces once per presence combination
        jitted = jax.jit(fn, donate_argnums=(4, 5, 6, 7, 8, 9, 10, 11))
        self._paged_admit_cache[key] = jitted
        return self._paged_admit_cache[key]


    def prefill_fn(self, plen: int) -> Callable:
        """Exact-length batch-1 prefill (reference path, pre-PR shape)."""
        with self._build_lock:
            if plen not in self.prefill_cache:
                cfg, scfg = self.cfg, self.scfg

                def fn(params, tokens):
                    caches = api.init_caches(cfg, 1, scfg.max_len)
                    return tfm.prefill(params, cfg, tokens, caches)

                self.prefill_cache[plen] = jax.jit(fn)
            return self.prefill_cache[plen]


def make_engine_fns(cfg, scfg: ServeConfig) -> EngineFns:
    """Shared-jit bundle for an engine pool (see :class:`EngineFns`)."""
    return EngineFns(cfg, scfg)


class Engine:
    def __init__(self, params, cfg, scfg: ServeConfig,
                 metrics: Optional[MetricsRegistry] = None,
                 shared_fns: Optional[EngineFns] = None):
        self.params, self.cfg, self.scfg = params, cfg, scfg
        if cfg.family == "encdec":
            raise NotImplementedError("Engine serves decoder-LM families")
        self.fns = shared_fns if shared_fns is not None \
            else make_engine_fns(cfg, scfg)
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        # paged KV: only families whose whole cache is position-addressed
        # attention K/V can page; the rest (SSM/RG-LRU/MLA/ring) keep the
        # dense path — observable via `engine.paged` and the counter
        self.paged = scfg.paged and self.fns.paged_ok
        if scfg.paged and not self.fns.paged_ok:
            self.metrics.counter("engine.paged_fallback_dense").inc()
        if self.paged:
            bs = scfg.block_size
            self.nb_max = scfg.max_len // bs
            n_blocks = scfg.kv_blocks or scfg.slots * self.nb_max
            self.caches = tfm.init_paged_caches(cfg, n_blocks, bs)
            self.alloc = BlockAllocator(n_blocks, bs)
            self.alloc.on_evict = lambda bid: current_recorder().record(
                "kv_evict", block=bid)
            self._seq_of_slot: List[Optional[int]] = [None] * scfg.slots
            self._bt = np.zeros((scfg.slots, self.nb_max), np.int32)
            self._pos_h = np.zeros((scfg.slots,), np.int64)
            self._rem_h = np.zeros((scfg.slots,), np.int64)
            self._act_h = np.zeros((scfg.slots,), bool)
            # device-resident block table (donated through the decode loop
            # and passed back): host mutations set the dirty flag and the
            # next sync re-uploads, sliced to the bucketed width that
            # covers the longest live sequence
            self._bt_dev = None
            self._bt_width = 0
            self._bt_dirty = True
            # device-resident virtual caches (gather-hoisted dense view of
            # the live slots' blocks): reused across syncs; None forces a
            # regather — set on admit/fork/victim and on width change.
            # Writeback to the pool is LAZY: _wb_h[s] is the first
            # position not yet flushed; _flush_virt() makes the pool
            # authoritative before anything reads it
            self._virt = None
            self._virt_width = 0
            self._wb_h = np.zeros((scfg.slots,), np.int64)
            # swap_tier="artifact": lazily-built content-addressed store
            # for swapped block payloads (host tier carries bytes inline)
            self._swap_store = None
            self.metrics.gauge("engine.kv_blocks_total").set(n_blocks)
            self._kv_gauges()
        else:
            self.caches = api.init_caches(cfg, scfg.slots, scfg.max_len)
        # speculative decode: paged + greedy + row-decoupled only (the
        # fns bundle holds the gate); fall back silently but observably
        self.speculative = self.paged and self.fns.spec
        if scfg.speculative and not self.speculative:
            self.metrics.counter("engine.spec_fallback").inc()
        if self.speculative:
            # device token history feeding the n-gram draft: row s holds
            # the tokens of slot s's sequence at their absolute positions
            self._hist = jnp.zeros((scfg.slots, scfg.max_len), jnp.int32)
        self.active: List[Optional[Request]] = [None] * scfg.slots
        self.queue: Deque[Request] = deque()
        self.finished: List[Request] = []
        if scfg.fused:
            # device-resident loop state (donated through every fused call)
            self._pos = jnp.zeros((scfg.slots,), jnp.int32)
            self._last = jnp.zeros((scfg.slots,), jnp.int32)
            self._active = jnp.zeros((scfg.slots,), bool)
            self._remaining = jnp.zeros((scfg.slots,), jnp.int32)
            self._rng = jax.random.PRNGKey(scfg.seed)
        else:
            self.pos = np.zeros((scfg.slots,), np.int32)
        # monotonic request ids: never reused, regardless of how many
        # requests are queued/active/finished at submit time
        self._rids = itertools.count(1000)
        # flipped by the first submit carrying a deadline or cancel_cb;
        # keeps the per-step resilience sweep off the hot path otherwise
        self._watch_early = False

    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new: int,
               on_tokens: Optional[Callable] = None,
               trace_ctx: Any = None, priority: int = 0,
               deadline_s: Optional[float] = None,
               cancel_cb: Optional[Callable[[], bool]] = None) -> Request:
        req = Request(rid=next(self._rids),
                      prompt=np.asarray(prompt, np.int32), max_new=max_new,
                      submit_t=time.perf_counter(), on_tokens=on_tokens,
                      priority=priority, deadline_s=deadline_s,
                      cancel_cb=cancel_cb)
        if deadline_s is not None or cancel_cb is not None:
            self._watch_early = True
        if self.paged and self.scfg.prefix_cache:
            # sha256 prefix-chain hashing runs here — off the admit/step
            # critical path, and memoized across identical prompts
            req.block_hashes = hash_token_blocks_memo(
                req.prompt, self.scfg.block_size)
        # with a cluster context this parents into the request's trace;
        # standalone (trace_ctx None) it roots one, subject to sampling
        sp = current_tracer().span("engine.request", parent=trace_ctx,
                                   rid=req.rid, prompt_len=len(req.prompt),
                                   max_new=max_new)
        if sp.recording:
            req.trace_span = sp
            req.trace_ctx = sp.ctx
        self.queue.append(req)
        return req

    def _emit(self, req: Request, toks: List[int], done: bool):
        """Per-sync streaming callback; a throwing consumer must not take
        the engine (and every other slot's request) down with it."""
        if req.on_tokens is None:
            return
        try:
            req.on_tokens(req, list(toks), done)
        except Exception:
            self.metrics.counter("engine.stream_errors").inc()

    def _kv_gauges(self):
        self.metrics.gauge("engine.kv_blocks_free").set(
            self.alloc.free_blocks)
        self.metrics.gauge("engine.kv_blocks_cached").set(
            self.alloc.cached_blocks)

    def _close_span(self, req: Request):
        if req.trace_span is not None:
            req.trace_span.tag(finish=req.finish_reason,
                               decoded=req.decoded)
            req.trace_span.end()
            req.trace_span = None

    def _finish(self, slot: int, reason: str):
        req = self.active[slot]
        req.done = True
        req.finish_reason = reason
        req.done_t = time.perf_counter()
        self._close_span(req)
        self.finished.append(req)
        self.active[slot] = None
        if self.paged:
            # release the sequence's blocks (cached prefix blocks survive
            # via the prefix cache's own reference) and null the table row
            # so the still-running device loop can write nothing real
            sid = self._seq_of_slot[slot]
            if sid is not None:
                self.alloc.free_seq(sid)
                self._seq_of_slot[slot] = None
                self._bt[slot] = NULL_BLOCK
                # the freed blocks can be re-allocated to another slot in
                # this very sync — a stale device copy of this row would
                # let the frozen slot's masked writes land in the new
                # owner's blocks
                self._bt_dirty = True
                # drop the dead slot's pending writeback: its blocks are
                # freed, and a later flush must not inflate its width for
                # rows nobody can read (the nulled table row would drop
                # them anyway)
                self._wb_h[slot] = self._pos_h[slot]
            self._kv_gauges()
        self.metrics.counter("engine.requests").inc()
        self.metrics.counter("engine.tokens").inc(req.decoded)
        if reason == "max_len":
            self.metrics.counter("engine.truncated").inc()
        self.metrics.histogram("engine.ttft_s").observe(
            req.first_token_t - req.submit_t)
        self.metrics.histogram("engine.latency_s").observe(
            req.done_t - req.submit_t)

    # ------------------------------------------------------------------
    # fused path
    def _admit_fused(self):
        free = [s for s in range(self.scfg.slots) if self.active[s] is None]
        while free and self.queue:
            # longest same-bucket *prefix* of the queue (strict FIFO), up to
            # the number of free slots, prefilled as one padded batch
            bucket = self.fns.bucket(len(self.queue[0].prompt))
            batch = [self.queue.popleft()]
            # MoE rows couple through expert capacity: batch/pad admits
            # would diverge from the reference path's batch-1 prefill
            max_admit = 1 if self.fns.row_coupled else len(free)
            while self.queue and len(batch) < max_admit and \
                    self.fns.bucket(len(self.queue[0].prompt)) == bucket:
                batch.append(self.queue.popleft())
            n = len(batch)
            slots_idx, free = free[:n], free[n:]
            # pad the batch dimension up to a power of two so admit
            # compiles are bounded by |buckets| x log2(slots), not by every
            # batch size the queue happens to produce.  Pad rows duplicate
            # row 0 *and its slot* and come first, so the real rows' writes
            # (last in the unrolled insert) always win.
            n_pad = _next_pow2(n) if n > 1 else 1
            rows = [batch[0]] * (n_pad - n) + batch
            row_slots = np.asarray([slots_idx[0]] * (n_pad - n) + slots_idx,
                                   np.int32)
            tokens = np.zeros((n_pad, bucket), np.int32)
            last_idx = np.zeros((n_pad,), np.int32)
            budget = np.zeros((n_pad,), np.int32)
            for j, req in enumerate(rows):
                plen = len(req.prompt)
                tokens[j, :plen] = req.prompt
                last_idx[j] = plen - 1
                budget[j] = max(req.max_new, 0)
            asp = current_tracer().span(
                "engine.admit",
                parent=next((r.trace_ctx for r in batch
                             if r.trace_ctx is not None), None),
                bucket=bucket, n=n, n_pad=n_pad,
                rids=[r.rid for r in batch])
            current_recorder().record("admit", rids=[r.rid for r in batch],
                                      bucket=bucket, n=n)
            _qh = self.metrics.histogram("engine.queue_wait_s")
            _now = time.perf_counter()
            for r in batch:
                _qh.observe(_now - r.submit_t)
            # the prefill span brackets the jitted call *plus* the host
            # sync that realizes its tokens — tracing never reaches
            # inside jit, it measures the host-visible stage
            psp = current_tracer().span("engine.prefill", parent=asp,
                                        bucket=bucket, n_pad=n_pad)
            with annotate("prefill"):
                toks, self.caches, self._pos, self._last, self._active, \
                    self._remaining, self._rng = \
                    self.fns.admit_fn(bucket, n_pad)(
                        self.params, jnp.asarray(tokens),
                        jnp.asarray(last_idx),
                        jnp.asarray(row_slots), jnp.asarray(budget),
                        self.caches, self._pos, self._last,
                        self._active, self._remaining, self._rng)
                toks_h = np.asarray(toks)[n_pad - n:]
            psp.end()
            now = time.perf_counter()
            for j, req in enumerate(batch):
                req.out_tokens.append(int(toks_h[j]))
                req.first_token_t = now
                self.active[slots_idx[j]] = req
                if req.max_new <= 0:
                    self._finish(slots_idx[j], "max_new")
                elif len(req.prompt) >= self.scfg.max_len - 1:
                    self._finish(slots_idx[j], "max_len")
                self._emit(req, req.out_tokens[-1:], req.done)
            asp.end()
            self.metrics.counter("engine.prefill_batches").inc()

    def _batch_ctx(self):
        """Trace parent for a decode-sync span: the first traced active
        request (one span serves the whole shared batch)."""
        return next((r.trace_ctx for r in self.active
                     if r is not None and r.trace_ctx is not None), None)

    def _step_fused(self) -> bool:
        self._admit_fused()
        if not any(r is not None for r in self.active):
            return False
        dsp = current_tracer().span(
            "engine.decode_sync", parent=self._batch_ctx(),
            k=self.scfg.sync_every,
            n_active=sum(r is not None for r in self.active))
        with annotate("decode_loop"):
            out, emitted, self.caches, self._pos, self._last, self._active, \
                self._remaining, self._rng = self.fns.decode_loop(
                    self.params, self.caches, self._pos, self._last,
                    self._active, self._remaining, self._rng)
            # one host sync per K decode steps (sampling happened in-jit)
            hsp = current_tracer().span("engine.host_sync", parent=dsp)
            out_h = np.asarray(out)
            em_h = np.asarray(emitted)
            act_h = np.asarray(self._active)
            rem_h = np.asarray(self._remaining)
            hsp.end()
        esp = current_tracer().span("engine.stream_emit", parent=dsp) \
            if any(r is not None and r.on_tokens is not None
                   for r in self.active) else NULL_SPAN
        for s, req in enumerate(self.active):
            if req is None:
                continue
            new = [int(t) for t in out_h[s, :em_h[s]]]
            req.out_tokens.extend(new)
            if not act_h[s]:
                self._finish(s, "max_new" if rem_h[s] <= 0 else "max_len")
            self._emit(req, new, req.done)
        esp.end()
        dsp.end()
        self.metrics.counter("engine.steps").inc()
        return True

    # ------------------------------------------------------------------
    # paged path: same fused K-step loop, but K/V live in a shared block
    # pool addressed through per-slot block tables (serving/kvpool.py).
    # Admits prefill only the suffix a prefix-cache hit leaves uncovered;
    # block allocation / COW / freeing are host decisions executed on
    # device between syncs.
    def _prep_paged(self, req: Request):
        """Plan one admit without side effects: prefix hits, suffix shape,
        and the block headroom it would need.  None == cannot admit now."""
        bs = self.scfg.block_size
        plen = len(req.prompt)
        if not self.scfg.prefix_cache:
            hashes: List[bytes] = []
        elif req.block_hashes is not None:      # hashed at submit()
            hashes = req.block_hashes
        else:                                   # forked/hand-built request
            hashes = hash_token_blocks_memo(req.prompt, bs)
        # reuse covers at most plen-1 tokens: the last prompt token must be
        # recomputed so the admit has logits to sample the first output
        reusable = hashes[:max(plen - 1, 0) // bs]
        hits = self.alloc.prefix_lookup(reusable)
        n_cached_tok = len(hits) * bs
        need = -(-plen // bs) - len(hits) + 1      # +1 decode-ahead block
        if need > self.alloc.num_blocks:
            # would defer forever: the whole pool cannot hold this prompt
            raise _PromptTooLong(
                f"prompt of {plen} tokens needs {need} KV blocks but the "
                f"pool has only {self.alloc.num_blocks}: raise kv_blocks "
                f"or shorten the prompt")
        if need > self.alloc.available_excluding(hits):
            return None
        return (hashes, hits, n_cached_tok, plen - n_cached_tok)

    def _reject_oversized(self, req: Request, detail: str):
        """Fail just the unservable request — never the batch it queued
        with.  It completes empty with an explicit finish reason instead
        of raising out of ``step()`` (where a replica loop would spill
        the whole in-flight batch and re-route the poison request into
        the next replica)."""
        req.done = True
        req.finish_reason = "rejected_prompt_too_long"
        req.done_t = req.first_token_t = time.perf_counter()
        self._close_span(req)
        self.finished.append(req)
        self.metrics.counter("engine.rejected_too_long").inc()
        self._emit(req, [], True)

    def _admit_paged(self):
        scfg = self.scfg
        free = [s for s in range(scfg.slots) if self.active[s] is None]
        while free and self.queue:
            # NO flush here, by construction: admission only reads
            # *published* prefix blocks (immutable once published — decode
            # writes COW first) and only binds *free* blocks, while every
            # lazily-pending virtual row targets a live slot's private
            # block (fork/victim flush before sharing or freeing, and
            # _finish resets a dead slot's watermark) — so the pool is
            # authoritative for everything an admit can touch
            if self.queue[0].kv_snapshot is not None:
                # a preempted session resumes by block import, never by
                # re-prefill; deferring it keeps FIFO (nothing behind it
                # may overtake the resume)
                if self._try_restore(free):
                    continue
                self.metrics.counter("engine.admit_deferred_kv").inc()
                break
            try:
                prep = self._prep_paged(self.queue[0])
            except _PromptTooLong as e:
                self._reject_oversized(self.queue.popleft(), str(e))
                continue
            if prep is None:
                # pool pressure: leave the queue intact — admission
                # headroom gating upstream keeps this rare
                self.metrics.counter("engine.admit_deferred_kv").inc()
                break
            bucket = self.fns.bucket(prep[3])
            max_admit = 1 if self.fns.row_coupled else len(free)
            # pop-and-commit one request at a time so each headroom probe
            # sees the blocks its batch-mates already claimed
            rows = []
            while prep is not None and len(rows) < max_admit and \
                    self.fns.bucket(prep[3]) == bucket:
                req = self.queue.popleft()
                hashes, hits, n_cached_tok, suffix_len = prep
                plen = len(req.prompt)
                slot = free[len(rows)]
                sid = self.alloc.new_seq()
                self.alloc.append_shared(sid, hits)
                self.alloc.extend_to(sid, plen)
                self._seq_of_slot[slot] = sid
                self._bt[slot] = padded_table(self.alloc.table(sid),
                                              self.nb_max)
                self._bt_dirty = True
                self._pos_h[slot] = plen
                self._wb_h[slot] = plen   # nothing pending: admit writes pool
                self._rem_h[slot] = max(req.max_new, 0)
                self._act_h[slot] = req.max_new > 0 and \
                    plen < scfg.max_len - 1
                self.metrics.counter("engine.prefix_hit_blocks").inc(
                    len(hits))
                # denominator of the hit rate: count the blocks actually
                # *looked up* (reuse is capped at plen-1 tokens), not the
                # prompt's full-block count — else a block-aligned prompt
                # could never reach hit_rate 1.0
                self.metrics.counter("engine.prefix_lookup_blocks").inc(
                    max(plen - 1, 0) // self.scfg.block_size)
                self.metrics.counter("engine.prefill_tokens_saved").inc(
                    n_cached_tok)
                rows.append((req, slot, sid, hashes, n_cached_tok,
                             suffix_len))
                try:
                    # a snapshot-carrying head never joins a prefill
                    # batch — the outer loop restores it via block import
                    prep = self._prep_paged(self.queue[0]) \
                        if self.queue and \
                        self.queue[0].kv_snapshot is None else None
                except _PromptTooLong:
                    # oversized next prompt: stop batching here; the head
                    # of the next admit loop rejects it individually,
                    # after this batch's extend has run
                    prep = None
            n = len(rows)
            free = free[n:]
            # pad the batch dim to a power of two (same compile-bounding
            # trick as the dense admit); pad rows duplicate row 0 and its
            # slot/table — identical values to identical addresses
            n_pad = _next_pow2(n) if n > 1 else 1
            full = [rows[0]] * (n_pad - n) + rows
            tokens = np.zeros((n_pad, bucket), np.int32)
            pos0 = np.zeros((n_pad,), np.int32)
            last_idx = np.zeros((n_pad,), np.int32)
            slot_arr = np.zeros((n_pad,), np.int32)
            budget = np.zeros((n_pad,), np.int32)
            bt = np.zeros((n_pad, self.nb_max), np.int32)
            for j, (req, slot, sid, hashes, n_cached_tok, suffix_len) in \
                    enumerate(full):
                tokens[j, :suffix_len] = req.prompt[n_cached_tok:]
                pos0[j] = n_cached_tok
                last_idx[j] = suffix_len - 1
                slot_arr[j] = slot
                budget[j] = max(req.max_new, 0)
                bt[j] = self._bt[slot]
            hit_toks = sum(r[4] for r in rows)
            asp = current_tracer().span(
                "engine.admit",
                parent=next((r[0].trace_ctx for r in rows
                             if r[0].trace_ctx is not None), None),
                bucket=bucket, n=n, n_pad=n_pad,
                rids=[r[0].rid for r in rows],
                prefix_hit_tokens=hit_toks,
                kv_blocks_free=self.alloc.free_blocks)
            current_recorder().record(
                "admit", rids=[r[0].rid for r in rows], bucket=bucket,
                n=n, prefix_hit_tokens=hit_toks)
            _qh = self.metrics.histogram("engine.queue_wait_s")
            _now = time.perf_counter()
            for r in rows:
                _qh.observe(_now - r[0].submit_t)
            psp = current_tracer().span("engine.prefill", parent=asp,
                                        bucket=bucket, n_pad=n_pad)
            with annotate("prefill"):
                # one packed (4, n_pad) upload for the per-row int vectors
                # — host->device dispatches dominate the admit wall here.
                # The jit also re-gathers the admitted slots' rows of the
                # resident view in the same call (other slots' lazily-
                # pending rows must NOT be re-read from the pool); a
                # prompt wider than the resident view is fine — decode's
                # width check (need > width) forces a flush + full
                # regather before any truncated row could be read.
                meta = np.stack([pos0, last_idx, slot_arr, budget])
                toks, self._virt, self.caches, hist, self._pos, \
                    self._last, self._active, self._remaining, self._rng = \
                    self.fns.paged_admit_fn(bucket, n_pad)(
                        self.params, jnp.asarray(tokens),
                        jnp.asarray(meta), jnp.asarray(bt), self._virt,
                        self.caches,
                        self._hist if self.speculative else None,
                        self._pos, self._last, self._active,
                        self._remaining, self._rng)
                if self.speculative:
                    self._hist = hist
                toks_h = np.asarray(toks)[n_pad - n:]
            psp.end()
            now = time.perf_counter()
            for j, (req, slot, sid, hashes, n_cached_tok, suffix_len) in \
                    enumerate(rows):
                plen = len(req.prompt)
                if self.speculative and n_cached_tok:
                    # a prefix-cache hit skips the admit extend for the
                    # cached tokens, so the in-jit history seeding never
                    # sees them — backfill host-side (admits are rare;
                    # this keeps the n-gram draft sighted over the whole
                    # context instead of just the uncached suffix)
                    self._hist = self._hist.at[slot, :n_cached_tok].set(
                        jnp.asarray(req.prompt[:n_cached_tok], jnp.int32))
                if scfg.prefix_cache:
                    # every *full* prompt block is now written and
                    # immutable (decode writes start at plen) — publish it
                    n_full = plen // scfg.block_size
                    self.alloc.prefix_insert(hashes[:n_full],
                                             self.alloc.table(sid)[:n_full])
                req.out_tokens.append(int(toks_h[j]))
                req.first_token_t = now
                self.active[slot] = req
                if req.max_new <= 0:
                    self._finish(slot, "max_new")
                elif plen >= scfg.max_len - 1:
                    self._finish(slot, "max_len")
                self._emit(req, req.out_tokens[-1:], req.done)
            asp.end()
            self.metrics.counter("engine.prefill_batches").inc()
            self._kv_gauges()

    def _flush_virt(self):
        """Lazy-writeback flush: scatter every virtual-cache row decoded
        since the last flush into the block pool, making the pool
        authoritative again.  Steady-state syncs skip the per-sync
        scatter entirely; this runs only when something needs to read the
        pool — an admit's regather, a fork, a pool-exhausted victim, or
        an explicit :meth:`flush_kv`.  Each slot is clamped to its own
        written range (``stop``), and finished slots' rows null-redirect
        through their nulled table rows."""
        if self._virt is None:
            self._wb_h[:] = self._pos_h
            return
        pend = int(np.max(self._pos_h - self._wb_h))
        if pend <= 0:
            return
        # the device table must be current for the flushed rows: _finish
        # nulls dead rows and appends bind fresh blocks, both set dirty
        if self._bt_dirty or self._bt_width != self._virt_width:
            self._bt_dev = jnp.asarray(self._bt[:, :self._virt_width])
            self._bt_width = self._virt_width
            self._bt_dirty = False
        width = _next_pow2(pend) if pend > 1 else 1
        self.caches = self.fns.flush_fn(width)(
            self.caches, self._virt, self._bt_dev,
            jnp.asarray(self._wb_h.astype(np.int32)),
            jnp.asarray(self._pos_h.astype(np.int32)))
        self._wb_h[:] = self._pos_h

    def flush_kv(self):
        """Make the block pool authoritative for every live sequence (the
        resident virtual caches are flushed; a no-op on dense or kernel
        paths).  Anything that reads KV content from ``engine.caches``
        directly — tests, future block swap/migration — must call this
        first."""
        if self.paged:
            self._flush_virt()

    def _exhaust_victim(self, slot: int):
        """PoolExhausted mid-decode: complete this slot's request with
        ``finish_reason="kv_pool_exhausted"`` and free its blocks (the
        single-victim contract, like ``rejected_prompt_too_long``) instead
        of raising out of ``step()`` and poisoning its batch-mates — the
        freed blocks can satisfy later slots in this very sync."""
        req = self.active[slot]
        self.metrics.counter("engine.kv_pool_exhausted").inc()
        current_recorder().record("kv_pool_exhausted", rid=req.rid,
                                  slot=slot, pos=int(self._pos_h[slot]))
        self._active = self._active.at[slot].set(False)
        self._last = self._last.at[slot].set(0)
        self._act_h[slot] = False
        # flush BEFORE the free: the other slots' pending rows must reach
        # the pool while every table row still maps to its true owner
        self._flush_virt()
        self._virt = None
        self._finish(slot, "kv_pool_exhausted")
        self._emit(req, [], True)

    # ------------------------------------------------------------------
    # resilience: deadline expiry + cancellation.  Both terminate a
    # session early at the next step boundary — "within one sync" — with
    # the single-victim contract of _exhaust_victim: the rest of the
    # batch keeps decoding, the victim's KV frees immediately.
    @staticmethod
    def _early_reason(req: Request, now: float) -> Optional[str]:
        if req.cancel_cb is not None:
            try:
                if req.cancel_cb():
                    return "cancelled"
            except Exception:           # noqa: BLE001 - poller's bug
                pass                    # a broken poller must not kill step()
        if req.deadline_s is not None and now > req.deadline_s:
            return "deadline"
        return None

    def _finish_early(self, slot: int, reason: str):
        """End an *active* slot mid-decode with ``reason``; on the paged
        path this frees its blocks inside the current sync (flush first,
        exactly like :meth:`_exhaust_victim`, so surviving slots' pending
        rows reach the pool while the table still maps every owner)."""
        req = self.active[slot]
        if self.scfg.fused:
            self._active = self._active.at[slot].set(False)
            self._last = self._last.at[slot].set(0)
        if self.paged:
            self._act_h[slot] = False
            self._flush_virt()
            self._virt = None
        self._finish(slot, reason)
        self._emit(req, [], True)

    def _sweep_expired(self):
        """Per-step resilience sweep: complete queued work that is already
        pointless (expired in queue / cancelled before admit) without it
        ever taking a slot, then end active sessions whose deadline passed
        or whose submitter cancelled."""
        now = time.monotonic()
        if self.queue:
            keep: Deque[Request] = deque()
            for req in self.queue:
                reason = self._early_reason(req, now)
                if reason is None:
                    keep.append(req)
                    continue
                req.done = True
                req.finish_reason = reason
                req.done_t = req.first_token_t = time.perf_counter()
                self._close_span(req)
                self.finished.append(req)
                self.metrics.counter(
                    "engine.cancelled" if reason == "cancelled"
                    else "engine.deadline_expired").inc()
                current_recorder().record(
                    reason if reason == "cancelled" else "deadline_expired",
                    rid=req.rid, where="engine_queue")
                self._emit(req, [], True)
            self.queue = keep
        for s, req in enumerate(self.active):
            if req is None:
                continue
            reason = self._early_reason(req, now)
            if reason is None:
                continue
            self.metrics.counter(
                "engine.cancelled" if reason == "cancelled"
                else "engine.deadline_expired").inc()
            current_recorder().record(
                reason if reason == "cancelled" else "deadline_expired",
                rid=req.rid, where="mid_decode",
                decoded=req.decoded)
            self._finish_early(s, reason)

    # ------------------------------------------------------------------
    # KV lifecycle: preemption + host/artifact swap (ServeConfig.kv_swap)
    # and warm migration export/import (cluster drain path).  Both ride
    # the same serialization primitives: pin the blocks, flush the
    # resident view so the pool is authoritative, gather the rows in one
    # jitted call, and pack them with kvpool.pack_block_arrays.
    def _swap_payload_store(self):
        if self._swap_store is None:
            # deferred import: artifacts -> backends -> engine is a cycle
            # at module scope
            from repro.cluster.artifacts import ArtifactStore
            self._swap_store = ArtifactStore()
        return self._swap_store

    def _gather_block_rows(self, blocks: List[int]) -> bytes:
        """Serialize pool rows ``blocks`` (caller flushed + pinned)."""
        ids = np.asarray(blocks, np.int32)
        n_pad = _next_pow2(len(ids)) if len(ids) > 1 else 1
        ids_p = np.full((n_pad,), NULL_BLOCK, np.int32)
        ids_p[:len(ids)] = ids
        rows = self.fns.kv_export(self.caches, jnp.asarray(ids_p))
        arrays = [np.asarray(leaf)[:, :len(ids)]
                  for leaf in jax.tree_util.tree_leaves(rows)]
        return pack_block_arrays(arrays)

    def _scatter_block_rows(self, blocks: List[int], arrays) -> None:
        """Write serialized rows (one array per cache leaf, block axis 1)
        into pool blocks ``blocks``."""
        ids = np.asarray(blocks, np.int32)
        n_pad = _next_pow2(len(ids)) if len(ids) > 1 else 1
        ids_p = np.full((n_pad,), NULL_BLOCK, np.int32)
        ids_p[:len(ids)] = ids
        padded = []
        for a in arrays:
            if n_pad > a.shape[1]:
                fill = np.zeros(a.shape[:1] + (n_pad - a.shape[1],)
                                + a.shape[2:], a.dtype)
                a = np.concatenate([a, fill], axis=1)
            padded.append(jnp.asarray(a))
        rows = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(self.caches), padded)
        self.caches = self.fns.kv_import(self.caches, jnp.asarray(ids_p),
                                         rows)

    def _wave_hi(self, s: int, adv: int, d: int) -> int:
        """Highest position (exclusive) slot ``s`` can write this sync."""
        scfg = self.scfg
        lo = int(self._pos_h[s])
        hi = min(lo + min(adv, int(self._rem_h[s])), scfg.max_len)
        if d:
            # the last verify window scatters up to d+1 rows past the
            # final emitted position
            hi = min(min(lo + min(adv, int(self._rem_h[s])),
                         scfg.max_len - 1) + d + 1, scfg.max_len)
        return hi

    def _swap_demand(self, s: int, adv: int, d: int) -> int:
        """Blocks slot ``s`` will claim this sync: fresh allocations plus
        COW copies of shared blocks in its write range."""
        bs = self.scfg.block_size
        sid = self._seq_of_slot[s]
        lo = int(self._pos_h[s])
        hi = self._wave_hi(s, adv, d)
        table = self.alloc.table(sid)
        fresh = max(-(-hi // bs) - len(table), 0)
        shared = sum(1 for j in range(lo // bs, min(-(-hi // bs),
                                                    len(table)))
                     if self.alloc.refcount(table[j]) > 1)
        return fresh + shared

    def _swap_out(self, slot: int):
        """Preempt slot ``slot``: serialize its blocks off-device, free
        them, and requeue the request at the queue FRONT carrying a
        :class:`SessionSnapshot` — it resumes ahead of never-admitted
        requests as soon as headroom returns.  The flush runs while the
        victim's table is untouched, so the export reads exactly the rows
        decode wrote."""
        req = self.active[slot]
        sid = self._seq_of_slot[slot]
        self._flush_virt()
        pos = int(self._pos_h[slot])
        table = self.alloc.table(sid)
        blocks = table[:-(-pos // self.scfg.block_size)] if pos else []
        snap = SessionSnapshot(
            pos=pos, rem=int(self._rem_h[slot]),
            last_tok=req.out_tokens[-1] if req.out_tokens else 0,
            n_blocks=len(blocks))
        if blocks:
            self.alloc.pin(blocks)
            try:
                data = self._gather_block_rows(blocks)
            finally:
                self.alloc.unpin(blocks)
            if self.scfg.swap_tier == "artifact":
                snap.digest = self._swap_payload_store().put_bytes(data)
            else:
                snap.data = data
        req.kv_snapshot = snap
        self.queue.appendleft(req)
        self.active[slot] = None
        self.alloc.free_seq(sid)
        self._seq_of_slot[slot] = None
        self._bt[slot] = NULL_BLOCK
        self._bt_dirty = True
        self._wb_h[slot] = self._pos_h[slot]
        self._act_h[slot] = False
        self._active = self._active.at[slot].set(False)
        self._last = self._last.at[slot].set(0)
        # the freed blocks can be rebound this very sync — regather so no
        # stale resident row aliases the new owner's content
        self._virt = None
        self.metrics.counter("engine.kv_swap_out").inc()
        self.metrics.counter("engine.kv_swapped_blocks").inc(len(blocks))
        current_recorder().record("kv_swap_out", rid=req.rid, slot=slot,
                                  pos=pos, blocks=len(blocks))
        self._kv_gauges()

    def _preempt_for_headroom(self, adv: int, d: int):
        """Swap-out preflight: while this wave's worst-case block demand
        exceeds the pool, preempt the lowest-``(priority, -rid)`` active
        session (lowest priority class first; ties toward the newest
        request, which has the least decode work to lose).  Runs BEFORE
        cow_targets/extend_to mutate any table, so exports always see
        consistent tables and no COW pair can reference a freed block.  A
        lone survivor is never preempted — if it still cannot fit, the
        existing ``_exhaust_victim`` fallback applies."""
        while True:
            live = [(r.priority, -r.rid, s)
                    for s, r in enumerate(self.active) if r is not None]
            if len(live) <= 1:
                return
            demand = sum(self._swap_demand(s, adv, d) for _, _, s in live)
            if demand <= self.alloc.available_blocks:
                return
            live.sort()
            self._swap_out(live[0][2])

    def _try_restore(self, free: List[int]) -> bool:
        """Queue head is a swapped-out session: re-admit it by importing
        its serialized blocks instead of prefilling.  True = handled
        (restored into a slot, or finished as unrestorable); False =
        deferred on pool pressure with the queue left intact — FIFO
        holds, so the preempted session resumes before anything behind
        it."""
        req = self.queue[0]
        snap = req.kv_snapshot
        need = snap.n_blocks + 1            # +1 decode-ahead block
        if need > self.alloc.num_blocks:
            # no future state of this pool can restore it: complete
            # explicitly (the single-victim contract)
            self.queue.popleft()
            req.kv_snapshot = None
            self.metrics.counter("engine.kv_pool_exhausted").inc()
            current_recorder().record("kv_pool_exhausted", rid=req.rid,
                                      pos=snap.pos, at="restore")
            req.done = True
            req.finish_reason = "kv_pool_exhausted"
            req.done_t = time.perf_counter()
            self._close_span(req)
            self.finished.append(req)
            self._emit(req, [], True)
            return True
        if need > self.alloc.available_blocks:
            return False
        slot = free.pop(0)
        self.queue.popleft()
        # survivors may hold lazily-pending decode rows that exist only in
        # the resident view; the restore invalidates that view below, so
        # flush them into the pool first or they would be silently dropped
        self._flush_virt()
        data = snap.data if snap.data is not None \
            else self._swap_payload_store().read_bytes(snap.digest)
        sid = self.alloc.new_seq()
        self.alloc.extend_to(sid, snap.pos)
        table = self.alloc.table(sid)
        if snap.n_blocks:
            self._scatter_block_rows(table, unpack_block_arrays(data))
        self._seq_of_slot[slot] = sid
        self._bt[slot] = padded_table(table, self.nb_max)
        self._bt_dirty = True
        # the pool now holds the restored rows; regather before decoding
        self._virt = None
        pos = snap.pos
        self._pos_h[slot] = pos
        self._wb_h[slot] = pos
        self._rem_h[slot] = snap.rem
        alive = snap.rem > 0 and pos < self.scfg.max_len - 1
        self._act_h[slot] = alive
        self._pos = self._pos.at[slot].set(pos)
        self._last = self._last.at[slot].set(snap.last_tok if alive else 0)
        self._remaining = self._remaining.at[slot].set(max(snap.rem, 0))
        self._active = self._active.at[slot].set(alive)
        if self.speculative:
            # rebuild the draft history at absolute positions: prompt,
            # then every token emitted so far (hist[pos] == last_tok)
            toks = np.concatenate(
                [req.prompt, np.asarray(req.out_tokens, np.int32)]
            )[:self.scfg.max_len]
            self._hist = self._hist.at[slot, :len(toks)].set(
                jnp.asarray(toks, jnp.int32))
        self.active[slot] = req
        req.kv_snapshot = None
        self.metrics.counter("engine.kv_swap_in").inc()
        current_recorder().record("kv_swap_in", rid=req.rid, slot=slot,
                                  pos=pos, blocks=snap.n_blocks)
        if not alive:
            self._finish(slot, "max_new" if snap.rem <= 0 else "max_len")
        self._kv_gauges()
        return True

    # ------------------------------------------------------------------
    # warm migration: drain-time hand-off of the prefix cache's published
    # blocks to a session's new rendezvous home (cluster/router.py ships
    # the frame; cluster/replica.py calls these between batches)
    def export_kv_state(self) -> Optional[dict]:
        """Serialize the prefix cache — ``(chained hash, block rows)`` in
        LRU order — as one picklable frame, or None when there is nothing
        to ship (dense engine / empty cache).  Published blocks are
        immutable (decode COWs before writing), so the export needs no
        quiesce beyond a flush; pins keep eviction away mid-gather."""
        if not self.paged:
            return None
        items = self.alloc.prefix_items()
        if not items:
            return None
        self.flush_kv()
        blocks = [b for _, b in items]
        self.alloc.pin(blocks)
        try:
            data = self._gather_block_rows(blocks)
        finally:
            self.alloc.unpin(blocks)
        self.metrics.counter("engine.kv_export_blocks").inc(len(blocks))
        current_recorder().record("kv_export", blocks=len(blocks))
        return {"kind": "kv_blocks", "block_size": self.scfg.block_size,
                "hashes": [h for h, _ in items], "data": data}

    def import_kv_state(self, state) -> int:
        """Adopt a migrated replica's prefix blocks: every unseen hash
        binds a *free* block (never evicting — adopted entries arrive
        evictable, so admission headroom never shrinks) and the shipped
        rows are scattered in with one jitted call.  Idempotent: already
        cached hashes are skipped, so at-least-once delivery is safe.
        Returns the number of adopted blocks."""
        if not self.paged or not isinstance(state, dict) \
                or state.get("kind") != "kv_blocks" \
                or state.get("block_size") != self.scfg.block_size:
            return 0
        arrays = unpack_block_arrays(state["data"])
        ids: List[int] = []
        cols: List[int] = []
        for i, h in enumerate(state["hashes"]):
            b = self.alloc.import_cached(h)
            if b is None:
                continue
            ids.append(b)
            cols.append(i)
        if not ids:
            return 0
        sel = np.asarray(cols, np.intp)
        self._scatter_block_rows(ids, [a[:, sel] for a in arrays])
        self.metrics.counter("engine.kv_import_blocks").inc(len(ids))
        current_recorder().record("kv_import", blocks=len(ids))
        self._kv_gauges()
        return len(ids)

    def _step_paged(self) -> bool:
        self._admit_paged()
        if not any(r is not None for r in self.active):
            return False
        scfg = self.scfg
        d = scfg.spec_draft if self.speculative else 0
        adv = scfg.sync_every * (d + 1)   # max emissions in one sync
        dsp = current_tracer().span(
            "engine.decode_sync", parent=self._batch_ctx(),
            k=scfg.sync_every,
            n_active=sum(r is not None for r in self.active))
        if scfg.kv_swap:
            # swap preflight: make room by preempting whole sessions
            # BEFORE any table mutates below, so swap-outs export
            # consistent tables and never strand a COW pair
            self._preempt_for_headroom(adv, d)
        # host pre-work: every active slot needs writable private blocks
        # covering every position this loop can write — allocate ahead,
        # COW any block shared with the prefix cache or a fork.  Under
        # speculation the last verify window scatters up to d+1 rows past
        # the final emitted position, so cover (but never allocate past
        # max_len) those too.
        cow_src: List[int] = []
        cow_dst: List[int] = []
        max_hi = 1
        for s, req in enumerate(self.active):
            if req is None:
                continue
            sid = self._seq_of_slot[s]
            lo = int(self._pos_h[s])
            hi = self._wave_hi(s, adv, d)
            pairs = self.alloc.cow_targets(sid, lo, hi)
            try:
                fresh = self.alloc.extend_to(sid, hi)
            except PoolExhausted:
                # the victim's COW pairs are dropped: its sequence is
                # freed, so mirroring them on device could race the very
                # allocations its freed blocks now satisfy
                self._exhaust_victim(s)
                continue
            cow_src += [p[0] for p in pairs]
            cow_dst += [p[1] for p in pairs]
            if pairs or fresh:
                self._bt[s] = padded_table(self.alloc.table(sid),
                                           self.nb_max)
                self._bt_dirty = True
            max_hi = max(max_hi, hi)
        if not any(r is not None for r in self.active):
            dsp.end()
            return True
        if cow_src:
            pad = (_next_pow2(len(cow_src)) if len(cow_src) > 1 else 1) \
                - len(cow_src)
            src = jnp.asarray([0] * pad + cow_src, jnp.int32)
            dst = jnp.asarray([0] * pad + cow_dst, jnp.int32)
            self.caches = self.fns.cow(self.caches, src, dst)
            self.metrics.counter("engine.kv_cow_copies").inc(len(cow_src))
            dsp.tag(cow_copies=len(cow_src))
            current_recorder().record("cow", n=len(cow_src))
        # resident virtual caches with lazy writeback: a steady-state sync
        # is ONE jit call (the dense loop on the resident view) — no pool
        # scatter, no block-table upload, no gather.  The width bucket
        # covers every position this WAVE can ever write (pos + remaining
        # budget), so the view stays width-stable across block-boundary
        # crossings — and across admits too, since the admit jit
        # refreshes its own slots' rows in place; a regather
        # (invalidation or width growth) flushes pending rows first so
        # the pool it reads is authoritative.  The kernel path instead re-cuts the device
        # table to the tighter per-sync bound (the Pallas kernel re-reads
        # the pool every step; width only sets how many blocks the grid
        # walks).
        use_virt = self.speculative or not self.cfg.use_kernels
        if use_virt:
            need = 1
            for s, req in enumerate(self.active):
                if req is None:
                    continue
                fin = min(int(self._pos_h[s]) + int(self._rem_h[s]) + d + 1,
                          scfg.max_len)
                need = max(need, -(-fin // scfg.block_size))
            nbw = 1
            while nbw < need:
                nbw *= 2
            nbw = min(nbw, self.nb_max)
            if self._virt is not None and self._virt_width > nbw:
                # a wider resident cache is still valid (extra columns are
                # all >= pos, junk-tolerant) — keep it rather than regather
                nbw = self._virt_width
            if self._virt is None or self._virt_width != nbw:
                self._flush_virt()
                if self._bt_dirty or nbw != self._bt_width:
                    self._bt_dev = jnp.asarray(self._bt[:, :nbw])
                    self._bt_width = nbw
                    self._bt_dirty = False
                self._virt = self.fns.gather_virt(self.caches,
                                                  self._bt_dev)
                self._virt_width = nbw
                self._wb_h[:] = self._pos_h
        else:
            need = -(-max_hi // scfg.block_size)
            nbw = 1
            while nbw < need:
                nbw *= 2
            nbw = min(nbw, self.nb_max)
            if self._bt_dirty or nbw != self._bt_width:
                self._bt_dev = jnp.asarray(self._bt[:, :nbw])
                self._bt_width = nbw
                self._bt_dirty = False
        with annotate("decode_loop"):
            if self.speculative:
                ssp = current_tracer().span("engine.spec_decode",
                                            parent=dsp, draft_len=d)
                packed, self._virt, self._hist, self._pos, self._last, \
                    self._active, self._remaining, self._rng = \
                    self.fns.spec_decode_loop(
                        self.params, self._virt, self._hist, self._pos,
                        self._last, self._active, self._remaining,
                        self._rng)
            elif use_virt:
                packed, self._virt, self._pos, self._last, self._active, \
                    self._remaining, self._rng = self.fns.paged_decode_loop(
                        self.params, self._virt, self._pos, self._last,
                        self._active, self._remaining, self._rng)
            else:
                packed, self._bt_dev, self.caches, self._pos, self._last, \
                    self._active, self._remaining, self._rng = \
                    self.fns.paged_decode_loop(
                        self.params, self._bt_dev, self.caches, self._pos,
                        self._last, self._active, self._remaining,
                        self._rng)
            hsp = current_tracer().span("engine.host_sync", parent=dsp)
            # ONE device fetch: [tokens | emitted]; liveness, positions and
            # budgets advance host-side by exactly the emitted counts
            packed_h = np.asarray(packed)
            if self.speculative:
                out_h, em_h = packed_h[:, :-3], packed_h[:, -3]
            else:
                out_h, em_h = packed_h[:, :-1], packed_h[:, -1]
            self._pos_h += em_h.astype(np.int64)
            self._rem_h -= em_h.astype(np.int64)
            self._act_h &= (self._rem_h > 0) & \
                (self._pos_h < scfg.max_len - 1)
            if self.speculative:
                acc, prop = int(packed_h[0, -2]), int(packed_h[0, -1])
                self.metrics.counter("engine.spec_proposed").inc(prop)
                self.metrics.counter("engine.spec_accepted").inc(acc)
                ssp.tag(proposed=prop, accepted=acc)
                ssp.end()
            hsp.end()
        esp = current_tracer().span("engine.stream_emit", parent=dsp) \
            if any(r is not None and r.on_tokens is not None
                   for r in self.active) else NULL_SPAN
        for s, req in enumerate(self.active):
            if req is None:
                continue
            new = [int(t) for t in out_h[s, :em_h[s]]]
            req.out_tokens.extend(new)
            if not self._act_h[s]:
                self._finish(s, "max_new" if self._rem_h[s] <= 0
                             else "max_len")
            self._emit(req, new, req.done)
        esp.end()
        dsp.end()
        self.metrics.counter("engine.steps").inc()
        return True

    def fork(self, parent: Request, max_new: int,
             on_tokens: Optional[Callable] = None) -> Request:
        """Branch an *active* request into a new session that shares all
        of its KV blocks copy-on-write (parallel sampling / n-best).  The
        child continues from the parent's current position; its blocks
        stay shared until either side writes (then `cow_targets` splits
        exactly the written block).  Paged engines only; needs a free
        slot."""
        if not self.paged:
            raise RuntimeError("fork requires a paged engine "
                               "(ServeConfig.paged=True on a supported "
                               "family)")
        try:
            pslot = next(s for s, r in enumerate(self.active)
                         if r is parent)
        except StopIteration:
            raise ValueError(f"request {parent.rid} is not active "
                             f"(finished or still queued)") from None
        try:
            slot = next(s for s, r in enumerate(self.active) if r is None)
        except StopIteration:
            raise RuntimeError("no free slot to fork into") from None
        child = Request(rid=next(self._rids), prompt=parent.prompt.copy(),
                        max_new=max_new,
                        out_tokens=list(parent.out_tokens),
                        submit_t=time.perf_counter(), on_tokens=on_tokens)
        child.first_token_t = child.submit_t
        # the child's first regather reads the parent's rows from the
        # pool — flush the parent's pending writeback before sharing
        self._flush_virt()
        sid = self.alloc.fork(self._seq_of_slot[pslot])
        self._seq_of_slot[slot] = sid
        self._bt[slot] = padded_table(self.alloc.table(sid), self.nb_max)
        self._bt_dirty = True
        # the child slot's resident virtual row is whatever its previous
        # occupant left behind — regather before the next sync
        self._virt = None
        self._pos_h[slot] = self._pos_h[pslot]
        self._rem_h[slot] = max(max_new, 0)
        pos = int(self._pos_h[pslot])
        last_tok = parent.out_tokens[-1] if parent.out_tokens else 0
        alive = max_new > 0 and pos < self.scfg.max_len - 1
        self._act_h[slot] = alive
        self._pos = self._pos.at[slot].set(pos)
        self._last = self._last.at[slot].set(last_tok if alive else 0)
        self._remaining = self._remaining.at[slot].set(max(max_new, 0))
        self._active = self._active.at[slot].set(alive)
        if self.speculative:
            self._hist = self._hist.at[slot].set(self._hist[pslot])
        self.active[slot] = child
        self.metrics.counter("engine.forks").inc()
        if not alive:
            self._finish(slot, "max_new" if max_new <= 0 else "max_len")
        self._kv_gauges()
        return child

    # ------------------------------------------------------------------
    # reference path: the pre-PR per-token loop (parity oracle / "before"
    # benchmark side); one host round trip + (slots, vocab) logits transfer
    # per token, full cache copy per step and per admit.
    def _admit_reference(self):
        for slot in range(self.scfg.slots):
            if self.active[slot] is None and self.queue:
                req = self.queue.popleft()
                plen = len(req.prompt)
                logits, small = self.fns.prefill_fn(plen)(
                    self.params, jnp.asarray(req.prompt[None]))
                self.caches = _insert_slot(self.caches, small, slot)
                tok = int(jnp.argmax(logits[0, -1]))
                req.out_tokens.append(tok)
                req.first_token_t = time.perf_counter()
                self.active[slot] = req
                self.pos[slot] = plen                 # next write position
                if req.max_new <= 0:
                    self._finish(slot, "max_new")
                elif plen >= self.scfg.max_len - 1:
                    self._finish(slot, "max_len")
                self._emit(req, req.out_tokens[-1:], req.done)

    def _step_reference(self) -> bool:
        self._admit_reference()
        if not any(r is not None for r in self.active):
            return False
        toks = np.zeros((self.scfg.slots, 1), np.int32)
        for s, req in enumerate(self.active):
            if req is not None:
                toks[s, 0] = req.out_tokens[-1]
        logits, self.caches = self.fns.decode(self.params, jnp.asarray(toks),
                                              self.caches,
                                              jnp.asarray(self.pos))
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        for s, req in enumerate(self.active):
            if req is None:
                continue
            self.pos[s] += 1
            req.out_tokens.append(int(nxt[s]))
            if req.decoded >= req.max_new:
                self._finish(s, "max_new")
            elif self.pos[s] >= self.scfg.max_len - 1:
                self._finish(s, "max_len")
            self._emit(req, req.out_tokens[-1:], req.done)
        self.metrics.counter("engine.steps").inc()
        return True

    # ------------------------------------------------------------------
    def step(self):
        """One engine iteration: admit, then decode — a single step on the
        reference path, ``sync_every`` fused steps (one host sync) on the
        fused and paged paths."""
        if self._watch_early:
            self._sweep_expired()
        if self.paged:
            return self._step_paged()
        if self.scfg.fused:
            return self._step_fused()
        return self._step_reference()

    def run_until_drained(self, max_steps: int = 10_000):
        steps = 0
        while (self.queue or any(r is not None for r in self.active)) \
                and steps < max_steps:
            self.step()
            steps += 1
        return self.finished
