"""LM serving engine with continuous batching.

This is the paper's two-phase pipeline read onto LM serving (see
docs/DESIGN.md, "Two-phase pipeline -> serving"):
prefill is the per-instance *map* (each request independent), the batcher is
the *aggregation* (requests meet in a shared decode batch), and the decode
step is the parallel post-aggregation map.  Weights are placed once
(broadcast/tp policy) and reused across micro-batches — the mapPartitions
amortization.

Static shapes throughout: a fixed number of decode slots; prefill pads to
power-of-two buckets (pad-tolerant families only) to bound recompilation.

Two hot paths (``ServeConfig.fused``):

* **fused** (default): a decode iteration never leaves the device — the
  jitted step embeds, runs the backbone, and *samples in-jit* (greedy or
  temperature), returning only ``(slots,)`` token ids; caches / pos /
  last-token / liveness / budget are donated device buffers updated in
  place; a ``lax.fori_loop`` runs ``sync_every`` (K) steps per host sync
  with per-slot stop honored exactly via masking; admits run as bucketed
  batch prefill fused with a donated slot insert.
* **reference**: the original per-token loop (one host round trip and a
  ``(slots, vocab)`` logits transfer per token, full cache re-materialized
  per step and per admit).  It is the parity oracle
  (``tests/test_serving_fused.py``) and the "before" side of
  ``BENCH_serving.json``.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster.metrics import MetricsRegistry
from repro.models import api, transformer as tfm


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 512              # cache length per slot
    slots: int = 4                  # decode batch size (continuous batching)
    fused: bool = True              # on-device K-step loop + in-jit sampling
    sync_every: int = 8             # K: decode steps per host sync (fused)
    temperature: float = 0.0        # 0.0 -> greedy argmax (in-jit either way)
    seed: int = 0                   # sampling rng seed (temperature > 0)
    # Pad prompts up to power-of-two buckets so several queued requests
    # prefill in one call.  Auto-gated: recurrent archs (SSM/RG-LRU) would
    # absorb pads into their state, MoE capacity couples batch rows, and
    # ring (windowed) caches could evict real K/V — those families keep the
    # exact-length path (same-length prompts still batch there).
    prefill_bucketing: bool = True
    min_bucket: int = 8             # smallest prefill bucket (pad-tolerant)

    def __post_init__(self):
        if self.fused and self.sync_every < 1:
            raise ValueError(f"sync_every must be >= 1, got "
                             f"{self.sync_every}: a 0-step fused loop would "
                             f"spin without ever finishing a request")
        if not self.fused and self.temperature:
            raise ValueError("the reference (fused=False) path decodes "
                             "greedy-only; temperature sampling requires "
                             "the fused engine")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (S,) int32
    max_new: int                    # decoded-token budget (prefill token free)
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    finish_reason: str = ""         # "max_new" | "max_len" once done
    submit_t: float = 0.0
    first_token_t: float = 0.0
    done_t: float = 0.0

    @property
    def decoded(self) -> int:
        """Tokens produced by decode steps (excludes the prefill sample)."""
        return max(len(self.out_tokens) - 1, 0)


def _insert_slot(big, small, slot: int):
    """Write a batch-1 cache pytree into slot `slot` of the engine cache.
    Cache leaves have batch at axis 1: (repeats, B, ...)."""
    return jax.tree_util.tree_map(
        lambda b, s: b.at[:, slot:slot + 1].set(s.astype(b.dtype)), big, small)


def pad_tolerant(cfg, max_len: int) -> bool:
    """Can this arch prefill right-padded prompts exactly?

    False for SSM ("S") / RG-LRU ("R") — the recurrent state would absorb
    pad tokens; for MoE ("M") — expert capacity couples batch rows, so pads
    can displace real tokens; and for windowed attention ("L") with a ring
    cache — writing pads into the ring can evict real K/V.  Plain causal /
    global attention is exactly invariant to right-padding (pads sit
    *after* every real token, decode masks positions beyond ``pos``, and
    each pad cache entry is overwritten before it ever becomes visible).
    """
    for g in cfg.groups:
        for kind in g.pattern:
            if kind in ("S", "R", "M"):
                return False
            if kind == "L" and cfg.window and cfg.window < max_len:
                return False
    return True


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 1).bit_length()


class EngineFns:
    """Jitted engine functions shareable by N engine replicas with identical
    cfg/scfg — one XLA compile for the whole pool instead of one per replica.

    Fused-path functions donate the engine's device state (caches, pos,
    last-token, liveness, budget) so XLA updates the KV caches in place
    instead of copying the full pytree every step/admit; callers must treat
    the passed-in state as consumed and adopt the returned buffers.
    """

    def __init__(self, cfg, scfg: ServeConfig):
        self.cfg, self.scfg = cfg, scfg
        self.pad_ok = pad_tolerant(cfg, scfg.max_len)
        # MoE expert capacity couples batch rows: admitting several prompts
        # (or pad-duplicated rows) in one prefill would let rows displace
        # each other's expert slots and diverge from the reference path's
        # batch-1 admits — so MoE admits stay batch-1
        self.row_coupled = any(k == "M" for g in cfg.groups
                               for k in g.pattern)
        self.decode = jax.jit(
            lambda p, t, c, pos: tfm.decode_step(p, cfg, t, c, pos))
        # jit-cache builds are locked: the bundle is shared across thread
        # replicas, and a duplicated build means a duplicated multi-second
        # XLA compile — the exact cost this class exists to amortize
        self._build_lock = threading.Lock()
        # (plen,) -> jitted exact-length batch-1 prefill (reference path)
        self.prefill_cache: Dict[int, Callable] = {}
        # (bucket, n) -> jitted fused prefill+sample+insert (fused path)
        self._admit_cache: Dict[Tuple[int, int], Callable] = {}
        k, max_len, temp = scfg.sync_every, scfg.max_len, scfg.temperature

        def loop_fn(params, caches, pos, last, active, remaining, rng):
            return tfm.decode_loop(params, cfg, caches, pos, last, active,
                                   remaining, rng, k=k, max_len=max_len,
                                   temperature=temp)

        # donate caches/pos/last/active/remaining/rng: the K-step loop
        # aliases every state buffer instead of materializing a copy
        self.decode_loop = jax.jit(loop_fn, donate_argnums=(1, 2, 3, 4, 5, 6))

    def bucket(self, plen: int) -> int:
        """Prefill compile bucket for a prompt of length ``plen``."""
        if not (self.scfg.prefill_bucketing and self.pad_ok):
            return plen                       # exact-length path
        return min(max(_next_pow2(plen), self.scfg.min_bucket),
                   self.scfg.max_len)

    def admit_fn(self, bucket: int, n: int) -> Callable:
        """Jitted bucketed batch prefill: prefill ``n`` prompts padded to
        ``bucket`` in one call, sample their first tokens in-jit, and insert
        caches + per-slot state via donated ``dynamic_update_slice``."""
        key = (bucket, n)
        with self._build_lock:
            return self._admit_cache.get(key) or self._build_admit_fn(key)

    def _build_admit_fn(self, key: Tuple[int, int]) -> Callable:
        bucket, n = key
        cfg, scfg = self.cfg, self.scfg

        def fn(params, tokens, last_idx, slot_idx, budget,
               caches, pos, last, active, remaining, rng):
            """tokens (n,bucket) · last_idx/slot_idx/budget (n,) ·
            engine state donated; returns (first_tokens (n,), state...)."""
            small = api.init_caches(cfg, n, scfg.max_len)
            rng, sub = jax.random.split(rng)
            logits, small = tfm.prefill(params, cfg, tokens, small,
                                        last_index=last_idx)
            toks = tfm.sample_tokens(logits[:, 0], scfg.temperature, sub)
            for j in range(n):            # static unroll over admits
                s = slot_idx[j]
                caches = jax.tree_util.tree_map(
                    lambda b, sm: jax.lax.dynamic_update_slice_in_dim(
                        b, sm[:, j:j + 1].astype(b.dtype), s, axis=1),
                    caches, small)
                act_j = (budget[j] > 0) & (last_idx[j] + 1 < scfg.max_len - 1)
                pos = jax.lax.dynamic_update_index_in_dim(
                    pos, last_idx[j] + 1, s, 0)
                # an immediately-exhausted admit parks the slot on token 0,
                # the reference loop's zero-fill for empty slots
                last = jax.lax.dynamic_update_index_in_dim(
                    last, jnp.where(act_j, toks[j], 0), s, 0)
                remaining = jax.lax.dynamic_update_index_in_dim(
                    remaining, budget[j], s, 0)
                active = jax.lax.dynamic_update_index_in_dim(
                    active, act_j, s, 0)
            return toks, caches, pos, last, active, remaining, rng

        self._admit_cache[key] = jax.jit(
            fn, donate_argnums=(5, 6, 7, 8, 9, 10))
        return self._admit_cache[key]

    def prefill_fn(self, plen: int) -> Callable:
        """Exact-length batch-1 prefill (reference path, pre-PR shape)."""
        with self._build_lock:
            if plen not in self.prefill_cache:
                cfg, scfg = self.cfg, self.scfg

                def fn(params, tokens):
                    caches = api.init_caches(cfg, 1, scfg.max_len)
                    return tfm.prefill(params, cfg, tokens, caches)

                self.prefill_cache[plen] = jax.jit(fn)
            return self.prefill_cache[plen]


def make_engine_fns(cfg, scfg: ServeConfig) -> EngineFns:
    """Shared-jit bundle for an engine pool (see :class:`EngineFns`)."""
    return EngineFns(cfg, scfg)


class Engine:
    def __init__(self, params, cfg, scfg: ServeConfig,
                 metrics: Optional[MetricsRegistry] = None,
                 shared_fns: Optional[EngineFns] = None):
        self.params, self.cfg, self.scfg = params, cfg, scfg
        if cfg.family == "encdec":
            raise NotImplementedError("Engine serves decoder-LM families")
        self.fns = shared_fns if shared_fns is not None \
            else make_engine_fns(cfg, scfg)
        self.caches = api.init_caches(cfg, scfg.slots, scfg.max_len)
        self.active: List[Optional[Request]] = [None] * scfg.slots
        self.queue: Deque[Request] = deque()
        self.finished: List[Request] = []
        if scfg.fused:
            # device-resident loop state (donated through every fused call)
            self._pos = jnp.zeros((scfg.slots,), jnp.int32)
            self._last = jnp.zeros((scfg.slots,), jnp.int32)
            self._active = jnp.zeros((scfg.slots,), bool)
            self._remaining = jnp.zeros((scfg.slots,), jnp.int32)
            self._rng = jax.random.PRNGKey(scfg.seed)
        else:
            self.pos = np.zeros((scfg.slots,), np.int32)
        # monotonic request ids: never reused, regardless of how many
        # requests are queued/active/finished at submit time
        self._rids = itertools.count(1000)
        self.metrics = metrics if metrics is not None else MetricsRegistry()

    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new: int) -> Request:
        req = Request(rid=next(self._rids),
                      prompt=np.asarray(prompt, np.int32), max_new=max_new,
                      submit_t=time.perf_counter())
        self.queue.append(req)
        return req

    def _finish(self, slot: int, reason: str):
        req = self.active[slot]
        req.done = True
        req.finish_reason = reason
        req.done_t = time.perf_counter()
        self.finished.append(req)
        self.active[slot] = None
        self.metrics.counter("engine.requests").inc()
        self.metrics.counter("engine.tokens").inc(req.decoded)
        if reason == "max_len":
            self.metrics.counter("engine.truncated").inc()
        self.metrics.histogram("engine.ttft_s").observe(
            req.first_token_t - req.submit_t)
        self.metrics.histogram("engine.latency_s").observe(
            req.done_t - req.submit_t)

    # ------------------------------------------------------------------
    # fused path
    def _admit_fused(self):
        free = [s for s in range(self.scfg.slots) if self.active[s] is None]
        while free and self.queue:
            # longest same-bucket *prefix* of the queue (strict FIFO), up to
            # the number of free slots, prefilled as one padded batch
            bucket = self.fns.bucket(len(self.queue[0].prompt))
            batch = [self.queue.popleft()]
            # MoE rows couple through expert capacity: batch/pad admits
            # would diverge from the reference path's batch-1 prefill
            max_admit = 1 if self.fns.row_coupled else len(free)
            while self.queue and len(batch) < max_admit and \
                    self.fns.bucket(len(self.queue[0].prompt)) == bucket:
                batch.append(self.queue.popleft())
            n = len(batch)
            slots_idx, free = free[:n], free[n:]
            # pad the batch dimension up to a power of two so admit
            # compiles are bounded by |buckets| x log2(slots), not by every
            # batch size the queue happens to produce.  Pad rows duplicate
            # row 0 *and its slot* and come first, so the real rows' writes
            # (last in the unrolled insert) always win.
            n_pad = _next_pow2(n) if n > 1 else 1
            rows = [batch[0]] * (n_pad - n) + batch
            row_slots = np.asarray([slots_idx[0]] * (n_pad - n) + slots_idx,
                                   np.int32)
            tokens = np.zeros((n_pad, bucket), np.int32)
            last_idx = np.zeros((n_pad,), np.int32)
            budget = np.zeros((n_pad,), np.int32)
            for j, req in enumerate(rows):
                plen = len(req.prompt)
                tokens[j, :plen] = req.prompt
                last_idx[j] = plen - 1
                budget[j] = max(req.max_new, 0)
            toks, self.caches, self._pos, self._last, self._active, \
                self._remaining, self._rng = self.fns.admit_fn(bucket, n_pad)(
                    self.params, jnp.asarray(tokens), jnp.asarray(last_idx),
                    jnp.asarray(row_slots), jnp.asarray(budget),
                    self.caches, self._pos, self._last,
                    self._active, self._remaining, self._rng)
            toks_h = np.asarray(toks)[n_pad - n:]
            now = time.perf_counter()
            for j, req in enumerate(batch):
                req.out_tokens.append(int(toks_h[j]))
                req.first_token_t = now
                self.active[slots_idx[j]] = req
                if req.max_new <= 0:
                    self._finish(slots_idx[j], "max_new")
                elif len(req.prompt) >= self.scfg.max_len - 1:
                    self._finish(slots_idx[j], "max_len")
            self.metrics.counter("engine.prefill_batches").inc()

    def _step_fused(self) -> bool:
        self._admit_fused()
        if not any(r is not None for r in self.active):
            return False
        out, emitted, self.caches, self._pos, self._last, self._active, \
            self._remaining, self._rng = self.fns.decode_loop(
                self.params, self.caches, self._pos, self._last,
                self._active, self._remaining, self._rng)
        # one host sync per K decode steps
        out_h = np.asarray(out)
        em_h = np.asarray(emitted)
        act_h = np.asarray(self._active)
        rem_h = np.asarray(self._remaining)
        for s, req in enumerate(self.active):
            if req is None:
                continue
            req.out_tokens.extend(int(t) for t in out_h[s, :em_h[s]])
            if not act_h[s]:
                self._finish(s, "max_new" if rem_h[s] <= 0 else "max_len")
        self.metrics.counter("engine.steps").inc()
        return True

    # ------------------------------------------------------------------
    # reference path: the pre-PR per-token loop (parity oracle / "before"
    # benchmark side); one host round trip + (slots, vocab) logits transfer
    # per token, full cache copy per step and per admit.
    def _admit_reference(self):
        for slot in range(self.scfg.slots):
            if self.active[slot] is None and self.queue:
                req = self.queue.popleft()
                plen = len(req.prompt)
                logits, small = self.fns.prefill_fn(plen)(
                    self.params, jnp.asarray(req.prompt[None]))
                self.caches = _insert_slot(self.caches, small, slot)
                tok = int(jnp.argmax(logits[0, -1]))
                req.out_tokens.append(tok)
                req.first_token_t = time.perf_counter()
                self.active[slot] = req
                self.pos[slot] = plen                 # next write position
                if req.max_new <= 0:
                    self._finish(slot, "max_new")
                elif plen >= self.scfg.max_len - 1:
                    self._finish(slot, "max_len")

    def _step_reference(self) -> bool:
        self._admit_reference()
        if not any(r is not None for r in self.active):
            return False
        toks = np.zeros((self.scfg.slots, 1), np.int32)
        for s, req in enumerate(self.active):
            if req is not None:
                toks[s, 0] = req.out_tokens[-1]
        logits, self.caches = self.fns.decode(self.params, jnp.asarray(toks),
                                              self.caches,
                                              jnp.asarray(self.pos))
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        for s, req in enumerate(self.active):
            if req is None:
                continue
            self.pos[s] += 1
            req.out_tokens.append(int(nxt[s]))
            if req.decoded >= req.max_new:
                self._finish(s, "max_new")
            elif self.pos[s] >= self.scfg.max_len - 1:
                self._finish(s, "max_len")
        self.metrics.counter("engine.steps").inc()
        return True

    # ------------------------------------------------------------------
    def step(self):
        """One engine iteration: admit, then decode — a single step on the
        reference path, ``sync_every`` fused steps (one host sync) on the
        fused path."""
        if self.scfg.fused:
            return self._step_fused()
        return self._step_reference()

    def run_until_drained(self, max_steps: int = 10_000):
        steps = 0
        while (self.queue or any(r is not None for r in self.active)) \
                and steps < max_steps:
            self.step()
            steps += 1
        return self.finished
