"""LM serving engine with continuous batching.

This is the paper's two-phase pipeline read onto LM serving (see
docs/DESIGN.md, "Two-phase pipeline -> serving"):
prefill is the per-instance *map* (each request independent), the batcher is
the *aggregation* (requests meet in a shared decode batch), and the decode
step is the parallel post-aggregation map.  Weights are placed once
(broadcast/tp policy) and reused across micro-batches — the mapPartitions
amortization.

Static shapes throughout: a fixed number of decode slots; prefill pads to
power-of-two buckets to bound recompilation.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster.metrics import MetricsRegistry
from repro.models import api, transformer as tfm


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 512              # cache length per slot
    slots: int = 4                  # decode batch size (continuous batching)
    # Prompts are prefillied at exact length (one compile per distinct
    # length).  Production engines bucket + mask pad positions; recurrent
    # archs (SSM/RG-LRU) require pad-free prefill, so exact-length is the
    # correct default here.
    greedy: bool = True


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (S,) int32
    max_new: int
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    submit_t: float = 0.0
    first_token_t: float = 0.0
    done_t: float = 0.0


def _insert_slot(big, small, slot: int):
    """Write a batch-1 cache pytree into slot `slot` of the engine cache.
    Cache leaves have batch at axis 1: (repeats, B, ...)."""
    return jax.tree_util.tree_map(
        lambda b, s: b.at[:, slot:slot + 1].set(s.astype(b.dtype)), big, small)


def make_engine_fns(cfg, scfg: ServeConfig):
    """Jitted (decode_fn, prefill_cache) shareable by N engine replicas with
    identical cfg/scfg — one XLA compile for the whole pool instead of one
    per replica (each Engine otherwise jits its own fresh lambdas)."""
    decode = jax.jit(lambda p, t, c, pos: tfm.decode_step(p, cfg, t, c, pos))
    return decode, {}


class Engine:
    def __init__(self, params, cfg, scfg: ServeConfig,
                 metrics: Optional[MetricsRegistry] = None,
                 shared_fns=None):
        self.params, self.cfg, self.scfg = params, cfg, scfg
        if cfg.family == "encdec":
            raise NotImplementedError("Engine serves decoder-LM families")
        self.caches = api.init_caches(cfg, scfg.slots, scfg.max_len)
        self.pos = np.zeros((scfg.slots,), np.int32)
        self.active: List[Optional[Request]] = [None] * scfg.slots
        self.queue: Deque[Request] = deque()
        self.finished: List[Request] = []
        self._decode, self._prefill_cache = shared_fns if shared_fns else \
            make_engine_fns(cfg, scfg)
        # monotonic request ids: never reused, regardless of how many
        # requests are queued/active/finished at submit time
        self._rids = itertools.count(1000)
        self.metrics = metrics if metrics is not None else MetricsRegistry()

    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new: int) -> Request:
        req = Request(rid=next(self._rids),
                      prompt=np.asarray(prompt, np.int32), max_new=max_new,
                      submit_t=time.perf_counter())
        self.queue.append(req)
        return req

    def _prefill_fn(self, plen: int):
        if plen not in self._prefill_cache:
            cfg, scfg = self.cfg, self.scfg

            def fn(params, tokens):
                caches = api.init_caches(cfg, 1, scfg.max_len)
                return tfm.prefill(params, cfg, tokens, caches)

            self._prefill_cache[plen] = jax.jit(fn)
        return self._prefill_cache[plen]

    def _admit(self):
        for slot in range(self.scfg.slots):
            if self.active[slot] is None and self.queue:
                req = self.queue.popleft()
                plen = len(req.prompt)
                logits, small = self._prefill_fn(plen)(
                    self.params, jnp.asarray(req.prompt[None]))
                self.caches = _insert_slot(self.caches, small, slot)
                tok = int(jnp.argmax(logits[0, -1]))
                req.out_tokens.append(tok)
                req.first_token_t = time.perf_counter()
                self.active[slot] = req
                self.pos[slot] = plen                 # next write position

    # ------------------------------------------------------------------
    def step(self):
        """One engine iteration: admit + one decode step for all slots."""
        self._admit()
        if not any(self.active):
            return False
        toks = np.zeros((self.scfg.slots, 1), np.int32)
        for s, req in enumerate(self.active):
            if req is not None:
                toks[s, 0] = req.out_tokens[-1]
        logits, self.caches = self._decode(self.params, jnp.asarray(toks),
                                           self.caches,
                                           jnp.asarray(self.pos))
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        for s, req in enumerate(self.active):
            if req is None:
                continue
            self.pos[s] += 1
            req.out_tokens.append(int(nxt[s]))
            if len(req.out_tokens) >= req.max_new or self.pos[s] >= self.scfg.max_len - 1:
                req.done = True
                req.done_t = time.perf_counter()
                self.finished.append(req)
                self.active[s] = None
                self.metrics.counter("engine.requests").inc()
                self.metrics.counter("engine.tokens").inc(len(req.out_tokens))
                self.metrics.histogram("engine.ttft_s").observe(
                    req.first_token_t - req.submit_t)
                self.metrics.histogram("engine.latency_s").observe(
                    req.done_t - req.submit_t)
        self.metrics.counter("engine.steps").inc()
        return True

    def run_until_drained(self, max_steps: int = 10_000):
        steps = 0
        while (self.queue or any(self.active)) and steps < max_steps:
            self.step()
            steps += 1
        return self.finished
