"""LM serving engine with continuous batching.

This is the paper's two-phase pipeline read onto LM serving (see
docs/DESIGN.md, "Two-phase pipeline -> serving"):
prefill is the per-instance *map* (each request independent), the batcher is
the *aggregation* (requests meet in a shared decode batch), and the decode
step is the parallel post-aggregation map.  Weights are placed once
(broadcast/tp policy) and reused across micro-batches — the mapPartitions
amortization.

Static shapes throughout: a fixed number of decode slots; prefill pads to
power-of-two buckets (pad-tolerant families only) to bound recompilation.

Two hot paths (``ServeConfig.fused``):

* **fused** (default): a decode iteration never leaves the device — the
  jitted step embeds, runs the backbone, and *samples in-jit* (greedy or
  temperature), returning only ``(slots,)`` token ids; caches / pos /
  last-token / liveness / budget are donated device buffers updated in
  place; a ``lax.fori_loop`` runs ``sync_every`` (K) steps per host sync
  with per-slot stop honored exactly via masking; admits run as bucketed
  batch prefill fused with a donated slot insert.
* **reference**: the original per-token loop (one host round trip and a
  ``(slots, vocab)`` logits transfer per token, full cache re-materialized
  per step and per admit).  It is the parity oracle
  (``tests/test_serving_fused.py``) and the "before" side of
  ``BENCH_serving.json``.

With ``ServeConfig.paged`` the fused loop additionally runs against a
**paged KV cache** (``serving/kvpool.py``): K/V live in a shared
per-layer block pool addressed through per-slot block tables, blocks are
allocated as decode advances (not reserved at ``max_len``), shared
system/task prompts are prefilled once via a content-hashed prefix cache,
and forks share blocks copy-on-write.  Token-exact vs the dense fused
path (``tests/test_serving_paged.py``); capacity numbers in
``BENCH_serving.json`` under ``"paged"``.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster.metrics import MetricsRegistry
from repro.cluster.tracing import (NULL_SPAN, annotate, current_recorder,
                                   current_tracer)
from repro.models import api, transformer as tfm
from repro.serving.kvpool import (NULL_BLOCK, BlockAllocator, PoolExhausted,
                                  hash_token_blocks, padded_table)


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 512              # cache length per slot
    slots: int = 4                  # decode batch size (continuous batching)
    fused: bool = True              # on-device K-step loop + in-jit sampling
    sync_every: int = 8             # K: decode steps per host sync (fused)
    temperature: float = 0.0        # 0.0 -> greedy argmax (in-jit either way)
    seed: int = 0                   # sampling rng seed (temperature > 0)
    # Pad prompts up to power-of-two buckets so several queued requests
    # prefill in one call.  Auto-gated: recurrent archs (SSM/RG-LRU) would
    # absorb pads into their state, MoE capacity couples batch rows, and
    # ring (windowed) caches could evict real K/V — those families keep the
    # exact-length path (same-length prompts still batch there).
    prefill_bucketing: bool = True
    min_bucket: int = 8             # smallest prefill bucket (pad-tolerant)
    # Paged KV cache (serving/kvpool.py): K/V live in a shared block pool
    # instead of one dense max_len stripe per slot, so per-replica session
    # capacity is bounded by *tokens in flight*, not slots x max_len.
    # Families holding non-pageable state (SSM/RG-LRU/MLA/ring windows)
    # silently keep the dense path (engine.paged reports the outcome).
    paged: bool = False
    block_size: int = 16            # tokens per KV block
    # usable pool blocks; 0 -> slots * (max_len / block_size), i.e. the
    # same token capacity the dense layout reserves.  Capacity gains come
    # from raising `slots` while holding kv_blocks * block_size fixed.
    kv_blocks: int = 0
    prefix_cache: bool = True       # content-hashed full-block prompt reuse

    def __post_init__(self):
        if self.fused and self.sync_every < 1:
            raise ValueError(f"sync_every must be >= 1, got "
                             f"{self.sync_every}: a 0-step fused loop would "
                             f"spin without ever finishing a request")
        if not self.fused and self.temperature:
            raise ValueError("the reference (fused=False) path decodes "
                             "greedy-only; temperature sampling requires "
                             "the fused engine")
        if self.paged:
            if not self.fused:
                raise ValueError("paged=True requires the fused engine; "
                                 "the per-token reference loop is dense-"
                                 "only (it is the parity oracle)")
            if self.block_size < 1 or self.max_len % self.block_size:
                raise ValueError(
                    f"block_size ({self.block_size}) must divide max_len "
                    f"({self.max_len}): equal virtual cache length is what "
                    f"makes the paged path token-exact vs the dense oracle")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (S,) int32
    max_new: int                    # decoded-token budget (prefill token free)
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    finish_reason: str = ""         # "max_new" | "max_len" once done
    submit_t: float = 0.0
    first_token_t: float = 0.0
    done_t: float = 0.0
    # streaming: called at every host sync with the tokens that sync
    # produced — on_tokens(req, new_tokens, done).  One call per K-step
    # sync on the fused/paged paths, per token on the reference path.
    on_tokens: Optional[Callable[["Request", List[int], bool], None]] = None
    # tracing: the engine-side request span (submit -> finish) and the
    # context engine batch spans parent on; under a cluster the context
    # arrives with the work item, standalone submits root their own
    trace_span: Any = None
    trace_ctx: Any = None

    @property
    def decoded(self) -> int:
        """Tokens produced by decode steps (excludes the prefill sample)."""
        return max(len(self.out_tokens) - 1, 0)


def _insert_slot(big, small, slot: int):
    """Write a batch-1 cache pytree into slot `slot` of the engine cache.
    Cache leaves have batch at axis 1: (repeats, B, ...)."""
    return jax.tree_util.tree_map(
        lambda b, s: b.at[:, slot:slot + 1].set(s.astype(b.dtype)), big, small)


def pad_tolerant(cfg, max_len: int) -> bool:
    """Can this arch prefill right-padded prompts exactly?

    False for SSM ("S") / RG-LRU ("R") — the recurrent state would absorb
    pad tokens; for MoE ("M") — expert capacity couples batch rows, so pads
    can displace real tokens; and for windowed attention ("L") with a ring
    cache — writing pads into the ring can evict real K/V.  Plain causal /
    global attention is exactly invariant to right-padding (pads sit
    *after* every real token, decode masks positions beyond ``pos``, and
    each pad cache entry is overwritten before it ever becomes visible).
    """
    for g in cfg.groups:
        for kind in g.pattern:
            if kind in ("S", "R", "M"):
                return False
            if kind == "L" and cfg.window and cfg.window < max_len:
                return False
    return True


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 1).bit_length()


class _PromptTooLong(ValueError):
    """A prompt no allocation could ever satisfy (needs more blocks than
    the whole pool): rejected per-request, never raised out of step()."""


class EngineFns:
    """Jitted engine functions shareable by N engine replicas with identical
    cfg/scfg — one XLA compile for the whole pool instead of one per replica.

    Fused-path functions donate the engine's device state (caches, pos,
    last-token, liveness, budget) so XLA updates the KV caches in place
    instead of copying the full pytree every step/admit; callers must treat
    the passed-in state as consumed and adopt the returned buffers.
    """

    def __init__(self, cfg, scfg: ServeConfig):
        self.cfg, self.scfg = cfg, scfg
        self.pad_ok = pad_tolerant(cfg, scfg.max_len)
        self.paged_ok = tfm.paged_supported(cfg, scfg.max_len)
        # MoE expert capacity couples batch rows: admitting several prompts
        # (or pad-duplicated rows) in one prefill would let rows displace
        # each other's expert slots and diverge from the reference path's
        # batch-1 admits — so MoE admits stay batch-1
        self.row_coupled = any(k == "M" for g in cfg.groups
                               for k in g.pattern)
        self.decode = jax.jit(
            lambda p, t, c, pos: tfm.decode_step(p, cfg, t, c, pos))
        # jit-cache builds are locked: the bundle is shared across thread
        # replicas, and a duplicated build means a duplicated multi-second
        # XLA compile — the exact cost this class exists to amortize
        self._build_lock = threading.Lock()
        # (plen,) -> jitted exact-length batch-1 prefill (reference path)
        self.prefill_cache: Dict[int, Callable] = {}
        # (bucket, n) -> jitted fused prefill+sample+insert (fused path)
        self._admit_cache: Dict[Tuple[int, int], Callable] = {}
        k, max_len, temp = scfg.sync_every, scfg.max_len, scfg.temperature

        def loop_fn(params, caches, pos, last, active, remaining, rng):
            return tfm.decode_loop(params, cfg, caches, pos, last, active,
                                   remaining, rng, k=k, max_len=max_len,
                                   temperature=temp)

        # donate caches/pos/last/active/remaining/rng: the K-step loop
        # aliases every state buffer instead of materializing a copy
        self.decode_loop = jax.jit(loop_fn, donate_argnums=(1, 2, 3, 4, 5, 6))

        def paged_loop_fn(params, bt, caches, pos, last, active, remaining,
                          rng):
            return tfm.decode_loop(params, cfg, caches, pos, last, active,
                                   remaining, rng, k=k, max_len=max_len,
                                   temperature=temp, bt=bt)

        # block tables are rebuilt host-side each sync (allocation is a
        # host decision), so bt is a plain input — everything else donates
        self.paged_decode_loop = jax.jit(paged_loop_fn,
                                         donate_argnums=(2, 3, 4, 5, 6, 7))
        # (bucket, n) -> jitted paged suffix-extend + sample + slot insert
        self._paged_admit_cache: Dict[Tuple[int, int], Callable] = {}

        def cow(caches, src, dst):
            """Copy-on-write: ``pool[dst[i]] = pool[src[i]]`` for every
            layer's K/V pool (donated).  Pad pairs are (0, 0) — a
            null-block self-copy; callers pad pair counts to powers of
            two so jit's shape specialization stays bounded."""
            return jax.tree_util.tree_map(
                lambda c: c.at[:, dst].set(c[:, src]), caches)

        self.cow = jax.jit(cow, donate_argnums=(0,))

    def bucket(self, plen: int) -> int:
        """Prefill compile bucket for a prompt of length ``plen``."""
        if not (self.scfg.prefill_bucketing and self.pad_ok):
            return plen                       # exact-length path
        return min(max(_next_pow2(plen), self.scfg.min_bucket),
                   self.scfg.max_len)

    def admit_fn(self, bucket: int, n: int) -> Callable:
        """Jitted bucketed batch prefill: prefill ``n`` prompts padded to
        ``bucket`` in one call, sample their first tokens in-jit, and insert
        caches + per-slot state via donated ``dynamic_update_slice``."""
        key = (bucket, n)
        with self._build_lock:
            return self._admit_cache.get(key) or self._build_admit_fn(key)

    def _build_admit_fn(self, key: Tuple[int, int]) -> Callable:
        bucket, n = key
        cfg, scfg = self.cfg, self.scfg

        def fn(params, tokens, last_idx, slot_idx, budget,
               caches, pos, last, active, remaining, rng):
            """tokens (n,bucket) · last_idx/slot_idx/budget (n,) ·
            engine state donated; returns (first_tokens (n,), state...)."""
            small = api.init_caches(cfg, n, scfg.max_len)
            rng, sub = jax.random.split(rng)
            logits, small = tfm.prefill(params, cfg, tokens, small,
                                        last_index=last_idx)
            toks = tfm.sample_tokens(logits[:, 0], scfg.temperature, sub)
            for j in range(n):            # static unroll over admits
                s = slot_idx[j]
                caches = jax.tree_util.tree_map(
                    lambda b, sm: jax.lax.dynamic_update_slice_in_dim(
                        b, sm[:, j:j + 1].astype(b.dtype), s, axis=1),
                    caches, small)
                act_j = (budget[j] > 0) & (last_idx[j] + 1 < scfg.max_len - 1)
                pos = jax.lax.dynamic_update_index_in_dim(
                    pos, last_idx[j] + 1, s, 0)
                # an immediately-exhausted admit parks the slot on token 0,
                # the reference loop's zero-fill for empty slots
                last = jax.lax.dynamic_update_index_in_dim(
                    last, jnp.where(act_j, toks[j], 0), s, 0)
                remaining = jax.lax.dynamic_update_index_in_dim(
                    remaining, budget[j], s, 0)
                active = jax.lax.dynamic_update_index_in_dim(
                    active, act_j, s, 0)
            return toks, caches, pos, last, active, remaining, rng

        self._admit_cache[key] = jax.jit(
            fn, donate_argnums=(5, 6, 7, 8, 9, 10))
        return self._admit_cache[key]

    def paged_admit_fn(self, bucket: int, n: int) -> Callable:
        """Jitted paged admit: extend ``n`` sequences by their (padded)
        suffix tokens through their block tables, sample first tokens
        in-jit, and update the donated slot state."""
        key = (bucket, n)
        with self._build_lock:
            return self._paged_admit_cache.get(key) or \
                self._build_paged_admit_fn(key)

    def _build_paged_admit_fn(self, key: Tuple[int, int]) -> Callable:
        bucket, n = key
        cfg, scfg = self.cfg, self.scfg

        def fn(params, tokens, pos0, last_idx, slot_idx, budget, bt,
               caches, pos, last, active, remaining, rng):
            """tokens (n,bucket) suffix ids · pos0 (n,) cached-prefix
            length · last_idx (n,) suffix-local last index · bt
            (n, nb_max) block tables · engine state donated."""
            rng, sub = jax.random.split(rng)
            logits, caches = tfm.extend_paged(params, cfg, tokens, caches,
                                              pos0, bt, last_index=last_idx)
            toks = tfm.sample_tokens(logits[:, 0], scfg.temperature, sub)
            for j in range(n):            # static unroll over admits
                s = slot_idx[j]
                nxt = pos0[j] + last_idx[j] + 1     # next write position
                act_j = (budget[j] > 0) & (nxt < scfg.max_len - 1)
                pos = jax.lax.dynamic_update_index_in_dim(pos, nxt, s, 0)
                last = jax.lax.dynamic_update_index_in_dim(
                    last, jnp.where(act_j, toks[j], 0), s, 0)
                remaining = jax.lax.dynamic_update_index_in_dim(
                    remaining, budget[j], s, 0)
                active = jax.lax.dynamic_update_index_in_dim(
                    active, act_j, s, 0)
            return toks, caches, pos, last, active, remaining, rng

        self._paged_admit_cache[key] = jax.jit(
            fn, donate_argnums=(7, 8, 9, 10, 11, 12))
        return self._paged_admit_cache[key]


    def prefill_fn(self, plen: int) -> Callable:
        """Exact-length batch-1 prefill (reference path, pre-PR shape)."""
        with self._build_lock:
            if plen not in self.prefill_cache:
                cfg, scfg = self.cfg, self.scfg

                def fn(params, tokens):
                    caches = api.init_caches(cfg, 1, scfg.max_len)
                    return tfm.prefill(params, cfg, tokens, caches)

                self.prefill_cache[plen] = jax.jit(fn)
            return self.prefill_cache[plen]


def make_engine_fns(cfg, scfg: ServeConfig) -> EngineFns:
    """Shared-jit bundle for an engine pool (see :class:`EngineFns`)."""
    return EngineFns(cfg, scfg)


class Engine:
    def __init__(self, params, cfg, scfg: ServeConfig,
                 metrics: Optional[MetricsRegistry] = None,
                 shared_fns: Optional[EngineFns] = None):
        self.params, self.cfg, self.scfg = params, cfg, scfg
        if cfg.family == "encdec":
            raise NotImplementedError("Engine serves decoder-LM families")
        self.fns = shared_fns if shared_fns is not None \
            else make_engine_fns(cfg, scfg)
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        # paged KV: only families whose whole cache is position-addressed
        # attention K/V can page; the rest (SSM/RG-LRU/MLA/ring) keep the
        # dense path — observable via `engine.paged` and the counter
        self.paged = scfg.paged and self.fns.paged_ok
        if scfg.paged and not self.fns.paged_ok:
            self.metrics.counter("engine.paged_fallback_dense").inc()
        if self.paged:
            bs = scfg.block_size
            self.nb_max = scfg.max_len // bs
            n_blocks = scfg.kv_blocks or scfg.slots * self.nb_max
            self.caches = tfm.init_paged_caches(cfg, n_blocks, bs)
            self.alloc = BlockAllocator(n_blocks, bs)
            self.alloc.on_evict = lambda bid: current_recorder().record(
                "kv_evict", block=bid)
            self._seq_of_slot: List[Optional[int]] = [None] * scfg.slots
            self._bt = np.zeros((scfg.slots, self.nb_max), np.int32)
            self._pos_h = np.zeros((scfg.slots,), np.int64)
            self._rem_h = np.zeros((scfg.slots,), np.int64)
            self.metrics.gauge("engine.kv_blocks_total").set(n_blocks)
            self._kv_gauges()
        else:
            self.caches = api.init_caches(cfg, scfg.slots, scfg.max_len)
        self.active: List[Optional[Request]] = [None] * scfg.slots
        self.queue: Deque[Request] = deque()
        self.finished: List[Request] = []
        if scfg.fused:
            # device-resident loop state (donated through every fused call)
            self._pos = jnp.zeros((scfg.slots,), jnp.int32)
            self._last = jnp.zeros((scfg.slots,), jnp.int32)
            self._active = jnp.zeros((scfg.slots,), bool)
            self._remaining = jnp.zeros((scfg.slots,), jnp.int32)
            self._rng = jax.random.PRNGKey(scfg.seed)
        else:
            self.pos = np.zeros((scfg.slots,), np.int32)
        # monotonic request ids: never reused, regardless of how many
        # requests are queued/active/finished at submit time
        self._rids = itertools.count(1000)

    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new: int,
               on_tokens: Optional[Callable] = None,
               trace_ctx: Any = None) -> Request:
        req = Request(rid=next(self._rids),
                      prompt=np.asarray(prompt, np.int32), max_new=max_new,
                      submit_t=time.perf_counter(), on_tokens=on_tokens)
        # with a cluster context this parents into the request's trace;
        # standalone (trace_ctx None) it roots one, subject to sampling
        sp = current_tracer().span("engine.request", parent=trace_ctx,
                                   rid=req.rid, prompt_len=len(req.prompt),
                                   max_new=max_new)
        if sp.recording:
            req.trace_span = sp
            req.trace_ctx = sp.ctx
        self.queue.append(req)
        return req

    def _emit(self, req: Request, toks: List[int], done: bool):
        """Per-sync streaming callback; a throwing consumer must not take
        the engine (and every other slot's request) down with it."""
        if req.on_tokens is None:
            return
        try:
            req.on_tokens(req, list(toks), done)
        except Exception:
            self.metrics.counter("engine.stream_errors").inc()

    def _kv_gauges(self):
        self.metrics.gauge("engine.kv_blocks_free").set(
            self.alloc.free_blocks)
        self.metrics.gauge("engine.kv_blocks_cached").set(
            self.alloc.cached_blocks)

    def _close_span(self, req: Request):
        if req.trace_span is not None:
            req.trace_span.tag(finish=req.finish_reason,
                               decoded=req.decoded)
            req.trace_span.end()
            req.trace_span = None

    def _finish(self, slot: int, reason: str):
        req = self.active[slot]
        req.done = True
        req.finish_reason = reason
        req.done_t = time.perf_counter()
        self._close_span(req)
        self.finished.append(req)
        self.active[slot] = None
        if self.paged:
            # release the sequence's blocks (cached prefix blocks survive
            # via the prefix cache's own reference) and null the table row
            # so the still-running device loop can write nothing real
            sid = self._seq_of_slot[slot]
            if sid is not None:
                self.alloc.free_seq(sid)
                self._seq_of_slot[slot] = None
                self._bt[slot] = NULL_BLOCK
            self._kv_gauges()
        self.metrics.counter("engine.requests").inc()
        self.metrics.counter("engine.tokens").inc(req.decoded)
        if reason == "max_len":
            self.metrics.counter("engine.truncated").inc()
        self.metrics.histogram("engine.ttft_s").observe(
            req.first_token_t - req.submit_t)
        self.metrics.histogram("engine.latency_s").observe(
            req.done_t - req.submit_t)

    # ------------------------------------------------------------------
    # fused path
    def _admit_fused(self):
        free = [s for s in range(self.scfg.slots) if self.active[s] is None]
        while free and self.queue:
            # longest same-bucket *prefix* of the queue (strict FIFO), up to
            # the number of free slots, prefilled as one padded batch
            bucket = self.fns.bucket(len(self.queue[0].prompt))
            batch = [self.queue.popleft()]
            # MoE rows couple through expert capacity: batch/pad admits
            # would diverge from the reference path's batch-1 prefill
            max_admit = 1 if self.fns.row_coupled else len(free)
            while self.queue and len(batch) < max_admit and \
                    self.fns.bucket(len(self.queue[0].prompt)) == bucket:
                batch.append(self.queue.popleft())
            n = len(batch)
            slots_idx, free = free[:n], free[n:]
            # pad the batch dimension up to a power of two so admit
            # compiles are bounded by |buckets| x log2(slots), not by every
            # batch size the queue happens to produce.  Pad rows duplicate
            # row 0 *and its slot* and come first, so the real rows' writes
            # (last in the unrolled insert) always win.
            n_pad = _next_pow2(n) if n > 1 else 1
            rows = [batch[0]] * (n_pad - n) + batch
            row_slots = np.asarray([slots_idx[0]] * (n_pad - n) + slots_idx,
                                   np.int32)
            tokens = np.zeros((n_pad, bucket), np.int32)
            last_idx = np.zeros((n_pad,), np.int32)
            budget = np.zeros((n_pad,), np.int32)
            for j, req in enumerate(rows):
                plen = len(req.prompt)
                tokens[j, :plen] = req.prompt
                last_idx[j] = plen - 1
                budget[j] = max(req.max_new, 0)
            asp = current_tracer().span(
                "engine.admit",
                parent=next((r.trace_ctx for r in batch
                             if r.trace_ctx is not None), None),
                bucket=bucket, n=n, n_pad=n_pad,
                rids=[r.rid for r in batch])
            current_recorder().record("admit", rids=[r.rid for r in batch],
                                      bucket=bucket, n=n)
            # the prefill span brackets the jitted call *plus* the host
            # sync that realizes its tokens — tracing never reaches
            # inside jit, it measures the host-visible stage
            psp = current_tracer().span("engine.prefill", parent=asp,
                                        bucket=bucket, n_pad=n_pad)
            with annotate("prefill"):
                toks, self.caches, self._pos, self._last, self._active, \
                    self._remaining, self._rng = \
                    self.fns.admit_fn(bucket, n_pad)(
                        self.params, jnp.asarray(tokens),
                        jnp.asarray(last_idx),
                        jnp.asarray(row_slots), jnp.asarray(budget),
                        self.caches, self._pos, self._last,
                        self._active, self._remaining, self._rng)
                toks_h = np.asarray(toks)[n_pad - n:]
            psp.end()
            now = time.perf_counter()
            for j, req in enumerate(batch):
                req.out_tokens.append(int(toks_h[j]))
                req.first_token_t = now
                self.active[slots_idx[j]] = req
                if req.max_new <= 0:
                    self._finish(slots_idx[j], "max_new")
                elif len(req.prompt) >= self.scfg.max_len - 1:
                    self._finish(slots_idx[j], "max_len")
                self._emit(req, req.out_tokens[-1:], req.done)
            asp.end()
            self.metrics.counter("engine.prefill_batches").inc()

    def _batch_ctx(self):
        """Trace parent for a decode-sync span: the first traced active
        request (one span serves the whole shared batch)."""
        return next((r.trace_ctx for r in self.active
                     if r is not None and r.trace_ctx is not None), None)

    def _step_fused(self) -> bool:
        self._admit_fused()
        if not any(r is not None for r in self.active):
            return False
        dsp = current_tracer().span(
            "engine.decode_sync", parent=self._batch_ctx(),
            k=self.scfg.sync_every,
            n_active=sum(r is not None for r in self.active))
        with annotate("decode_loop"):
            out, emitted, self.caches, self._pos, self._last, self._active, \
                self._remaining, self._rng = self.fns.decode_loop(
                    self.params, self.caches, self._pos, self._last,
                    self._active, self._remaining, self._rng)
            # one host sync per K decode steps (sampling happened in-jit)
            hsp = current_tracer().span("engine.host_sync", parent=dsp)
            out_h = np.asarray(out)
            em_h = np.asarray(emitted)
            act_h = np.asarray(self._active)
            rem_h = np.asarray(self._remaining)
            hsp.end()
        esp = current_tracer().span("engine.stream_emit", parent=dsp) \
            if any(r is not None and r.on_tokens is not None
                   for r in self.active) else NULL_SPAN
        for s, req in enumerate(self.active):
            if req is None:
                continue
            new = [int(t) for t in out_h[s, :em_h[s]]]
            req.out_tokens.extend(new)
            if not act_h[s]:
                self._finish(s, "max_new" if rem_h[s] <= 0 else "max_len")
            self._emit(req, new, req.done)
        esp.end()
        dsp.end()
        self.metrics.counter("engine.steps").inc()
        return True

    # ------------------------------------------------------------------
    # paged path: same fused K-step loop, but K/V live in a shared block
    # pool addressed through per-slot block tables (serving/kvpool.py).
    # Admits prefill only the suffix a prefix-cache hit leaves uncovered;
    # block allocation / COW / freeing are host decisions executed on
    # device between syncs.
    def _prep_paged(self, req: Request):
        """Plan one admit without side effects: prefix hits, suffix shape,
        and the block headroom it would need.  None == cannot admit now."""
        bs = self.scfg.block_size
        tokens = [int(t) for t in req.prompt]
        plen = len(tokens)
        hashes = hash_token_blocks(tokens, bs) if self.scfg.prefix_cache \
            else []
        # reuse covers at most plen-1 tokens: the last prompt token must be
        # recomputed so the admit has logits to sample the first output
        reusable = hashes[:max(plen - 1, 0) // bs]
        hits = self.alloc.prefix_lookup(reusable)
        n_cached_tok = len(hits) * bs
        need = -(-plen // bs) - len(hits) + 1      # +1 decode-ahead block
        if need > self.alloc.num_blocks:
            # would defer forever: the whole pool cannot hold this prompt
            raise _PromptTooLong(
                f"prompt of {plen} tokens needs {need} KV blocks but the "
                f"pool has only {self.alloc.num_blocks}: raise kv_blocks "
                f"or shorten the prompt")
        if need > self.alloc.available_excluding(hits):
            return None
        return (hashes, hits, n_cached_tok, plen - n_cached_tok)

    def _reject_oversized(self, req: Request, detail: str):
        """Fail just the unservable request — never the batch it queued
        with.  It completes empty with an explicit finish reason instead
        of raising out of ``step()`` (where a replica loop would spill
        the whole in-flight batch and re-route the poison request into
        the next replica)."""
        req.done = True
        req.finish_reason = "rejected_prompt_too_long"
        req.done_t = req.first_token_t = time.perf_counter()
        self._close_span(req)
        self.finished.append(req)
        self.metrics.counter("engine.rejected_too_long").inc()
        self._emit(req, [], True)

    def _admit_paged(self):
        scfg = self.scfg
        free = [s for s in range(scfg.slots) if self.active[s] is None]
        while free and self.queue:
            try:
                prep = self._prep_paged(self.queue[0])
            except _PromptTooLong as e:
                self._reject_oversized(self.queue.popleft(), str(e))
                continue
            if prep is None:
                # pool pressure: leave the queue intact — admission
                # headroom gating upstream keeps this rare
                self.metrics.counter("engine.admit_deferred_kv").inc()
                break
            bucket = self.fns.bucket(prep[3])
            max_admit = 1 if self.fns.row_coupled else len(free)
            # pop-and-commit one request at a time so each headroom probe
            # sees the blocks its batch-mates already claimed
            rows = []
            while prep is not None and len(rows) < max_admit and \
                    self.fns.bucket(prep[3]) == bucket:
                req = self.queue.popleft()
                hashes, hits, n_cached_tok, suffix_len = prep
                plen = len(req.prompt)
                slot = free[len(rows)]
                sid = self.alloc.new_seq()
                self.alloc.append_shared(sid, hits)
                self.alloc.extend_to(sid, plen)
                self._seq_of_slot[slot] = sid
                self._bt[slot] = padded_table(self.alloc.table(sid),
                                              self.nb_max)
                self._pos_h[slot] = plen
                self._rem_h[slot] = max(req.max_new, 0)
                self.metrics.counter("engine.prefix_hit_blocks").inc(
                    len(hits))
                # denominator of the hit rate: count the blocks actually
                # *looked up* (reuse is capped at plen-1 tokens), not the
                # prompt's full-block count — else a block-aligned prompt
                # could never reach hit_rate 1.0
                self.metrics.counter("engine.prefix_lookup_blocks").inc(
                    max(plen - 1, 0) // self.scfg.block_size)
                self.metrics.counter("engine.prefill_tokens_saved").inc(
                    n_cached_tok)
                rows.append((req, slot, sid, hashes, n_cached_tok,
                             suffix_len))
                try:
                    prep = self._prep_paged(self.queue[0]) if self.queue \
                        else None
                except _PromptTooLong:
                    # oversized next prompt: stop batching here; the head
                    # of the next admit loop rejects it individually,
                    # after this batch's extend has run
                    prep = None
            n = len(rows)
            free = free[n:]
            # pad the batch dim to a power of two (same compile-bounding
            # trick as the dense admit); pad rows duplicate row 0 and its
            # slot/table — identical values to identical addresses
            n_pad = _next_pow2(n) if n > 1 else 1
            full = [rows[0]] * (n_pad - n) + rows
            tokens = np.zeros((n_pad, bucket), np.int32)
            pos0 = np.zeros((n_pad,), np.int32)
            last_idx = np.zeros((n_pad,), np.int32)
            slot_arr = np.zeros((n_pad,), np.int32)
            budget = np.zeros((n_pad,), np.int32)
            bt = np.zeros((n_pad, self.nb_max), np.int32)
            for j, (req, slot, sid, hashes, n_cached_tok, suffix_len) in \
                    enumerate(full):
                tokens[j, :suffix_len] = req.prompt[n_cached_tok:]
                pos0[j] = n_cached_tok
                last_idx[j] = suffix_len - 1
                slot_arr[j] = slot
                budget[j] = max(req.max_new, 0)
                bt[j] = self._bt[slot]
            hit_toks = sum(r[4] for r in rows)
            asp = current_tracer().span(
                "engine.admit",
                parent=next((r[0].trace_ctx for r in rows
                             if r[0].trace_ctx is not None), None),
                bucket=bucket, n=n, n_pad=n_pad,
                rids=[r[0].rid for r in rows],
                prefix_hit_tokens=hit_toks,
                kv_blocks_free=self.alloc.free_blocks)
            current_recorder().record(
                "admit", rids=[r[0].rid for r in rows], bucket=bucket,
                n=n, prefix_hit_tokens=hit_toks)
            psp = current_tracer().span("engine.prefill", parent=asp,
                                        bucket=bucket, n_pad=n_pad)
            with annotate("prefill"):
                toks, self.caches, self._pos, self._last, self._active, \
                    self._remaining, self._rng = self.fns.paged_admit_fn(
                        bucket, n_pad)(
                        self.params, jnp.asarray(tokens), jnp.asarray(pos0),
                        jnp.asarray(last_idx), jnp.asarray(slot_arr),
                        jnp.asarray(budget), jnp.asarray(bt),
                        self.caches, self._pos, self._last,
                        self._active, self._remaining, self._rng)
                toks_h = np.asarray(toks)[n_pad - n:]
            psp.end()
            now = time.perf_counter()
            for j, (req, slot, sid, hashes, n_cached_tok, suffix_len) in \
                    enumerate(rows):
                plen = len(req.prompt)
                if scfg.prefix_cache:
                    # every *full* prompt block is now written and
                    # immutable (decode writes start at plen) — publish it
                    n_full = plen // scfg.block_size
                    self.alloc.prefix_insert(hashes[:n_full],
                                             self.alloc.table(sid)[:n_full])
                req.out_tokens.append(int(toks_h[j]))
                req.first_token_t = now
                self.active[slot] = req
                if req.max_new <= 0:
                    self._finish(slot, "max_new")
                elif plen >= scfg.max_len - 1:
                    self._finish(slot, "max_len")
                self._emit(req, req.out_tokens[-1:], req.done)
            asp.end()
            self.metrics.counter("engine.prefill_batches").inc()
            self._kv_gauges()

    def _step_paged(self) -> bool:
        self._admit_paged()
        if not any(r is not None for r in self.active):
            return False
        scfg = self.scfg
        dsp = current_tracer().span(
            "engine.decode_sync", parent=self._batch_ctx(),
            k=scfg.sync_every,
            n_active=sum(r is not None for r in self.active))
        # host pre-work: every active slot needs writable private blocks
        # covering the K positions this loop will write — allocate ahead,
        # COW any block shared with the prefix cache or a fork
        cow_src: List[int] = []
        cow_dst: List[int] = []
        for s, req in enumerate(self.active):
            if req is None:
                continue
            sid = self._seq_of_slot[s]
            lo = int(self._pos_h[s])
            # allocate ahead only for positions this loop can actually
            # write: K steps, capped by the slot's remaining budget (an
            # exhausted slot's further writes go to its frozen position
            # or the null block) and by max_len
            hi = min(lo + min(scfg.sync_every, int(self._rem_h[s])),
                     scfg.max_len)
            for src, dst in self.alloc.cow_targets(sid, lo, hi):
                cow_src.append(src)
                cow_dst.append(dst)
            try:
                self.alloc.extend_to(sid, hi)
            except PoolExhausted:
                raise PoolExhausted(
                    f"kv pool exhausted mid-decode (slot {s}, pos {lo}): "
                    f"active sequences outgrew kv_blocks="
                    f"{self.alloc.num_blocks}; size the pool for the "
                    f"workload or lower admission headroom") from None
            self._bt[s] = padded_table(self.alloc.table(sid), self.nb_max)
        if cow_src:
            pad = (_next_pow2(len(cow_src)) if len(cow_src) > 1 else 1) \
                - len(cow_src)
            src = jnp.asarray([0] * pad + cow_src, jnp.int32)
            dst = jnp.asarray([0] * pad + cow_dst, jnp.int32)
            self.caches = self.fns.cow(self.caches, src, dst)
            self.metrics.counter("engine.kv_cow_copies").inc(len(cow_src))
            dsp.tag(cow_copies=len(cow_src))
            current_recorder().record("cow", n=len(cow_src))
        with annotate("decode_loop"):
            out, emitted, self.caches, self._pos, self._last, self._active, \
                self._remaining, self._rng = self.fns.paged_decode_loop(
                    self.params, jnp.asarray(self._bt), self.caches,
                    self._pos, self._last, self._active, self._remaining,
                    self._rng)
            hsp = current_tracer().span("engine.host_sync", parent=dsp)
            out_h = np.asarray(out)
            em_h = np.asarray(emitted)
            act_h = np.asarray(self._active)
            rem_h = np.asarray(self._remaining)
            self._pos_h = np.asarray(self._pos).astype(np.int64)
            self._rem_h = rem_h.astype(np.int64)
            hsp.end()
        esp = current_tracer().span("engine.stream_emit", parent=dsp) \
            if any(r is not None and r.on_tokens is not None
                   for r in self.active) else NULL_SPAN
        for s, req in enumerate(self.active):
            if req is None:
                continue
            new = [int(t) for t in out_h[s, :em_h[s]]]
            req.out_tokens.extend(new)
            if not act_h[s]:
                self._finish(s, "max_new" if rem_h[s] <= 0 else "max_len")
            self._emit(req, new, req.done)
        esp.end()
        dsp.end()
        self.metrics.counter("engine.steps").inc()
        self._kv_gauges()
        return True

    def fork(self, parent: Request, max_new: int,
             on_tokens: Optional[Callable] = None) -> Request:
        """Branch an *active* request into a new session that shares all
        of its KV blocks copy-on-write (parallel sampling / n-best).  The
        child continues from the parent's current position; its blocks
        stay shared until either side writes (then `cow_targets` splits
        exactly the written block).  Paged engines only; needs a free
        slot."""
        if not self.paged:
            raise RuntimeError("fork requires a paged engine "
                               "(ServeConfig.paged=True on a supported "
                               "family)")
        try:
            pslot = next(s for s, r in enumerate(self.active)
                         if r is parent)
        except StopIteration:
            raise ValueError(f"request {parent.rid} is not active "
                             f"(finished or still queued)") from None
        try:
            slot = next(s for s, r in enumerate(self.active) if r is None)
        except StopIteration:
            raise RuntimeError("no free slot to fork into") from None
        child = Request(rid=next(self._rids), prompt=parent.prompt.copy(),
                        max_new=max_new,
                        out_tokens=list(parent.out_tokens),
                        submit_t=time.perf_counter(), on_tokens=on_tokens)
        child.first_token_t = child.submit_t
        sid = self.alloc.fork(self._seq_of_slot[pslot])
        self._seq_of_slot[slot] = sid
        self._bt[slot] = padded_table(self.alloc.table(sid), self.nb_max)
        self._pos_h[slot] = self._pos_h[pslot]
        self._rem_h[slot] = max(max_new, 0)
        pos = int(self._pos_h[pslot])
        last_tok = parent.out_tokens[-1] if parent.out_tokens else 0
        alive = max_new > 0 and pos < self.scfg.max_len - 1
        self._pos = self._pos.at[slot].set(pos)
        self._last = self._last.at[slot].set(last_tok if alive else 0)
        self._remaining = self._remaining.at[slot].set(max(max_new, 0))
        self._active = self._active.at[slot].set(alive)
        self.active[slot] = child
        self.metrics.counter("engine.forks").inc()
        if not alive:
            self._finish(slot, "max_new" if max_new <= 0 else "max_len")
        self._kv_gauges()
        return child

    # ------------------------------------------------------------------
    # reference path: the pre-PR per-token loop (parity oracle / "before"
    # benchmark side); one host round trip + (slots, vocab) logits transfer
    # per token, full cache copy per step and per admit.
    def _admit_reference(self):
        for slot in range(self.scfg.slots):
            if self.active[slot] is None and self.queue:
                req = self.queue.popleft()
                plen = len(req.prompt)
                logits, small = self.fns.prefill_fn(plen)(
                    self.params, jnp.asarray(req.prompt[None]))
                self.caches = _insert_slot(self.caches, small, slot)
                tok = int(jnp.argmax(logits[0, -1]))
                req.out_tokens.append(tok)
                req.first_token_t = time.perf_counter()
                self.active[slot] = req
                self.pos[slot] = plen                 # next write position
                if req.max_new <= 0:
                    self._finish(slot, "max_new")
                elif plen >= self.scfg.max_len - 1:
                    self._finish(slot, "max_len")
                self._emit(req, req.out_tokens[-1:], req.done)

    def _step_reference(self) -> bool:
        self._admit_reference()
        if not any(r is not None for r in self.active):
            return False
        toks = np.zeros((self.scfg.slots, 1), np.int32)
        for s, req in enumerate(self.active):
            if req is not None:
                toks[s, 0] = req.out_tokens[-1]
        logits, self.caches = self.fns.decode(self.params, jnp.asarray(toks),
                                              self.caches,
                                              jnp.asarray(self.pos))
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        for s, req in enumerate(self.active):
            if req is None:
                continue
            self.pos[s] += 1
            req.out_tokens.append(int(nxt[s]))
            if req.decoded >= req.max_new:
                self._finish(s, "max_new")
            elif self.pos[s] >= self.scfg.max_len - 1:
                self._finish(s, "max_len")
            self._emit(req, req.out_tokens[-1:], req.done)
        self.metrics.counter("engine.steps").inc()
        return True

    # ------------------------------------------------------------------
    def step(self):
        """One engine iteration: admit, then decode — a single step on the
        reference path, ``sync_every`` fused steps (one host sync) on the
        fused and paged paths."""
        if self.paged:
            return self._step_paged()
        if self.scfg.fused:
            return self._step_fused()
        return self._step_reference()

    def run_until_drained(self, max_steps: int = 10_000):
        steps = 0
        while (self.queue or any(r is not None for r in self.active)) \
                and steps < max_steps:
            self.step()
            steps += 1
        return self.finished
