"""Message channels and the worker handshake listener.

The transport layer (``cluster/transport.py``) speaks to a replica worker
through a :class:`Channel`: a bidirectional, message-oriented pipe carrying
the frames produced by ``encode_frame``/``decode_frame``.  Two carriers:

  * :class:`PipeChannel`   — a ``multiprocessing.Connection`` duplex pipe
    (the process transport: parent and worker share a host).
  * :class:`SocketChannel` — a TCP stream with 4-byte big-endian
    length-prefixed frames (the socket transport: the worker may live on
    any host that can reach the listener).

Both raise :class:`ChannelClosed` (an ``OSError``) on a broken carrier, so
callers handle pipe EOF and TCP resets identically.

:class:`WorkerListener` is the parent-side accept loop for socket workers.
A connecting worker opens the conversation with a versioned *hello* frame::

    ("hello", PROTOCOL_VERSION, token, kind | None, spec_hash | None)

The listener rejects protocol-version mismatches and unknown tokens with a
``("reject", reason)`` frame, and otherwise routes the connection — first
contact or reconnect — to the :class:`~repro.cluster.transport.
SocketTransport` registered under that token, which continues the
handshake (spec-hash check, ``("welcome", ...)`` reply).
"""
from __future__ import annotations

import select
import socket
import struct
import threading
import time
from typing import Any, Callable, Dict, Optional, Tuple

from repro.cluster.framing import decode_frame, encode_frame, msgpack

# Bump when hello/welcome/tag semantics change: a worker built from an
# older checkout must be refused at the door, not fail mid-request.
# v2: drain-time ("kv_state", state) frame — warm KV migration hand-off.
#
# Still v2 (backward/forward compatible additions, same door):
#   * ("req", rid, cost, payload, tctx[, budget]) — element 5 is an
#     optional *relative* deadline budget in seconds (monotonic clocks do
#     not cross hosts; the worker pins an absolute deadline at ingest).
#     Old workers ignore the extra element; old parents omit it.
#   * ("cancel", rid) and ("brownout", level) parent->worker control
#     frames — WorkerIO drops unknown tags, so old workers skip them.
PROTOCOL_VERSION = 2

# Bounds a malicious or corrupted length word before we try to allocate
# it.  Note this is also the practical cap on a single artifact transfer
# (fetch replies are one frame; see ROADMAP for chunked transfer).
MAX_FRAME_BYTES = 1 << 31
# Before a peer has presented a known worker token it gets a hello-sized
# budget and — when msgpack is available — no pickle decoding at all:
# ``pickle.loads`` on unauthenticated bytes is remote code execution.
UNTRUSTED_FRAME_BYTES = 1 << 16

_LEN = struct.Struct(">I")


class ChannelClosed(OSError):
    """The carrier under a channel is gone (EOF, reset, closed twice)."""


def _decode_or_close(frame: bytes, allow_pickle: bool = True):
    """A peer that sends an undecodable frame is indistinguishable from a
    corrupt/hostile connection: treat it as closed, never let the decode
    error escape into a receive loop.  With ``allow_pickle=False`` a
    pickle-tagged frame is refused outright (pre-authentication, pickle ==
    arbitrary code execution)."""
    if not allow_pickle and frame[:1] == b"P":
        raise ChannelClosed("pickle frame before authentication")
    try:
        return decode_frame(frame)
    except Exception as e:              # noqa: BLE001 - any decode failure
        raise ChannelClosed(f"undecodable frame: {e!r}") from e


class Channel:
    """Message-oriented duplex channel of ``encode_frame`` payloads."""

    def send(self, obj: Any, pickle_only: bool = False) -> None:
        raise NotImplementedError

    def send_bytes(self, frame: bytes) -> None:
        raise NotImplementedError

    def recv(self, timeout: float) -> Optional[Any]:
        """Next message, or ``None`` if nothing arrived within ``timeout``.
        Raises :class:`ChannelClosed` when the carrier is gone."""
        raise NotImplementedError

    def close(self) -> None:
        raise NotImplementedError


class PipeChannel(Channel):
    """A ``multiprocessing.Connection`` wrapped to the Channel surface."""

    def __init__(self, conn):
        self.conn = conn
        self._send_lock = threading.Lock()

    def send(self, obj: Any, pickle_only: bool = False) -> None:
        self.send_bytes(encode_frame(obj, pickle_only))

    def send_bytes(self, frame: bytes) -> None:
        try:
            with self._send_lock:
                self.conn.send_bytes(frame)
        except (OSError, ValueError, EOFError) as e:
            raise ChannelClosed(str(e)) from e

    def recv(self, timeout: float) -> Optional[Any]:
        try:
            if not self.conn.poll(timeout):
                return None
            buf = self.conn.recv_bytes()
        except (EOFError, OSError) as e:
            raise ChannelClosed(str(e)) from e
        return _decode_or_close(buf)

    def close(self) -> None:
        try:
            self.conn.close()
        except OSError:
            pass


class SocketChannel(Channel):
    """Length-prefixed ``encode_frame`` frames over a TCP stream.

    Wire format: ``>I`` byte length, then the frame (tag byte + body).
    Reads buffer partial frames across calls, so a ``recv`` timeout never
    corrupts framing.
    """

    def __init__(self, sock: socket.socket, trusted: bool = True):
        self.sock = sock
        self.sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._send_lock = threading.Lock()
        self._recv_lock = threading.Lock()
        self._buf = bytearray()
        self._closed = False
        self._trusted = trusted

    def trust(self) -> None:
        """Lift the pre-authentication restrictions (pickle ban + small
        frame budget) once the peer presented a known worker token."""
        self._trusted = True

    def send(self, obj: Any, pickle_only: bool = False) -> None:
        self.send_bytes(encode_frame(obj, pickle_only))

    def send_bytes(self, frame: bytes) -> None:
        try:
            with self._send_lock:
                self.sock.sendall(_LEN.pack(len(frame)) + frame)
        except struct.error as e:       # > 4 GiB: length prefix overflow
            raise ChannelClosed(
                f"frame too large for the wire ({len(frame)} bytes)") from e
        except OSError as e:
            raise ChannelClosed(str(e)) from e

    def _parse_frame(self) -> Optional[bytes]:
        if len(self._buf) < _LEN.size:
            return None
        (n,) = _LEN.unpack_from(self._buf)
        limit = MAX_FRAME_BYTES if self._trusted else UNTRUSTED_FRAME_BYTES
        if n > limit:
            raise ChannelClosed(f"oversized frame ({n} bytes)")
        if len(self._buf) < _LEN.size + n:
            return None
        frame = bytes(self._buf[_LEN.size:_LEN.size + n])
        del self._buf[:_LEN.size + n]
        return frame

    def recv(self, timeout: float) -> Optional[Any]:
        # readiness via select, not settimeout: the timeout must never
        # leak onto a concurrent send() sharing this socket
        with self._recv_lock:
            frame = self._parse_frame()
            while frame is None:
                if self._closed:
                    raise ChannelClosed("channel closed")
                try:
                    ready, _, _ = select.select([self.sock], [], [], timeout)
                    if not ready:
                        return None
                    chunk = self.sock.recv(1 << 16)
                except (OSError, ValueError) as e:
                    raise ChannelClosed(str(e)) from e
                if not chunk:
                    raise ChannelClosed("EOF")
                self._buf.extend(chunk)
                frame = self._parse_frame()
                # after the first chunk, consume only what is already
                # buffered so one recv() call never blocks on the wire twice
                if frame is None:
                    timeout = 0.0
        # msgpack missing means even hello frames arrive pickled: a
        # degraded single-trust-domain mode, not the multi-host posture
        return _decode_or_close(frame,
                                allow_pickle=self._trusted or msgpack is None)

    def close(self) -> None:
        self._closed = True
        try:
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self.sock.close()
        except OSError:
            pass


def connect_channel(address: Tuple[str, int],
                    timeout: float = 5.0) -> SocketChannel:
    """Dial a listener; raises ``OSError`` while it is unreachable."""
    sock = socket.create_connection(address, timeout=timeout)
    sock.settimeout(None)
    return SocketChannel(sock)


# ----------------------------------------------------------------------
class WorkerListener:
    """Accepts socket-worker connections and routes them by token.

    One listener serves every :class:`SocketTransport` in the process;
    transports ``register(token, adopt)`` and the listener completes the
    version half of the handshake before handing the channel (plus the
    decoded hello) to the transport's ``adopt`` callback.
    """

    def __init__(self, host: str = "127.0.0.1", port: int = 0,
                 handshake_timeout_s: float = 5.0):
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(64)
        self.address: Tuple[str, int] = self._sock.getsockname()[:2]
        self.handshake_timeout_s = handshake_timeout_s
        self._handlers: Dict[str, Callable[[SocketChannel, tuple], None]] = {}
        self._lock = threading.Lock()
        self._closed = threading.Event()
        self._thread = threading.Thread(target=self._accept_loop, daemon=True,
                                        name="worker-listener")
        self._thread.start()

    def register(self, token: str,
                 adopt: Callable[[SocketChannel, tuple], None]) -> None:
        with self._lock:
            self._handlers[token] = adopt

    def unregister(self, token: str) -> None:
        with self._lock:
            self._handlers.pop(token, None)

    # -- accept path -----------------------------------------------------
    def _accept_loop(self) -> None:
        while not self._closed.is_set():
            try:
                sock, _addr = self._sock.accept()
            except OSError:
                return                      # listener closed
            threading.Thread(target=self._handshake, args=(sock,),
                             daemon=True, name="worker-handshake").start()

    def _handshake(self, sock: socket.socket) -> None:
        # untrusted until the token checks out: no pickle decoding, small
        # frame budget — an unauthenticated peer must not reach
        # pickle.loads or allocate gigabytes
        chan = SocketChannel(sock, trusted=False)
        try:
            # loop, don't single-shot: the hello may arrive in several TCP
            # segments, and one recv() call only blocks on the wire once
            t_end = time.monotonic() + self.handshake_timeout_s
            hello = None
            try:
                while hello is None and time.monotonic() < t_end:
                    hello = chan.recv(min(0.2, self.handshake_timeout_s))
            except ChannelClosed as e:
                if "pickle frame" in str(e):
                    # a legitimate worker on a msgpack-less host would
                    # fall back to pickle hellos; tell it why it is being
                    # refused instead of ghosting (sending is still safe —
                    # only *decoding* untrusted pickle is not)
                    chan.send(("reject",
                               "pickle hello refused before authentication"
                               " — install msgpack on the worker host"))
                chan.close()
                return
            if hello is None:
                chan.close()
                return
            if (not isinstance(hello, (tuple, list)) or len(hello) < 5
                    or hello[0] != "hello"):
                chan.send(("reject", "malformed hello"))
                chan.close()
                return
            _tag, version, token, _kind, _spec_hash = hello[:5]
            if version != PROTOCOL_VERSION:
                chan.send(("reject",
                           f"protocol version {version} != "
                           f"{PROTOCOL_VERSION}"))
                chan.close()
                return
            with self._lock:
                adopt = self._handlers.get(token)
            if adopt is None:
                chan.send(("reject", f"unknown worker token {token!r}"))
                chan.close()
                return
        except (ChannelClosed, OSError):
            chan.close()
            return
        chan.trust()
        adopt(chan, tuple(hello))

    def close(self) -> None:
        self._closed.set()
        try:
            self._sock.close()
        except OSError:
            pass
        self._thread.join(timeout=2.0)
