"""Multi-replica serving cluster: the layer that turns one engine/stream
into a service for "evergrowing user bases" (paper §1/§3).

    Router (dispatch policies)  ->  N x ReplicaWorker (bounded inboxes)
      ^ admission control              each owning one backend:
      ^ autoscaler                     LM Engine | SVM stream | step fn
      v unified MetricsRegistry across every component

Layering: ``repro.core.service``/``repro.core.stream`` import the leaf
modules here (metrics, admission), so cluster modules must not import
``repro.core.service``/``repro.core.stream`` back — backends are passed in
as objects (see ``replica.StreamBackend``) precisely to keep this acyclic.
"""
from repro.cluster.admission import (AdmissionConfig,  # noqa: F401
                                     AdmissionController, Rejected,
                                     deadline_slack)
from repro.cluster.autoscaler import (Autoscaler, AutoscalerConfig,  # noqa: F401
                                      ScaleEvent)
from repro.cluster.metrics import (Counter, Gauge, Histogram,  # noqa: F401
                                   MetricsRegistry)
from repro.cluster.replica import (ClusterRequest, EngineBackend,  # noqa: F401
                                   FnBackend, ReplicaConfig, ReplicaCrash,
                                   ReplicaWorker, Status, StreamBackend)
from repro.cluster.router import POLICIES, Router  # noqa: F401
