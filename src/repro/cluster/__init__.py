"""Multi-replica serving cluster: the layer that turns one engine/stream
into a service for "evergrowing user bases" (paper §1/§3).

    Router (dispatch policies)  ->  N x Transport (bounded inboxes)
      ^ admission control              thread replica (LocalTransport) or
      ^ autoscaler                     worker process w/ RPC inbox
      v unified MetricsRegistry        (ProcessTransport), each owning one
        (+ worker-side snapshots)      backend: LM Engine | SVM stream | fn

Layering: ``repro.core.service``/``repro.core.stream`` import the leaf
modules here (metrics, admission), so cluster modules must not import
``repro.core.service``/``repro.core.stream`` back — backends are passed in
as objects (``replica.StreamBackend``) or rebuilt from a serializable
``backends.BackendSpec`` inside worker processes, precisely to keep this
acyclic.
"""
from repro.cluster.admission import (AdmissionConfig,  # noqa: F401
                                     AdmissionController, Rejected,
                                     deadline_slack)
from repro.cluster.artifacts import (ArtifactStore, artifact_ref,  # noqa: F401
                                     fetch_with_retry, resolve_spec,
                                     spec_fingerprint)
from repro.cluster.autoscaler import (Autoscaler, AutoscalerConfig,  # noqa: F401
                                      ScaleEvent)
from repro.cluster.backends import (BackendSpec, echo_spec,  # noqa: F401
                                    engine_spec, stream_spec)
from repro.cluster.metrics import (Counter, Gauge, Histogram,  # noqa: F401
                                   MetricsRegistry, merge_snapshots)
from repro.cluster.overload import (BreakerConfig, BrownoutConfig,  # noqa: F401
                                    BrownoutController, CircuitBreaker)
from repro.cluster.replica import (ClusterRequest, EngineBackend,  # noqa: F401
                                   FnBackend, ReplicaConfig, ReplicaCrash,
                                   Status, StreamBackend, Terminal,
                                   WaitTimeout)
from repro.cluster.dashboard import (StatsServer, render_dash,  # noqa: F401
                                     render_watch)
from repro.cluster.router import POLICIES, Router  # noqa: F401
from repro.cluster.slo import (BurnWindow, SLOEngine,  # noqa: F401
                               SLOObjective, test_scaled_objective)
from repro.cluster.timeseries import (EwmaRate, StageAttributor,  # noqa: F401
                                      TelemetrySampler, TimeSeriesStore)
from repro.cluster.tracing import (FlightRecorder, Span,  # noqa: F401
                                   TraceContext, Tracer, current_recorder,
                                   current_tracer, prometheus_text,
                                   set_recorder, set_tracer,
                                   to_chrome_trace)
from repro.cluster.transport import (TRANSPORTS, LocalTransport,  # noqa: F401
                                     ProcessTransport, ReplicaWorker,
                                     SocketTransport, Transport,
                                     default_listener, make_transport,
                                     set_flight_store, default_flight_store)
from repro.cluster.wire import (PROTOCOL_VERSION, WorkerListener)  # noqa: F401
