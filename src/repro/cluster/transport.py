"""Replica transports: where a replica runs and how requests reach it.

The router, autoscaler and metrics speak to replicas only through the
:class:`Transport` surface (submit/ack/spill/heartbeat over a bounded
inbox), so worker *placement* is pluggable:

  * :class:`LocalTransport`  — the replica driver on a host thread over a
    ``queue.Queue`` inbox.  Threads share one JAX runtime: weights are
    zero-copy, but device FLOPs do not scale beyond one client.
  * :class:`ProcessTransport` — a spawned worker subprocess with an RPC
    inbox over a duplex pipe; crash detection is by process liveness.
    Each worker owns an independent Python interpreter and JAX runtime.
  * :class:`SocketTransport`  — the same worker behind a framed TCP
    connection (``cluster/wire.py``), so the worker may live on *any*
    host: the paper's worker nodes, finally network-transparent.  The
    worker dials the parent's :class:`~repro.cluster.wire.WorkerListener`
    and completes a versioned (re)connect handshake (token, kind,
    ``BackendSpec`` fingerprint); weights resolve through a
    content-addressed artifact store (``cluster/artifacts.py``).  Crash
    detection is by *heartbeat timeout*, not process liveness — the
    parent may not own the worker's process.  A dropped connection spills
    every unacknowledged request immediately (zero lost) while the
    transport stays in the pool for a reconnect window, so a network blip
    costs a requeue, not a replica.

All transports implement the same at-least-once contract: every request
is either acknowledged exactly once or spilled back to ``on_spill`` for
redispatch; none are lost.  The in-replica loop is shared
(:func:`repro.cluster.replica.run_replica_loop`) and the parent-side
bookkeeping for both remote transports is shared too
(:class:`RemoteTransport`): the outstanding-request table, ack/heartbeat
dispatch, and the die/spill path are one implementation, with the process
and socket classes supplying only their carrier (pipe vs. TCP channel)
and their death detector (liveness vs. heartbeat timeout).

Remote workers are rebuilt from a :class:`~repro.cluster.backends.
BackendSpec` (config + weights path or ``artifact:<sha256>`` reference),
never from live objects — the only things that cross a process or host
boundary are picklable.
"""
from __future__ import annotations

import itertools
import json
import multiprocessing as mp
import os
import queue
import threading
import time
import uuid
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.cluster.artifacts import ArtifactStore, spec_fingerprint
from repro.cluster.backends import BackendSpec
from repro.cluster.framing import decode_frame, encode_frame  # noqa: F401
# (re-exported: the framed wire protocol predates cluster/framing.py)
from repro.cluster.metrics import MetricsRegistry, null_registry
from repro.cluster.replica import (ClusterRequest, ReplicaConfig,
                                   ReplicaCrash, run_replica_loop)
from repro.cluster.tracing import (FlightRecorder, TraceContext, Tracer,
                                   current_recorder, current_tracer,
                                   set_recorder, set_tracer)
from repro.cluster.wire import (Channel, ChannelClosed, PipeChannel,
                                WorkerListener)

TRANSPORTS = ("thread", "process", "socket")

OnSpill = Callable[[List[ClusterRequest], "Transport"], None]


# ----------------------------------------------------------------------
# Flight-recorder dumps land in an artifact store so a chaos postmortem
# can pull them by digest after the process that crashed is gone.  The
# default store is process-wide (shared tempdir root); tests and serve
# wiring may install their own.

_flight_store: Optional[ArtifactStore] = None
_flight_store_lock = threading.Lock()


def set_flight_store(store: Optional[ArtifactStore]) -> None:
    global _flight_store
    with _flight_store_lock:
        _flight_store = store


def default_flight_store() -> ArtifactStore:
    global _flight_store
    with _flight_store_lock:
        if _flight_store is None:
            _flight_store = ArtifactStore()
        return _flight_store


# ----------------------------------------------------------------------
class Transport:
    """What the router/autoscaler may assume about a replica.

    Lifecycle: ``start()`` -> ``offer()`` x N -> ``drain()`` (graceful) or
    ``inject_crash()`` (fault).  A dead transport spills every
    unacknowledged request to ``on_spill`` exactly once.
    """

    _ids = itertools.count()

    def __init__(self, cfg: ReplicaConfig, rid: Optional[int] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 on_spill: Optional[OnSpill] = None, kind: str = "fn"):
        self.rid = next(Transport._ids) if rid is None else rid
        self.cfg = cfg
        self.metrics = metrics if metrics is not None else null_registry()
        self.on_spill = on_spill
        self.kind = kind
        self.alive = False
        self.heartbeat_s = 0.0
        self.started_s = 0.0
        self.busy_s = 0.0
        self.processed = 0
        # tracing: live "transport.inflight" spans keyed by request rid
        # (offer -> ack/spill), and digests of flight-recorder dumps this
        # transport wrote to the artifact store on death
        self._inflight_spans: Dict[int, Any] = {}
        self.flight_dumps: List[str] = []
        # warm KV migration: the backend's drain-time export, published
        # by the replica driver just before the drained signal.  The
        # router reads this after drain() returns and ships it to the
        # drained sessions' new homes; None = nothing to migrate.
        self.kv_state: Any = None

    # -- control surface -------------------------------------------------
    def start(self) -> "Transport":
        raise NotImplementedError

    def offer(self, req: ClusterRequest) -> bool:
        """Enqueue; False == backpressure (inbox full / replica down)."""
        raise NotImplementedError

    def outstanding_cost(self) -> int:
        raise NotImplementedError

    def inject_crash(self) -> None:
        raise NotImplementedError

    def drain(self, timeout: float = 10.0) -> None:
        raise NotImplementedError

    def join(self, timeout: float = 10.0) -> None:
        raise NotImplementedError

    # -- health / telemetry ----------------------------------------------
    def healthy(self, now: Optional[float] = None) -> bool:
        now = time.monotonic() if now is None else now
        return self.alive and \
            now - self.heartbeat_s < self.cfg.heartbeat_timeout_s

    def busy_fraction(self) -> float:
        wall = time.monotonic() - self.started_s
        return self.busy_s / wall if wall > 0 else 0.0

    def metrics_snapshot(self) -> Dict[str, float]:
        """Worker-side metrics.  Local replicas write into the shared
        registry directly, so their snapshot is empty; remote replicas
        return the last heartbeat's registry snapshot."""
        return {}

    def _record_crash(self, n_spilled: int) -> None:
        self.metrics.counter("replica.crashes").inc()
        self.metrics.counter("replica.spilled_requests").inc(n_spilled)

    # -- tracing helpers --------------------------------------------------
    def _span_inflight(self, req: ClusterRequest) -> None:
        """Open a transport.inflight span (offer -> ack/spill) when the
        request carries a trace context.  Callers hold ``self._lock`` —
        the tracer lock is a leaf, so nesting is safe."""
        if req.trace_ctx is None:
            return
        self._inflight_spans[req.rid] = current_tracer().span(
            "transport.inflight", parent=req.trace_ctx,
            replica=self.rid, transport=type(self).__name__,
            kind=self.kind)

    def _end_inflight(self, rid: int, **tags) -> None:
        sp = self._inflight_spans.pop(rid, None)
        if sp is not None:
            if tags:
                sp.tag(**tags)
            sp.end()

    def _dump_flight(self, reason: str,
                     worker_events: Sequence = ()) -> Optional[str]:
        """Postmortem: write the merged flight-recorder event log (parent
        ring + the worker increments mirrored off heartbeats) to the
        artifact store.  Must never raise — it runs on fault paths."""
        try:
            store = getattr(self, "artifacts", None) or default_flight_store()
            doc = {"rid": self.rid, "kind": self.kind, "reason": reason,
                   "wall": time.time(),
                   "parent_events": current_recorder().events(),
                   "worker_events": list(worker_events)}
            digest = store.put_bytes(
                json.dumps(doc, sort_keys=True, default=str).encode())
            self.flight_dumps.append(digest)
            self.metrics.counter("replica.flight_dumps").inc()
            return digest
        except Exception:               # noqa: BLE001 - telemetry must not
            return None                 # take down the fault path itself


# ----------------------------------------------------------------------
class LocalTransport(Transport):
    """The replica driver on a host thread with a ``queue.Queue`` inbox.

    Behavior-preserving port of PR 1's ``ReplicaWorker`` (which remains as
    an alias): same offer/crash/drain races, same straggler handling.
    """

    def __init__(self, backend, cfg: ReplicaConfig = ReplicaConfig(),
                 rid: Optional[int] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 on_spill: Optional[OnSpill] = None, kind: str = "fn"):
        super().__init__(cfg, rid=rid, metrics=metrics, on_spill=on_spill,
                         kind=kind)
        self.backend = backend
        self.inbox: "queue.Queue[ClusterRequest]" = \
            queue.Queue(maxsize=cfg.inbox_capacity)
        self._lock = threading.Lock()
        self._outstanding_cost = 0
        self._crash = threading.Event()
        self._closing = threading.Event()
        self._brownout_level = 0
        self._hist = self.metrics.histogram("replica.batch_s")
        self._thread = threading.Thread(
            target=run_replica_loop, args=(backend, cfg, self),
            daemon=True, name=f"replica-{self.rid}")

    # -- control surface -------------------------------------------------
    def start(self) -> "LocalTransport":
        self.alive = True
        self.started_s = self.heartbeat_s = time.monotonic()
        self._thread.start()
        return self

    def offer(self, req: ClusterRequest) -> bool:
        if not self.alive or self._closing.is_set():
            return False
        try:
            self.inbox.put_nowait(req)
        except queue.Full:
            return False
        with self._lock:
            self._outstanding_cost += req.cost
            self._span_inflight(req)
        if not self.alive:
            # Raced with a concurrent crash: the dying thread may already
            # have drained the inbox, so reclaim whatever is left ourselves
            # and report failure — the caller re-dispatches elsewhere.
            leftovers: List[ClusterRequest] = []
            while True:
                try:
                    leftovers.append(self.inbox.get_nowait())
                except queue.Empty:
                    break
            with self._lock:
                self._outstanding_cost -= sum(r.cost for r in leftovers)
                for r in leftovers:
                    self._end_inflight(r.rid, aborted=True)
            others = [r for r in leftovers if r is not req]
            if others and self.on_spill is not None:
                self.on_spill(others, self)
            return False
        return True

    def outstanding_cost(self) -> int:
        with self._lock:
            return self._outstanding_cost

    def inject_crash(self) -> None:
        """Fault injection: the worker dies at its next loop checkpoint and
        spills all unacknowledged requests."""
        self._crash.set()

    def drain(self, timeout: float = 10.0) -> None:
        """Graceful: stop accepting, finish the inbox, exit."""
        self._closing.set()
        self._thread.join(timeout)

    def join(self, timeout: float = 10.0) -> None:
        self._thread.join(timeout)

    # -- driver inbox IO (run_replica_loop callbacks) --------------------
    def heartbeat(self) -> None:
        self.heartbeat_s = time.monotonic()

    def crash_requested(self) -> bool:
        return self._crash.is_set()

    def closing(self) -> bool:
        return self._closing.is_set()

    def get(self, timeout: float) -> ClusterRequest:
        return self.inbox.get(timeout=timeout)

    def get_nowait(self) -> ClusterRequest:
        return self.inbox.get_nowait()

    @staticmethod
    def payload(req: ClusterRequest) -> Any:
        return req.payload

    @staticmethod
    def trace_ctx(req: ClusterRequest) -> Any:
        """Same process: the driver reads the context straight off the
        request (remote transports rehydrate it from the wire frame)."""
        return req.trace_ctx

    @staticmethod
    def deadline(req: ClusterRequest) -> Any:
        """Same process, same monotonic clock: the absolute deadline is
        readable straight off the request (None when unbounded)."""
        dl = req.deadline_s
        return dl if dl != float("inf") else None

    @staticmethod
    def is_cancelled(req: ClusterRequest) -> bool:
        """Shared object: ``Router.cancel`` already flipped the flag."""
        return req.cancelled

    def cancel(self, rid: int) -> None:
        """No frame needed — cancellation travels through the shared
        ``ClusterRequest.cancelled`` flag the loop polls."""

    def brownout(self) -> int:
        return self._brownout_level

    def set_brownout(self, level: int) -> None:
        self._brownout_level = int(level)

    def begin(self, batch: List[ClusterRequest]) -> None:
        pass            # the driver hands the in-flight batch to spill()

    def publish_kv_state(self, state: Any) -> None:
        """Drain-time KV hand-off — same process, direct hand-over."""
        self.kv_state = state

    @staticmethod
    def emit(req: ClusterRequest, frame: Any) -> None:
        """Streaming: a partial-result frame for an in-flight request —
        same process, so it goes straight to the request."""
        req.emit_partial(frame)

    def ack(self, batch: List[ClusterRequest], results: List[Any],
            busy_s: float) -> None:
        self.busy_s += busy_s
        self._hist.observe(busy_s)
        done_cost = 0
        for r, res in zip(batch, results):
            with self._lock:
                self._end_inflight(r.rid)
            r.complete(res, self.rid)
            done_cost += r.cost
            self.processed += 1
        with self._lock:
            self._outstanding_cost -= done_cost

    def spill(self, batch: List[ClusterRequest], error: BaseException) -> None:
        """Crash path: mark dead, spill in-flight + inbox to the router."""
        self.alive = False
        spilled = list(batch)
        # Two drain passes with a grace gap: an `offer` that read `alive`
        # just before we flipped it may still land a request (offer's own
        # post-put check is the second line of defence).
        for _ in range(2):
            while True:
                try:
                    spilled.append(self.inbox.get_nowait())
                except queue.Empty:
                    break
            time.sleep(0.005)
        with self._lock:
            self._outstanding_cost = 0
            for r in spilled:
                self._end_inflight(r.rid, spilled=True)
        self._record_crash(len(spilled))
        current_recorder().record("replica_death", replica=self.rid,
                                  spilled=len(spilled), error=repr(error))
        if spilled:
            current_recorder().record("spill", replica=self.rid,
                                      rids=[r.rid for r in spilled])
        self._dump_flight(repr(error))
        if self.on_spill is not None:
            self.on_spill(spilled, self)
        else:
            for r in spilled:
                r.fail(error)

    def close(self) -> None:
        # Graceful exit: refuse new offers first, then finish any request
        # that raced into the inbox between the final empty poll and the
        # flip (offer's post-put aliveness re-check closes the rest of the
        # window by reclaiming and re-dispatching).
        self.alive = False
        time.sleep(self.cfg.poll_s)
        stragglers: List[ClusterRequest] = []
        while True:
            try:
                stragglers.append(self.inbox.get_nowait())
            except queue.Empty:
                break
        if stragglers:
            try:
                results = self.backend.process([r.payload for r in stragglers])
                for r, res in zip(stragglers, results):
                    r.complete(res, self.rid)
                    self.processed += 1
            except BaseException as e:
                if self.on_spill is not None:
                    self.on_spill(stragglers, self)
                else:
                    for r in stragglers:
                        r.fail(e)
        with self._lock:
            self._outstanding_cost = 0
            for rid in list(self._inflight_spans):
                self._end_inflight(rid)


# ----------------------------------------------------------------------
# Worker side, shared by the process and socket transports.

class WorkerIO:
    """Driver inbox IO inside a remote worker: work items are
    ``(rid, cost, payload, trace_ctx)`` tuples received over the channel;
    acks, heartbeats, metrics snapshots, trace spans and flight-recorder
    increments are shipped back.

    A dedicated reader thread pumps the channel into ``pending``
    continuously, so the parent's sends never back up behind a long
    ``backend.process`` call — ``offer()`` on the parent side stays
    non-blocking even when payloads exceed the OS transport buffer.

    With ``heartbeat_thread=True`` (socket workers) a second thread sends
    heartbeats on the wire every ``heartbeat_interval_s`` even while the
    replica loop is deep inside a long batch — the parent's only death
    signal is heartbeat staleness, so the worker must stay audibly alive
    through a minutes-long compile."""

    def __init__(self, chan: Channel, cfg: ReplicaConfig, rid: int,
                 registry: MetricsRegistry, heartbeat_thread: bool = False,
                 backlog: Optional[List[Any]] = None):
        self.chan = chan
        self.cfg = cfg
        self.rid = rid
        self.registry = registry
        self._hist = registry.histogram("replica.batch_s")
        self.pending: "queue.Queue[Tuple[int, int, Any, Any]]" = queue.Queue()
        self.cancelled: set = set()     # rids cancelled by the parent
        self._brownout = 0              # parent's current degradation level
        self._evt_seq = 0       # last flight-recorder seq shipped on a hb
        self.disconnected = False
        self.crashed = False
        self._crash = False
        self._closing = False
        self._last_hb = 0.0
        self.processed = 0
        self.busy_s = 0.0
        self._stop_hb = threading.Event()
        # frames read off the channel before this IO existed (e.g. control
        # frames that arrived while the artifact fetch loop owned the
        # connection) are replayed first, in arrival order
        for msg in (backlog or []):
            self._ingest(msg)
        self._reader = threading.Thread(target=self._pump_loop, daemon=True,
                                        name=f"replica-{rid}-pump")
        self._reader.start()
        self._hb_thread: Optional[threading.Thread] = None
        if heartbeat_thread:
            self._hb_thread = threading.Thread(
                target=self._hb_loop, daemon=True, name=f"replica-{rid}-hb")
            self._hb_thread.start()

    def _send(self, msg: Any, pickle_only: bool = False) -> None:
        try:
            self.chan.send(msg, pickle_only)
        except ChannelClosed:
            self._on_lost()

    def _on_lost(self) -> None:
        """The parent is unreachable: wind down.  Everything still queued
        here is parent-owned state the parent has already spilled, so drop
        it rather than burning compute on work that was re-dispatched."""
        self.disconnected = True
        self._closing = True
        while True:
            try:
                self.pending.get_nowait()
            except queue.Empty:
                break

    def _ingest(self, msg) -> None:
        tag = msg[0]
        if tag == "req":
            # trailing elements are optional: trace context (PR 6) then
            # the deadline *budget* in seconds (older parents send 4- or
            # 5-element frames; tolerate all).  The budget is relative —
            # time.monotonic() does not cross hosts — and pinned to this
            # worker's clock at ingest.
            tctx = TraceContext.from_wire(msg[4]) if len(msg) > 4 else None
            budget = msg[5] if len(msg) > 5 else None
            deadline = time.monotonic() + budget if budget is not None \
                else None
            self.pending.put((msg[1], msg[2], msg[3], tctx, deadline))
        elif tag == "cancel":
            # monotonic rid space, never reused: a cancel can never name
            # future work, so a plain grow-only set is race-free
            self.cancelled.add(msg[1])
        elif tag == "brownout":
            self._brownout = int(msg[1])
        elif tag == "drain":
            self._closing = True
        elif tag == "crash":
            self._crash = True

    def _pump_loop(self) -> None:
        """Reader thread: keep the parent->worker channel drained."""
        while not self.disconnected:
            try:
                msg = self.chan.recv(0.05)
            except ChannelClosed:
                self._on_lost()
                return
            if msg is None:
                continue
            self._ingest(msg)

    def _hb_frame(self) -> tuple:
        """Heartbeat payload: liveness + metrics snapshot + the tracer's
        finished spans + flight-recorder increments since the last ship.
        Telemetry on heartbeats is best-effort by design — a frame lost to
        a dropped connection costs spans, never correctness."""
        spans = current_tracer().drain()
        events = current_recorder().since(self._evt_seq)
        if events:
            self._evt_seq = events[-1]["seq"]
        return ("hb", self.processed, self.busy_s,
                self.registry.snapshot(), spans, events)

    def _hb_loop(self) -> None:
        while not self._stop_hb.wait(self.cfg.heartbeat_interval_s):
            if self.disconnected:
                return
            self._last_hb = time.monotonic()
            self._send(self._hb_frame())

    def send_ready(self) -> None:
        self._send(("ready",))

    def stop(self) -> None:
        self._stop_hb.set()
        if self._hb_thread is not None:
            self._hb_thread.join(timeout=2.0)

    # -- driver callbacks ------------------------------------------------
    def heartbeat(self) -> None:
        now = time.monotonic()
        if now - self._last_hb >= self.cfg.heartbeat_interval_s:
            self._last_hb = now
            self._send(self._hb_frame())

    def crash_requested(self) -> bool:
        return self._crash

    def closing(self) -> bool:
        return self._closing

    def get(self, timeout: float):
        return self.pending.get(timeout=timeout)

    def get_nowait(self):
        return self.pending.get_nowait()

    @staticmethod
    def payload(item) -> Any:
        return item[2]

    @staticmethod
    def trace_ctx(item) -> Any:
        """The rehydrated :class:`TraceContext` riding the work item."""
        return item[3] if len(item) > 3 else None

    @staticmethod
    def deadline(item) -> Any:
        """Absolute worker-clock deadline riding the item (or None)."""
        return item[4] if len(item) > 4 else None

    def is_cancelled(self, item) -> bool:
        return item[0] in self.cancelled

    def brownout(self) -> int:
        return self._brownout

    def begin(self, batch) -> None:
        pass                            # the parent tracks in-flight state

    def emit(self, item, frame) -> None:
        """Streaming: ship a partial-result frame for in-flight item
        ``(rid, cost, payload, tctx)``; the parent routes it to the
        request's ``on_partial``.  Best-effort — a lost frame only
        degrades streaming granularity, the ack still carries the full
        result."""
        self._send(("partial", item[0], frame), pickle_only=True)

    def ack(self, batch, results, busy_s: float) -> None:
        self.busy_s += busy_s
        self.processed += len(batch)
        self._hist.observe(busy_s)
        self._send(("ack", [(item[0], res)
                            for item, res in zip(batch, results)], busy_s),
                   pickle_only=True)    # results must round-trip type-exact

    def spill(self, batch, error: BaseException) -> None:
        # The parent owns every unacknowledged request; telling it why we
        # died is all that is needed — it spills from its own table.  The
        # dying breath also carries the final spans + flight events: the
        # heartbeat that would have shipped them will never fire.
        self.crashed = True
        events = current_recorder().since(self._evt_seq)
        if events:
            self._evt_seq = events[-1]["seq"]
        self._send(("dead", repr(error), current_tracer().drain(), events))

    def publish_kv_state(self, state: Any) -> None:
        """Drain-time KV hand-off: ship the backend's export on the wire.
        Sent before close()'s ("drained",) frame, so FIFO ordering
        guarantees the parent stores it before drain() returns."""
        self._send(("kv_state", state), pickle_only=True)

    def close(self) -> None:
        if self.disconnected:
            return                      # the parent already spilled our work
        # FIFO channel order guarantees every request sent before the drain
        # control message has already been pumped into `pending`, and the
        # driver only reaches here once `pending` is empty.
        self._send(self._hb_frame())
        self._send(("drained",))


def _worker_entry(conn, spec: BackendSpec, cfg: ReplicaConfig,
                  rid: int) -> None:
    """Entry point of a spawned pipe-replica worker process."""
    from repro.cluster.metrics import set_worker_registry
    registry = MetricsRegistry()
    set_worker_registry(registry)   # builders adopt the heartbeat registry
    # follower-mode tracer: sample_rate=0 means the worker never roots a
    # trace of its own, but spans parented on an incoming (sampled)
    # TraceContext always record — the parent's sampling decision rules
    set_tracer(Tracer(enabled=True, sample_rate=0.0, replica=str(rid)))
    set_recorder(FlightRecorder(replica=str(rid)))
    io = WorkerIO(PipeChannel(conn), cfg, rid, registry)
    try:
        backend = spec.build()
    except BaseException as e:          # noqa: BLE001 - report, don't raise
        io.spill([], e)
        return
    io.send_ready()
    run_replica_loop(backend, cfg, io)


# ----------------------------------------------------------------------
class RemoteTransport(Transport):
    """Parent-side half shared by :class:`ProcessTransport` and
    :class:`SocketTransport`.

    Owns the table of unacknowledged requests — the worker only ever sees
    ``(rid, cost, payload)`` triples — plus the ack/heartbeat/fetch frame
    dispatch and the die/spill path.  Subclasses supply the carrier
    (pipe/TCP), death detection (process liveness/heartbeat timeout) and
    carrier teardown.
    """

    def __init__(self, spec: BackendSpec, cfg: ReplicaConfig = ReplicaConfig(),
                 rid: Optional[int] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 on_spill: Optional[OnSpill] = None,
                 kind: Optional[str] = None):
        super().__init__(cfg, rid=rid, metrics=metrics, on_spill=on_spill,
                         kind=kind if kind is not None else spec.kind)
        self.spec = spec
        self._lock = threading.Lock()
        self._chan: Optional[Channel] = None
        self._outstanding: Dict[int, ClusterRequest] = {}
        self._dispatch_t: Dict[int, float] = {}   # rid -> offer() time
        self._outstanding_cost = 0
        self._closing = threading.Event()
        self._ready = threading.Event()
        self._drained = threading.Event()
        self._worker_snapshot: Dict[str, float] = {}
        # mirror of the worker's flight-recorder events (shipped as
        # heartbeat increments) so a postmortem dump has the worker's
        # side of the story even after the worker process is gone
        self._flight_mirror: deque = deque(maxlen=1024)
        # fault injection: inbound "hb" frames are dropped (one-way
        # partition) until this monotonic deadline
        self._hb_drop_until = 0.0

    # -- control surface -------------------------------------------------
    def offer(self, req: ClusterRequest) -> bool:
        if not self.alive or self._closing.is_set():
            return False
        try:
            # serialize before registering: payloads must round-trip
            # type-exact (tuples stay tuples), and an unpicklable payload
            # must neither kill the replica nor leak an outstanding entry —
            # refusing here lets the router shed it explicitly
            tctx = req.trace_ctx
            # deadline rides as a *relative* budget (monotonic clocks do
            # not cross hosts); workers that predate it ignore the extra
            # element, exactly like the PR 6 trace-context rollout
            budget = req.deadline_s - time.monotonic() \
                if req.deadline_s != float("inf") else None
            frame = encode_frame(
                ("req", req.rid, req.cost, req.payload,
                 tctx.to_wire() if tctx is not None else None, budget),
                pickle_only=True)
        except Exception:               # noqa: BLE001 - unserializable
            return False
        with self._lock:
            chan = self._chan
            if not self.alive or chan is None or \
                    len(self._outstanding) >= self.cfg.inbox_capacity:
                return False
            self._outstanding[req.rid] = req
            self._dispatch_t[req.rid] = time.monotonic()
            self._outstanding_cost += req.cost
            self._span_inflight(req)
        try:
            chan.send_bytes(frame)
        except ChannelClosed:
            with self._lock:
                owned = self._outstanding.pop(req.rid, None) is not None
                self._dispatch_t.pop(req.rid, None)
                if owned:
                    self._outstanding_cost -= req.cost
                    self._end_inflight(req.rid, aborted=True)
            self._channel_broken(chan, "send failed")
            # if the fault path already took the request it is being
            # requeued over there — claim success so the caller does not
            # dispatch a second copy
            return not owned
        if not self.alive or self._chan is not chan:
            # Raced with a concurrent death/disconnect.  If the spill
            # already took this request, the fault path owns it (it is
            # being requeued); otherwise reclaim it and report failure.
            with self._lock:
                if self._outstanding.pop(req.rid, None) is not None:
                    self._dispatch_t.pop(req.rid, None)
                    self._outstanding_cost -= req.cost
                    self._end_inflight(req.rid, aborted=True)
                    return False
        return True

    def outstanding_cost(self) -> int:
        with self._lock:
            return self._outstanding_cost

    def cancel(self, rid: int) -> None:
        """Best-effort ``("cancel", rid)`` control frame.  Safe to send
        for rids this worker never saw (the worker's cancelled-set is
        keyed by globally-unique rids) and safe to lose (the parent-side
        terminal state already refuses late acks and re-dispatch)."""
        chan = self._chan
        if chan is None or not self.alive:
            return
        try:
            chan.send(("cancel", rid))
        except ChannelClosed:
            pass                        # dying replica: spill handles it

    def set_brownout(self, level: int) -> None:
        """Ship the router's degradation level; old workers drop the
        unknown frame on the floor (graceful non-degradation)."""
        chan = self._chan
        if chan is None or not self.alive:
            return
        try:
            chan.send(("brownout", int(level)))
        except ChannelClosed:
            pass

    def drain(self, timeout: float = 10.0) -> None:
        self._closing.set()
        chan = self._chan
        if chan is not None:
            try:
                chan.send(("drain",))
            except ChannelClosed:
                pass
        self._drained.wait(timeout)
        self.join(timeout)

    def wait_ready(self, timeout: Optional[float] = None) -> bool:
        return self._ready.wait(
            self.cfg.spawn_timeout_s if timeout is None else timeout)

    def metrics_snapshot(self) -> Dict[str, float]:
        with self._lock:
            return dict(self._worker_snapshot)

    def _await_ready(self) -> None:
        if not self._ready.wait(self.cfg.spawn_timeout_s):
            err = ReplicaCrash(
                f"replica {self.rid}: worker not ready within "
                f"{self.cfg.spawn_timeout_s}s")
            self._die(err)
            raise err
        if not self.alive:              # died during startup (build failed)
            raise ReplicaCrash(
                f"replica {self.rid}: worker died during startup")

    # -- receive path ----------------------------------------------------
    def _recv_loop(self, chan: Channel) -> None:
        while True:
            if not self.alive or self._chan is not chan:
                return
            try:
                msg = chan.recv(0.05)
            except ChannelClosed:
                self._channel_broken(chan, "connection lost")
                return
            if msg is None:
                if not self._idle_tick(chan):
                    return
                continue
            if not self._handle(chan, msg):
                return

    def _handle(self, chan: Channel, msg) -> bool:
        tag = msg[0]
        if tag == "hb" and time.monotonic() < self._hb_drop_until:
            # injected one-way partition: the worker's heartbeats vanish
            # on the way in (acks and data frames still flow, so the
            # zero-lost invariants hold); a worker that sends nothing
            # else goes heartbeat-stale and dies exactly like a real
            # asymmetric partition would make it
            self.metrics.counter("replica.hb_dropped").inc()
            return True
        self.heartbeat_s = time.monotonic()
        if tag == "ack":
            self.busy_s += msg[2]
            for rid, res in msg[1]:
                with self._lock:
                    req = self._outstanding.pop(rid, None)
                    self._dispatch_t.pop(rid, None)
                    if req is not None:
                        self._outstanding_cost -= req.cost
                        self._end_inflight(rid)
                if req is not None:
                    req.complete(res, self.rid)
                    self.processed += 1
        elif tag == "hb":
            with self._lock:
                self._worker_snapshot = dict(msg[3])
            self._ingest_telemetry(
                msg[4] if len(msg) > 4 else None,
                msg[5] if len(msg) > 5 else None)
            # the stall check cannot live only on recv timeouts: a worker
            # heartbeating faster than the recv poll would keep the channel
            # busy enough that _idle_tick never fires — the exact loris
            # this guard exists to catch
            return not self._check_ack_stall()
        elif tag == "partial":
            # streaming frame for an in-flight request; don't pop — the
            # ack is still the completion signal (late frames after a
            # spill hit an empty table and drop harmlessly)
            with self._lock:
                req = self._outstanding.get(msg[1])
            if req is not None:
                req.emit_partial(msg[2])
        elif tag == "ready":
            self._ready.set()
        elif tag == "kv_state":
            # the drained worker's KV export; FIFO framing puts it ahead
            # of ("drained",), so it is in place before drain() returns
            self.kv_state = msg[1]
        elif tag == "drained":
            self._drained.set()
        elif tag == "dead":
            # the dying breath carries the worker's final spans + flight
            # events (the next heartbeat would have, but never fires)
            self._ingest_telemetry(
                msg[2] if len(msg) > 2 else None,
                msg[3] if len(msg) > 3 else None)
            self._die(ReplicaCrash(
                f"replica {self.rid}: worker died: {msg[1]}"))
            return False
        else:
            return self._handle_extra(chan, msg)
        return True

    def _ingest_telemetry(self, spans, events) -> None:
        """Adopt worker-shipped spans into the parent tracer and mirror
        worker flight events (for the postmortem dump)."""
        if spans:
            current_tracer().ingest(spans, replica=self.rid)
        if events:
            with self._lock:
                self._flight_mirror.extend(
                    e for e in events if isinstance(e, dict))

    def inject_hb_partition(self, duration_s: float) -> None:
        """Fault injection: a one-way network partition — inbound
        heartbeats are dropped for ``duration_s`` while every other frame
        (acks, partials) still flows.  An idle worker goes
        heartbeat-stale and dies with a spill; a busy worker survives on
        its data frames, exactly like a real asymmetric partition."""
        self._hb_drop_until = time.monotonic() + float(duration_s)
        self.metrics.counter("replica.hb_partitions").inc()
        current_recorder().record("partition", replica=self.rid,
                                  direction="worker->parent",
                                  duration_s=float(duration_s))

    def _handle_extra(self, chan: Channel, msg) -> bool:
        return True

    def _idle_tick(self, chan: Channel) -> bool:
        """Called on every recv timeout; False stops the loop."""
        return not self._check_ack_stall()

    def _check_ack_stall(self) -> bool:
        """Slow-loris detector: the replica looks alive (its carrier-level
        liveness signal is green) but its oldest dispatched request has
        gone unacknowledged past ``cfg.ack_timeout_s``.  Declares the
        transport dead — spilling every unacknowledged request for
        redispatch on survivors — and returns True.  Late acks from the
        zombie worker pop an empty outstanding table, so nothing is ever
        double-completed."""
        if self.cfg.ack_timeout_s <= 0:
            return False
        now = time.monotonic()
        with self._lock:
            if not self.alive or not self._outstanding:
                return False
            oldest = min(self._dispatch_t.get(rid, now)
                         for rid in self._outstanding)
        age = now - oldest
        if age <= self.cfg.ack_timeout_s:
            return False
        self.metrics.counter("replica.ack_timeouts").inc()
        self._die(ReplicaCrash(
            f"replica {self.rid}: ack timeout — oldest request "
            f"unacknowledged for {age:.2f}s > {self.cfg.ack_timeout_s}s "
            f"while the worker still looked alive (slow loris)"))
        return True

    def _channel_broken(self, chan: Channel, why: str) -> None:
        raise NotImplementedError

    # -- death / teardown ------------------------------------------------
    def _take_outstanding(self) -> List[ClusterRequest]:
        spilled = sorted(self._outstanding.values(), key=lambda r: r.rid)
        self._outstanding.clear()
        self._dispatch_t.clear()
        self._outstanding_cost = 0
        for rid in list(self._inflight_spans):
            self._end_inflight(rid, spilled=True)
        return spilled

    def _die(self, error: BaseException) -> None:
        with self._lock:
            if not self.alive:
                return
            self.alive = False
            spilled = self._take_outstanding()
            chan, self._chan = self._chan, None
        self._ready.set()               # unblock any start()/wait_ready()
        self._drained.set()
        self._kill_carrier(chan)
        self._record_crash(len(spilled))
        current_recorder().record("replica_death", replica=self.rid,
                                  spilled=len(spilled), error=repr(error))
        if spilled:
            # the spilled batch must be IN the dump (the router's
            # per-request respill events fire after it is written)
            current_recorder().record("spill", replica=self.rid,
                                      rids=[r.rid for r in spilled])
        with self._lock:
            mirror = list(self._flight_mirror)
        self._dump_flight(repr(error), worker_events=mirror)
        self._spill_out(spilled, error)

    def _drain_clean(self) -> None:
        with self._lock:
            self.alive = False
            leftovers = self._take_outstanding()
            chan, self._chan = self._chan, None
        if chan is not None:
            chan.close()
        # a clean drain should leave nothing behind; spill defensively
        if leftovers:
            self._spill_out(leftovers, ReplicaCrash(
                f"replica {self.rid}: drained with leftovers"))

    def _kill_carrier(self, chan: Optional[Channel]) -> None:
        if chan is not None:
            chan.close()

    def _spill_out(self, spilled: List[ClusterRequest],
                   error: BaseException) -> None:
        if self.on_spill is not None:
            # called even when nothing spilled: the router uses the empty
            # spill as the death notification (pool removal, session-remap
            # export) for workers that died idle
            self.on_spill(spilled, self)
        else:
            for r in spilled:
                r.fail(error)


# ----------------------------------------------------------------------
class ProcessTransport(RemoteTransport):
    """A replica in its own worker process behind an RPC inbox.

    If the process dies — a backend exception, an injected ``SIGKILL``, an
    OOM kill — the pipe breaks, the receiver notices within one poll
    interval, and every unacknowledged request spills to ``on_spill``: the
    same zero-lost contract as the thread transport, now robust to
    interpreter death.
    """

    def __init__(self, spec: BackendSpec, cfg: ReplicaConfig = ReplicaConfig(),
                 rid: Optional[int] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 on_spill: Optional[OnSpill] = None,
                 kind: Optional[str] = None, start_method: str = "spawn"):
        super().__init__(spec, cfg, rid=rid, metrics=metrics,
                         on_spill=on_spill, kind=kind)
        self._ctx = mp.get_context(start_method)
        self._conn, self._child_conn = self._ctx.Pipe(duplex=True)
        self._proc = None
        self._recv_thread: Optional[threading.Thread] = None

    # -- control surface -------------------------------------------------
    def start(self, wait_ready: bool = True) -> "ProcessTransport":
        self._proc = self._ctx.Process(
            target=_worker_entry,
            args=(self._child_conn, self.spec, self.cfg, self.rid),
            daemon=True, name=f"replica-{self.rid}")
        self._proc.start()
        self._child_conn.close()        # the child holds its own handle now
        self.alive = True
        self.started_s = self.heartbeat_s = time.monotonic()
        self._chan = PipeChannel(self._conn)
        self._recv_thread = threading.Thread(
            target=self._recv_loop, args=(self._chan,), daemon=True,
            name=f"replica-{self.rid}-recv")
        self._recv_thread.start()
        if wait_ready:
            self._await_ready()
        return self

    def inject_crash(self, soft: bool = False) -> None:
        """Fault injection.  Hard (default) == real process death: SIGKILL
        the worker; the receiver detects the broken pipe and spills every
        unacknowledged request, exactly as an OOM-killed production worker
        would.  Soft sends a ``("crash",)`` control frame instead: the
        worker raises at its next loop checkpoint (crash-*before*-ack if a
        batch is in flight) and reports back over the pipe."""
        if self._proc is None or not self._proc.is_alive():
            self._die(ReplicaCrash(f"replica {self.rid}: injected crash"))
            return
        if soft:
            chan = self._chan
            try:
                if chan is None:
                    raise ChannelClosed("no channel")
                chan.send(("crash",))
            except ChannelClosed:
                self._die(ReplicaCrash(
                    f"replica {self.rid}: pipe closed on soft crash"))
        else:
            self._proc.kill()

    def join(self, timeout: float = 10.0) -> None:
        if self._proc is not None:
            self._proc.join(timeout)
        if self._recv_thread is not None and \
                self._recv_thread is not threading.current_thread():
            self._recv_thread.join(timeout)

    # -- death detection: process liveness -------------------------------
    def _idle_tick(self, chan: Channel) -> bool:
        if self._proc is not None and not self._proc.is_alive():
            # exited without a frame on the wire (e.g. killed between
            # messages, or a clean post-drain exit)
            self._channel_broken(chan, "worker exited")
            return False
        return super()._idle_tick(chan)

    def _channel_broken(self, chan: Channel, why: str) -> None:
        if self._closing.is_set() and self._drained.is_set():
            self._drain_clean()
        else:
            self._die(ReplicaCrash(
                f"replica {self.rid}: worker process died ({why})"))

    def _kill_carrier(self, chan: Optional[Channel]) -> None:
        super()._kill_carrier(chan)
        if self._proc is not None and self._proc.is_alive():
            self._proc.kill()


# ----------------------------------------------------------------------
class SocketTransport(RemoteTransport):
    """A replica on the far side of a framed TCP connection.

    The worker dials the parent's :class:`~repro.cluster.wire.
    WorkerListener` and opens with a versioned hello (token, kind, spec
    fingerprint); the parent answers ``("welcome", rid, spec, cfg)`` and
    the worker builds its backend from the shipped spec, pulling any
    ``artifact:<sha256>`` weights reference from the parent's
    :class:`~repro.cluster.artifacts.ArtifactStore` over the same
    connection.  By default ``start()`` also spawns a local
    ``worker_main`` process that dials back over loopback, so the socket
    path is exercised end-to-end on one host; with ``spawn=False`` the
    parent only listens, and the operator runs
    ``python -m repro.cluster.worker_main --connect HOST:PORT --token T``
    on any machine.

    Failure model (vs. :class:`ProcessTransport`): the parent cannot see
    the worker's process, so

      * a *dropped connection* (RST, severed cable, SIGKILL'd worker)
        spills every unacknowledged request immediately — zero lost — but
        leaves the transport in the pool for a reconnect window;
      * a worker that reconnects within ``heartbeat_timeout_s`` (same
        token, same spec fingerprint) resumes service on the same rid, so
        session-affinity placement is undisturbed;
      * *heartbeat staleness* past ``heartbeat_timeout_s`` — never process
        liveness — declares the transport dead.
    """

    def __init__(self, spec: BackendSpec, cfg: ReplicaConfig = ReplicaConfig(),
                 rid: Optional[int] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 on_spill: Optional[OnSpill] = None,
                 kind: Optional[str] = None,
                 listener: Optional[WorkerListener] = None,
                 spawn: bool = True, token: Optional[str] = None,
                 artifacts: Optional[ArtifactStore] = None,
                 start_method: str = "spawn"):
        super().__init__(spec, cfg, rid=rid, metrics=metrics,
                         on_spill=on_spill, kind=kind)
        self.listener = listener if listener is not None \
            else default_listener()
        self.token = token if token is not None \
            else f"w{self.rid}-{uuid.uuid4().hex[:10]}"
        self.spawn = spawn
        self.artifacts = artifacts
        self._spec_hash = spec_fingerprint(spec)
        self._ctx = mp.get_context(start_method)
        self._proc = None
        self._recv_threads: List[threading.Thread] = []
        self._monitor: Optional[threading.Thread] = None
        self._ever_connected = False

    # -- control surface -------------------------------------------------
    def start(self, wait_ready: bool = True) -> "SocketTransport":
        self.alive = True
        self.started_s = self.heartbeat_s = time.monotonic()
        self.listener.register(self.token, self._adopt)
        if self.spawn:
            from repro.cluster import worker_main
            self._proc = self._ctx.Process(
                target=worker_main.run_worker,
                args=(tuple(self.listener.address), self.token),
                daemon=True, name=f"replica-{self.rid}-sock")
            self._proc.start()
        self._monitor = threading.Thread(
            target=self._monitor_loop, daemon=True,
            name=f"replica-{self.rid}-monitor")
        self._monitor.start()
        if wait_ready:
            self._await_ready()
        return self

    def inject_crash(self, soft: bool = False) -> None:
        """Hard (default): SIGKILL the spawned worker — the connection
        drops, unacknowledged requests spill at once, and the heartbeat
        monitor declares the transport dead when no reconnect arrives.
        For a non-spawned (remote) worker there is no process to kill, so
        hard crash degrades to immediate transport death.  Soft asks the
        worker to raise at its next loop checkpoint, as on a pipe."""
        if soft:
            chan = self._chan
            if chan is not None:
                try:
                    chan.send(("crash",))
                    return
                except ChannelClosed:
                    pass
        if self._proc is not None and self._proc.is_alive():
            self._proc.kill()
            if not soft:
                return              # disconnect spill + hb timeout follow
        self._die(ReplicaCrash(f"replica {self.rid}: injected crash"))

    def sever_connection(self) -> None:
        """Fault injection: cut the TCP connection without touching the
        worker — a network partition.  Unacknowledged requests spill
        immediately; the worker notices EOF and re-runs the handshake."""
        chan = self._chan
        if chan is not None:
            current_recorder().record("partition", replica=self.rid,
                                      direction="both", cause="sever")
            chan.close()            # recv loops on both sides see EOF

    def connected(self) -> bool:
        return self._chan is not None

    def drain(self, timeout: float = 10.0) -> None:
        self._closing.set()
        chan = self._chan
        if chan is not None:
            try:
                chan.send(("drain",))
            except ChannelClosed:
                pass
        t_end = time.monotonic() + timeout
        while time.monotonic() < t_end:
            if self._drained.wait(0.05):
                break
            if not self.alive:
                break
            if self._chan is None:
                break               # disconnected mid-drain: nothing to wait
        if self.alive and not self._drained.is_set():
            self._retire()          # worker unreachable; close the slot
        self.join(min(timeout, 5.0))

    def _retire(self) -> None:
        """Take the transport out of service without the crash metric —
        used when a drain cannot complete because no worker is connected
        (its outstanding table is already empty in that case)."""
        with self._lock:
            if not self.alive:
                return
            self.alive = False
            spilled = self._take_outstanding()
            chan, self._chan = self._chan, None
        self._ready.set()
        self._drained.set()
        self._kill_carrier(chan)
        if spilled:
            self._record_crash(len(spilled))
            self._spill_out(spilled, ReplicaCrash(
                f"replica {self.rid}: retired with outstanding requests"))

    def join(self, timeout: float = 10.0) -> None:
        if self._proc is not None:
            self._proc.join(timeout)
        me = threading.current_thread()
        for t in list(self._recv_threads):
            if t is not me:
                t.join(timeout)

    # -- handshake (listener callback) -----------------------------------
    def _adopt(self, chan: Channel, hello: tuple) -> None:
        """Version was already checked by the listener; this half verifies
        the spec fingerprint and swaps the live channel (first contact and
        reconnect are the same path)."""
        _tag, _ver, _token, _w_kind, w_hash = hello[:5]
        if not self.alive:
            try:
                chan.send(("reject", f"replica {self.rid} is dead"))
            except ChannelClosed:
                pass
            chan.close()
            return
        if w_hash is not None and w_hash != self._spec_hash:
            # a stale worker (old deployment / different weights) must be
            # refused at the door, not allowed to serve wrong results
            # (count first: the peer acts on the reject the moment it lands)
            self.metrics.counter("replica.handshake_rejects").inc()
            try:
                chan.send(("reject", "backend spec fingerprint mismatch"))
            except ChannelClosed:
                pass
            chan.close()
            return
        # welcome must hit the wire BEFORE the channel is published: once
        # self._chan is set, a concurrent offer() may send ("req", ...)
        # frames, and the worker treats anything-but-welcome as a reject
        try:
            chan.send(("welcome", self.rid, self.spec, self.cfg),
                      pickle_only=True)
            if self._closing.is_set():
                chan.send(("drain",))   # drain started while disconnected
        except ChannelClosed:
            chan.close()
            return                      # worker will redial (or is gone)
        with self._lock:
            if not self.alive:
                chan.close()
                return
            old, self._chan = self._chan, chan
            # the worker may redial before *we* notice the old connection
            # died (NAT drop, racing poll): anything still outstanding was
            # sent down the old pipe and the new incarnation never saw it,
            # so it must spill now — the stale recv loop will see the swap
            # and stand down without spilling
            stale = self._take_outstanding() if old is not None else []
        if old is not None:
            old.close()
        reconnect = self._ever_connected
        self._ever_connected = True
        self.heartbeat_s = time.monotonic()
        if reconnect:
            self.metrics.counter("replica.reconnects").inc()
            current_recorder().record("reconnect", replica=self.rid,
                                      stale_spilled=len(stale))
        if stale:
            self.metrics.counter("replica.disconnect_spills").inc(len(stale))
            self._spill_out(stale, ReplicaCrash(
                f"replica {self.rid}: reconnect superseded the previous "
                f"connection"))
        t = threading.Thread(target=self._recv_loop, args=(chan,),
                             daemon=True, name=f"replica-{self.rid}-recv")
        # prune loops whose channels are gone: a flaky link reconnecting
        # for days must not accumulate dead Thread objects
        self._recv_threads = [r for r in self._recv_threads if r.is_alive()]
        self._recv_threads.append(t)
        t.start()

    # -- death detection: heartbeat timeout ------------------------------
    def _monitor_loop(self) -> None:
        period = min(0.05, self.cfg.heartbeat_timeout_s / 4)
        while self.alive:
            time.sleep(period)
            if not self.alive:
                return
            if not self._ready.is_set():
                continue            # startup is governed by spawn_timeout_s
            stale = time.monotonic() - self.heartbeat_s
            if stale > self.cfg.heartbeat_timeout_s:
                self._die(ReplicaCrash(
                    f"replica {self.rid}: heartbeat timeout "
                    f"({stale:.2f}s > {self.cfg.heartbeat_timeout_s}s)"))
                return

    def _channel_broken(self, chan: Channel, why: str) -> None:
        with self._lock:
            if self._chan is not chan:
                return              # stale loop; a newer channel took over
            self._chan = None
            spilled = self._take_outstanding()
        chan.close()
        if self._closing.is_set() and self._drained.is_set():
            self.alive = False
            self.listener.unregister(self.token)
            if spilled:             # clean drain leaves nothing; defensive
                self._spill_out(spilled, ReplicaCrash(
                    f"replica {self.rid}: drained with leftovers"))
            return
        # Mid-flight disconnect: the zero-lost contract pays out *now* —
        # every unacknowledged request spills for redispatch — but the
        # transport stays in the pool for the reconnect window (the
        # monitor declares death if no worker returns in time).
        self.metrics.counter("replica.disconnects").inc()
        current_recorder().record("disconnect", replica=self.rid,
                                  why=why, spilled=len(spilled))
        if spilled:
            self.metrics.counter("replica.disconnect_spills") \
                .inc(len(spilled))
            self._spill_out(spilled, ReplicaCrash(
                f"replica {self.rid}: connection lost ({why})"))

    #: one-frame fetch replies cap the shippable artifact (chunked
    #: transfer is a ROADMAP item); past this the reply is an explicit
    #: miss, not a dead recv thread
    MAX_ARTIFACT_BYTES = 1 << 30

    def _handle_extra(self, chan: Channel, msg) -> bool:
        if msg[0] == "fetch":
            # served off-thread: a gigabyte read + sendall on the recv
            # thread would starve heartbeat processing for the whole
            # transfer and let the monitor kill a healthy worker mid-fetch
            threading.Thread(target=self._serve_fetch, args=(chan, msg[1]),
                             daemon=True,
                             name=f"replica-{self.rid}-fetch").start()
        return True

    def _serve_fetch(self, chan: Channel, digest) -> None:
        data = None
        try:
            if self.artifacts is not None and self.artifacts.has(digest):
                path = self.artifacts.get_path(digest)
                if os.path.getsize(path) <= self.MAX_ARTIFACT_BYTES:
                    data = self.artifacts.read_bytes(digest)
        except (ValueError, OSError, KeyError):
            data = None         # malformed digest / store hiccup: a miss,
            # never an exception that would kill a transport thread
        try:
            chan.send(("artifact", digest, data))
        except ChannelClosed:
            pass                # the recv loop notices the break itself

    def _kill_carrier(self, chan: Optional[Channel]) -> None:
        self.listener.unregister(self.token)
        super()._kill_carrier(chan)
        if self._proc is not None and self._proc.is_alive():
            self._proc.kill()


# ----------------------------------------------------------------------
_default_listener: Optional[WorkerListener] = None
_default_listener_lock = threading.Lock()


def default_listener() -> WorkerListener:
    """Process-wide listener shared by socket transports that were not
    given one explicitly (lazily bound to an ephemeral loopback port)."""
    global _default_listener
    with _default_listener_lock:
        if _default_listener is None:
            _default_listener = WorkerListener()
        return _default_listener


def make_transport(transport: str, *, backend=None,
                   spec: Optional[BackendSpec] = None,
                   cfg: ReplicaConfig = ReplicaConfig(),
                   rid: Optional[int] = None,
                   metrics: Optional[MetricsRegistry] = None,
                   on_spill: Optional[OnSpill] = None,
                   kind: Optional[str] = None,
                   listener: Optional[WorkerListener] = None,
                   artifacts: Optional[ArtifactStore] = None,
                   spawn: bool = True,
                   token: Optional[str] = None) -> Transport:
    """Build (but do not start) a transport.

    ``thread`` accepts a live backend object or a spec (built in-process);
    ``process`` and ``socket`` require a :class:`BackendSpec` — live
    backends cannot cross a process or host boundary.
    """
    if transport not in TRANSPORTS:
        raise ValueError(f"transport {transport!r} not in {TRANSPORTS}")
    if transport == "process":
        if spec is None:
            raise ValueError("ProcessTransport needs a BackendSpec "
                             "(a live backend cannot cross the process "
                             "boundary)")
        return ProcessTransport(spec, cfg, rid=rid, metrics=metrics,
                                on_spill=on_spill, kind=kind)
    if transport == "socket":
        if spec is None:
            raise ValueError("SocketTransport needs a BackendSpec "
                             "(a live backend cannot cross the host "
                             "boundary)")
        return SocketTransport(spec, cfg, rid=rid, metrics=metrics,
                               on_spill=on_spill, kind=kind,
                               listener=listener, artifacts=artifacts,
                               spawn=spawn, token=token)
    if backend is None:
        if spec is None:
            raise ValueError("LocalTransport needs a backend or a spec")
        backend = spec.build()
    resolved_kind = kind if kind is not None else \
        (spec.kind if spec is not None
         else getattr(backend, "kind", "fn") or "fn")
    return LocalTransport(backend, cfg, rid=rid, metrics=metrics,
                          on_spill=on_spill, kind=resolved_kind)


# Back-compat: PR 1's thread replica, by its old name.
ReplicaWorker = LocalTransport
