"""Replica transports: where a replica runs and how requests reach it.

The router, autoscaler and metrics speak to replicas only through the
:class:`Transport` surface (submit/ack/spill/heartbeat over a bounded
inbox), so worker *placement* is pluggable:

  * :class:`LocalTransport`  — the replica driver on a host thread over a
    ``queue.Queue`` inbox.  Threads share one JAX runtime: weights are
    zero-copy, but device FLOPs do not scale beyond one client.
  * :class:`ProcessTransport` — a spawned worker subprocess with an RPC
    inbox: requests travel over a duplex pipe as msgpack/pickle-framed
    messages, acknowledgements and heartbeat/metrics snapshots travel back,
    and crash detection is by process liveness (a SIGKILL'd worker is
    noticed at the next pipe read).  Each worker owns an independent Python
    interpreter and JAX runtime, so device FLOPs scale with workers — the
    paper's worker *nodes*.

Both implement the same at-least-once contract: every request is either
acknowledged exactly once or spilled back to ``on_spill`` for redispatch;
none are lost.  The in-replica loop is shared
(:func:`repro.cluster.replica.run_replica_loop`), so batching and
crash-before-ack semantics are identical.

Process workers are rebuilt from a :class:`~repro.cluster.backends.
BackendSpec` (config + weights path), never from live objects — the only
things that cross the spawn boundary are picklable.
"""
from __future__ import annotations

import itertools
import multiprocessing as mp
import pickle
import queue
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

try:
    import msgpack
except ImportError:                                   # pragma: no cover - env
    msgpack = None

from repro.cluster.backends import BackendSpec
from repro.cluster.metrics import MetricsRegistry, null_registry
from repro.cluster.replica import (ClusterRequest, ReplicaConfig,
                                   ReplicaCrash, run_replica_loop)

TRANSPORTS = ("thread", "process")

OnSpill = Callable[[List[ClusterRequest], "Transport"], None]


# ----------------------------------------------------------------------
# Wire framing: msgpack for the control plane (tags, rids, heartbeat
# snapshots — known plain types), pickle for anything carrying *user*
# payloads or results (``pickle_only=True``): msgpack would silently
# round-trip tuples as lists, making a backend behave differently across
# the process boundary.  One tag byte keeps decode unambiguous.

def encode_frame(obj: Any, pickle_only: bool = False) -> bytes:
    if not pickle_only and msgpack is not None:
        try:
            return b"M" + msgpack.packb(obj, use_bin_type=True)
        except (TypeError, ValueError, OverflowError):
            pass
    return b"P" + pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)


def decode_frame(buf: bytes) -> Any:
    tag, body = buf[:1], buf[1:]
    if tag == b"M":
        if msgpack is None:
            raise RuntimeError("msgpack frame received without msgpack")
        return msgpack.unpackb(body, raw=False)
    if tag == b"P":
        return pickle.loads(body)
    raise ValueError(f"unknown frame tag {tag!r}")


# ----------------------------------------------------------------------
class Transport:
    """What the router/autoscaler may assume about a replica.

    Lifecycle: ``start()`` -> ``offer()`` x N -> ``drain()`` (graceful) or
    ``inject_crash()`` (fault).  A dead transport spills every
    unacknowledged request to ``on_spill`` exactly once.
    """

    _ids = itertools.count()

    def __init__(self, cfg: ReplicaConfig, rid: Optional[int] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 on_spill: Optional[OnSpill] = None, kind: str = "fn"):
        self.rid = next(Transport._ids) if rid is None else rid
        self.cfg = cfg
        self.metrics = metrics if metrics is not None else null_registry()
        self.on_spill = on_spill
        self.kind = kind
        self.alive = False
        self.heartbeat_s = 0.0
        self.started_s = 0.0
        self.busy_s = 0.0
        self.processed = 0

    # -- control surface -------------------------------------------------
    def start(self) -> "Transport":
        raise NotImplementedError

    def offer(self, req: ClusterRequest) -> bool:
        """Enqueue; False == backpressure (inbox full / replica down)."""
        raise NotImplementedError

    def outstanding_cost(self) -> int:
        raise NotImplementedError

    def inject_crash(self) -> None:
        raise NotImplementedError

    def drain(self, timeout: float = 10.0) -> None:
        raise NotImplementedError

    def join(self, timeout: float = 10.0) -> None:
        raise NotImplementedError

    # -- health / telemetry ----------------------------------------------
    def healthy(self, now: Optional[float] = None) -> bool:
        now = time.monotonic() if now is None else now
        return self.alive and \
            now - self.heartbeat_s < self.cfg.heartbeat_timeout_s

    def busy_fraction(self) -> float:
        wall = time.monotonic() - self.started_s
        return self.busy_s / wall if wall > 0 else 0.0

    def metrics_snapshot(self) -> Dict[str, float]:
        """Worker-side metrics.  Local replicas write into the shared
        registry directly, so their snapshot is empty; process replicas
        return the last heartbeat's registry snapshot."""
        return {}

    def _record_crash(self, n_spilled: int) -> None:
        self.metrics.counter("replica.crashes").inc()
        self.metrics.counter("replica.spilled_requests").inc(n_spilled)


# ----------------------------------------------------------------------
class LocalTransport(Transport):
    """The replica driver on a host thread with a ``queue.Queue`` inbox.

    Behavior-preserving port of PR 1's ``ReplicaWorker`` (which remains as
    an alias): same offer/crash/drain races, same straggler handling.
    """

    def __init__(self, backend, cfg: ReplicaConfig = ReplicaConfig(),
                 rid: Optional[int] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 on_spill: Optional[OnSpill] = None, kind: str = "fn"):
        super().__init__(cfg, rid=rid, metrics=metrics, on_spill=on_spill,
                         kind=kind)
        self.backend = backend
        self.inbox: "queue.Queue[ClusterRequest]" = \
            queue.Queue(maxsize=cfg.inbox_capacity)
        self._lock = threading.Lock()
        self._outstanding_cost = 0
        self._crash = threading.Event()
        self._closing = threading.Event()
        self._hist = self.metrics.histogram("replica.batch_s")
        self._thread = threading.Thread(
            target=run_replica_loop, args=(backend, cfg, self),
            daemon=True, name=f"replica-{self.rid}")

    # -- control surface -------------------------------------------------
    def start(self) -> "LocalTransport":
        self.alive = True
        self.started_s = self.heartbeat_s = time.monotonic()
        self._thread.start()
        return self

    def offer(self, req: ClusterRequest) -> bool:
        if not self.alive or self._closing.is_set():
            return False
        try:
            self.inbox.put_nowait(req)
        except queue.Full:
            return False
        with self._lock:
            self._outstanding_cost += req.cost
        if not self.alive:
            # Raced with a concurrent crash: the dying thread may already
            # have drained the inbox, so reclaim whatever is left ourselves
            # and report failure — the caller re-dispatches elsewhere.
            leftovers: List[ClusterRequest] = []
            while True:
                try:
                    leftovers.append(self.inbox.get_nowait())
                except queue.Empty:
                    break
            with self._lock:
                self._outstanding_cost -= sum(r.cost for r in leftovers)
            others = [r for r in leftovers if r is not req]
            if others and self.on_spill is not None:
                self.on_spill(others, self)
            return False
        return True

    def outstanding_cost(self) -> int:
        with self._lock:
            return self._outstanding_cost

    def inject_crash(self) -> None:
        """Fault injection: the worker dies at its next loop checkpoint and
        spills all unacknowledged requests."""
        self._crash.set()

    def drain(self, timeout: float = 10.0) -> None:
        """Graceful: stop accepting, finish the inbox, exit."""
        self._closing.set()
        self._thread.join(timeout)

    def join(self, timeout: float = 10.0) -> None:
        self._thread.join(timeout)

    # -- driver inbox IO (run_replica_loop callbacks) --------------------
    def heartbeat(self) -> None:
        self.heartbeat_s = time.monotonic()

    def crash_requested(self) -> bool:
        return self._crash.is_set()

    def closing(self) -> bool:
        return self._closing.is_set()

    def get(self, timeout: float) -> ClusterRequest:
        return self.inbox.get(timeout=timeout)

    def get_nowait(self) -> ClusterRequest:
        return self.inbox.get_nowait()

    @staticmethod
    def payload(req: ClusterRequest) -> Any:
        return req.payload

    def begin(self, batch: List[ClusterRequest]) -> None:
        pass            # the driver hands the in-flight batch to spill()

    def ack(self, batch: List[ClusterRequest], results: List[Any],
            busy_s: float) -> None:
        self.busy_s += busy_s
        self._hist.observe(busy_s)
        done_cost = 0
        for r, res in zip(batch, results):
            r.complete(res, self.rid)
            done_cost += r.cost
            self.processed += 1
        with self._lock:
            self._outstanding_cost -= done_cost

    def spill(self, batch: List[ClusterRequest], error: BaseException) -> None:
        """Crash path: mark dead, spill in-flight + inbox to the router."""
        self.alive = False
        spilled = list(batch)
        # Two drain passes with a grace gap: an `offer` that read `alive`
        # just before we flipped it may still land a request (offer's own
        # post-put check is the second line of defence).
        for _ in range(2):
            while True:
                try:
                    spilled.append(self.inbox.get_nowait())
                except queue.Empty:
                    break
            time.sleep(0.005)
        with self._lock:
            self._outstanding_cost = 0
        self._record_crash(len(spilled))
        if self.on_spill is not None:
            self.on_spill(spilled, self)
        else:
            for r in spilled:
                r.fail(error)

    def close(self) -> None:
        # Graceful exit: refuse new offers first, then finish any request
        # that raced into the inbox between the final empty poll and the
        # flip (offer's post-put aliveness re-check closes the rest of the
        # window by reclaiming and re-dispatching).
        self.alive = False
        time.sleep(self.cfg.poll_s)
        stragglers: List[ClusterRequest] = []
        while True:
            try:
                stragglers.append(self.inbox.get_nowait())
            except queue.Empty:
                break
        if stragglers:
            try:
                results = self.backend.process([r.payload for r in stragglers])
                for r, res in zip(stragglers, results):
                    r.complete(res, self.rid)
                    self.processed += 1
            except BaseException as e:
                if self.on_spill is not None:
                    self.on_spill(stragglers, self)
                else:
                    for r in stragglers:
                        r.fail(e)
        with self._lock:
            self._outstanding_cost = 0


# ----------------------------------------------------------------------
# Worker-process side.

class _WorkerIO:
    """Driver inbox IO inside the worker process: work items are
    ``(rid, cost, payload)`` triples received over the pipe; acks,
    heartbeats and metrics snapshots are shipped back.

    A dedicated reader thread pumps the pipe into ``pending`` continuously,
    so the parent's sends never back up behind a long ``backend.process``
    call — ``offer()`` on the parent side stays non-blocking even when
    payloads exceed the OS pipe buffer."""

    def __init__(self, conn, cfg: ReplicaConfig, rid: int,
                 registry: MetricsRegistry):
        self.conn = conn
        self.cfg = cfg
        self.rid = rid
        self.registry = registry
        self._hist = registry.histogram("replica.batch_s")
        self.pending: "queue.Queue[Tuple[int, int, Any]]" = queue.Queue()
        self._crash = False
        self._closing = False
        self._send_lock = threading.Lock()
        self._last_hb = 0.0
        self.processed = 0
        self.busy_s = 0.0
        self._reader = threading.Thread(target=self._pump_loop, daemon=True,
                                        name=f"replica-{rid}-pump")
        self._reader.start()

    def _send(self, msg: Any, pickle_only: bool = False) -> None:
        with self._send_lock:
            self.conn.send_bytes(encode_frame(msg, pickle_only))

    def _pump_loop(self) -> None:
        """Reader thread: keep the parent->worker pipe drained."""
        while True:
            try:
                if not self.conn.poll(0.05):
                    continue
                msg = decode_frame(self.conn.recv_bytes())
            except (EOFError, OSError):
                self._closing = True       # parent went away: wind down
                return
            tag = msg[0]
            if tag == "req":
                self.pending.put((msg[1], msg[2], msg[3]))
            elif tag == "drain":
                self._closing = True
            elif tag == "crash":
                self._crash = True

    # -- driver callbacks ------------------------------------------------
    def heartbeat(self) -> None:
        now = time.monotonic()
        if now - self._last_hb >= self.cfg.heartbeat_interval_s:
            self._last_hb = now
            self._send(("hb", self.processed, self.busy_s,
                        self.registry.snapshot()))

    def crash_requested(self) -> bool:
        return self._crash

    def closing(self) -> bool:
        return self._closing

    def get(self, timeout: float):
        return self.pending.get(timeout=timeout)

    def get_nowait(self):
        return self.pending.get_nowait()

    @staticmethod
    def payload(item) -> Any:
        return item[2]

    def begin(self, batch) -> None:
        pass                            # the parent tracks in-flight state

    def ack(self, batch, results, busy_s: float) -> None:
        self.busy_s += busy_s
        self.processed += len(batch)
        self._hist.observe(busy_s)
        self._send(("ack", [(item[0], res)
                            for item, res in zip(batch, results)], busy_s),
                   pickle_only=True)    # results must round-trip type-exact

    def spill(self, batch, error: BaseException) -> None:
        # The parent owns every unacknowledged request; telling it why we
        # died is all that is needed — it spills from its own table.
        try:
            self._send(("dead", repr(error)))
        except OSError:
            pass

    def close(self) -> None:
        # FIFO pipe order guarantees every request sent before the drain
        # control message has already been pumped into `pending`, and the
        # driver only reaches here once `pending` is empty.
        try:
            self._send(("hb", self.processed, self.busy_s,
                        self.registry.snapshot()))
            self._send(("drained",))
        except OSError:
            pass


def _worker_entry(conn, spec: BackendSpec, cfg: ReplicaConfig,
                  rid: int) -> None:
    """Entry point of a spawned replica worker process."""
    registry = MetricsRegistry()
    io = _WorkerIO(conn, cfg, rid, registry)
    try:
        backend = spec.build()
    except BaseException as e:          # noqa: BLE001 - report, don't raise
        io.spill([], e)
        return
    io._send(("ready",))
    run_replica_loop(backend, cfg, io)


# ----------------------------------------------------------------------
class ProcessTransport(Transport):
    """A replica in its own worker process behind an RPC inbox.

    The parent keeps the table of unacknowledged requests; the worker only
    ever sees ``(rid, cost, payload)`` triples.  If the process dies — a
    backend exception, an injected ``SIGKILL``, an OOM kill — the pipe
    breaks, the receiver notices within one poll interval, and every
    unacknowledged request spills to ``on_spill``: the same zero-lost
    contract as the thread transport, now robust to interpreter death.
    """

    def __init__(self, spec: BackendSpec, cfg: ReplicaConfig = ReplicaConfig(),
                 rid: Optional[int] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 on_spill: Optional[OnSpill] = None,
                 kind: Optional[str] = None, start_method: str = "spawn"):
        super().__init__(cfg, rid=rid, metrics=metrics, on_spill=on_spill,
                         kind=kind if kind is not None else spec.kind)
        self.spec = spec
        self._ctx = mp.get_context(start_method)
        self._conn, self._child_conn = self._ctx.Pipe(duplex=True)
        self._proc = None
        self._recv_thread: Optional[threading.Thread] = None
        self._lock = threading.Lock()
        self._send_lock = threading.Lock()   # pipe writes only: a full pipe
        # must never stall the receiver's ack bookkeeping via self._lock
        self._outstanding: Dict[int, ClusterRequest] = {}
        self._outstanding_cost = 0
        self._closing = threading.Event()
        self._ready = threading.Event()
        self._drained = threading.Event()
        self._worker_snapshot: Dict[str, float] = {}

    # -- control surface -------------------------------------------------
    def start(self, wait_ready: bool = True) -> "ProcessTransport":
        self._proc = self._ctx.Process(
            target=_worker_entry,
            args=(self._child_conn, self.spec, self.cfg, self.rid),
            daemon=True, name=f"replica-{self.rid}")
        self._proc.start()
        self._child_conn.close()        # the child holds its own handle now
        self.alive = True
        self.started_s = self.heartbeat_s = time.monotonic()
        self._recv_thread = threading.Thread(
            target=self._recv_loop, daemon=True,
            name=f"replica-{self.rid}-recv")
        self._recv_thread.start()
        if wait_ready:
            if not self._ready.wait(self.cfg.spawn_timeout_s):
                err = ReplicaCrash(
                    f"replica {self.rid}: worker not ready within "
                    f"{self.cfg.spawn_timeout_s}s")
                self._die(err)
                raise err
            if not self.alive:          # died during startup (build failed)
                raise ReplicaCrash(
                    f"replica {self.rid}: worker died during startup")
        return self

    def offer(self, req: ClusterRequest) -> bool:
        if not self.alive or self._closing.is_set():
            return False
        try:
            # serialize before registering: payloads must round-trip
            # type-exact (tuples stay tuples), and an unpicklable payload
            # must neither kill the replica nor leak an outstanding entry —
            # refusing here lets the router shed it explicitly
            frame = encode_frame(("req", req.rid, req.cost, req.payload),
                                 pickle_only=True)
        except Exception:               # noqa: BLE001 - unserializable
            return False
        with self._lock:
            if not self.alive or len(self._outstanding) >= \
                    self.cfg.inbox_capacity:
                return False
            self._outstanding[req.rid] = req
            self._outstanding_cost += req.cost
        try:
            with self._send_lock:
                self._conn.send_bytes(frame)
        except (OSError, ValueError):
            with self._lock:
                if self._outstanding.pop(req.rid, None) is not None:
                    self._outstanding_cost -= req.cost
            self._die(ReplicaCrash(
                f"replica {self.rid}: pipe closed on offer"))
            return False
        if not self.alive:
            # Raced with a concurrent death.  If the receiver's spill
            # already took this request, the fault path owns it (it is
            # being requeued); otherwise reclaim it and report failure.
            with self._lock:
                if self._outstanding.pop(req.rid, None) is not None:
                    self._outstanding_cost -= req.cost
                    return False
        return True

    def outstanding_cost(self) -> int:
        with self._lock:
            return self._outstanding_cost

    def inject_crash(self, soft: bool = False) -> None:
        """Fault injection.  Hard (default) == real process death: SIGKILL
        the worker; the receiver detects the broken pipe and spills every
        unacknowledged request, exactly as an OOM-killed production worker
        would.  Soft sends a ``("crash",)`` control frame instead: the
        worker raises at its next loop checkpoint (crash-*before*-ack if a
        batch is in flight) and reports back over the pipe."""
        if self._proc is None or not self._proc.is_alive():
            self._die(ReplicaCrash(f"replica {self.rid}: injected crash"))
            return
        if soft:
            try:
                self._send(("crash",))
            except (OSError, ValueError):
                self._die(ReplicaCrash(
                    f"replica {self.rid}: pipe closed on soft crash"))
        else:
            self._proc.kill()

    def drain(self, timeout: float = 10.0) -> None:
        self._closing.set()
        try:
            self._send(("drain",))
        except (OSError, ValueError):
            pass
        self._drained.wait(timeout)
        self.join(timeout)

    def join(self, timeout: float = 10.0) -> None:
        if self._proc is not None:
            self._proc.join(timeout)
        if self._recv_thread is not None and \
                self._recv_thread is not threading.current_thread():
            self._recv_thread.join(timeout)

    def wait_ready(self, timeout: Optional[float] = None) -> bool:
        return self._ready.wait(
            self.cfg.spawn_timeout_s if timeout is None else timeout)

    def metrics_snapshot(self) -> Dict[str, float]:
        with self._lock:
            return dict(self._worker_snapshot)

    # -- parent-side receive path ----------------------------------------
    def _send(self, msg: Any, pickle_only: bool = False) -> None:
        with self._send_lock:
            self._conn.send_bytes(encode_frame(msg, pickle_only))

    def _recv_loop(self) -> None:
        while True:
            try:
                if not self._conn.poll(0.05):
                    if not self.alive:
                        return
                    if self._proc is not None and not self._proc.is_alive():
                        # exited without a frame on the wire (e.g. killed
                        # between messages, or a clean post-drain exit)
                        self._on_eof()
                        return
                    continue
                msg = decode_frame(self._conn.recv_bytes())
            except (EOFError, OSError, ValueError):
                self._on_eof()
                return
            tag = msg[0]
            self.heartbeat_s = time.monotonic()
            if tag == "ack":
                self.busy_s += msg[2]
                for rid, res in msg[1]:
                    with self._lock:
                        req = self._outstanding.pop(rid, None)
                        if req is not None:
                            self._outstanding_cost -= req.cost
                    if req is not None:
                        req.complete(res, self.rid)
                        self.processed += 1
            elif tag == "hb":
                with self._lock:
                    self._worker_snapshot = dict(msg[3])
            elif tag == "ready":
                self._ready.set()
            elif tag == "drained":
                self._drained.set()
            elif tag == "dead":
                self._die(ReplicaCrash(
                    f"replica {self.rid}: worker died: {msg[1]}"))
                return

    def _on_eof(self) -> None:
        clean = self._closing.is_set() and self._drained.is_set()
        if clean:
            self.alive = False
            with self._lock:
                leftovers = sorted(self._outstanding.values(),
                                   key=lambda r: r.rid)
                self._outstanding.clear()
                self._outstanding_cost = 0
            # a clean drain should leave nothing behind; spill defensively
            if leftovers:
                self._spill_out(leftovers, ReplicaCrash(
                    f"replica {self.rid}: drained with leftovers"))
        else:
            self._die(ReplicaCrash(
                f"replica {self.rid}: worker process died"))

    def _die(self, error: BaseException) -> None:
        with self._lock:
            if not self.alive:
                return
            self.alive = False
            spilled = sorted(self._outstanding.values(), key=lambda r: r.rid)
            self._outstanding.clear()
            self._outstanding_cost = 0
        self._ready.set()               # unblock any start()/wait_ready()
        self._drained.set()
        if self._proc is not None and self._proc.is_alive():
            self._proc.kill()
        self._record_crash(len(spilled))
        self._spill_out(spilled, error)

    def _spill_out(self, spilled: List[ClusterRequest],
                   error: BaseException) -> None:
        if self.on_spill is not None:
            if spilled:
                self.on_spill(spilled, self)
        else:
            for r in spilled:
                r.fail(error)


# ----------------------------------------------------------------------
def make_transport(transport: str, *, backend=None,
                   spec: Optional[BackendSpec] = None,
                   cfg: ReplicaConfig = ReplicaConfig(),
                   rid: Optional[int] = None,
                   metrics: Optional[MetricsRegistry] = None,
                   on_spill: Optional[OnSpill] = None,
                   kind: Optional[str] = None) -> Transport:
    """Build (but do not start) a transport.

    ``thread`` accepts a live backend object or a spec (built in-process);
    ``process`` requires a :class:`BackendSpec` — live backends cannot
    cross the spawn boundary.
    """
    if transport not in TRANSPORTS:
        raise ValueError(f"transport {transport!r} not in {TRANSPORTS}")
    if transport == "process":
        if spec is None:
            raise ValueError("ProcessTransport needs a BackendSpec "
                             "(a live backend cannot cross the process "
                             "boundary)")
        return ProcessTransport(spec, cfg, rid=rid, metrics=metrics,
                                on_spill=on_spill, kind=kind)
    if backend is None:
        if spec is None:
            raise ValueError("LocalTransport needs a backend or a spec")
        backend = spec.build()
    resolved_kind = kind if kind is not None else \
        (spec.kind if spec is not None else "fn")
    return LocalTransport(backend, cfg, rid=rid, metrics=metrics,
                          on_spill=on_spill, kind=resolved_kind)


# Back-compat: PR 1's thread replica, by its old name.
ReplicaWorker = LocalTransport
