"""Request router: fan user requests out over a pool of replica transports.

The paper distributes the *pipeline* over Spark workers; this module
distributes the *service* — the missing piece between one `Engine`/stream
runtime and "heavy traffic from millions of users".  Pluggable dispatch
policies:

  * ``round_robin``      — uniform rotation over alive replicas.
  * ``least_loaded``     — lowest outstanding cost (requests or token/row
                           weights), the classic join-shortest-queue policy.
  * ``session_affinity`` — rendezvous (highest-random-weight) hashing of the
                           session key, so a session sticks to one replica
                           (warm caches / per-user state) and only the keys
                           of a *removed* replica ever remap.

The router sees replicas only through the :class:`~repro.cluster.transport.
Transport` surface — it neither knows nor cares whether a replica is a
thread in this process (``transport="thread"``) or a worker subprocess
behind an RPC inbox (``transport="process"``, built from a serializable
:class:`~repro.cluster.backends.BackendSpec`).

Fault path: a replica crash spills its unacknowledged requests back here;
they are requeued on survivors (bounded retries, `core/fault.py` semantics).
Admission control (`cluster/admission.py`) runs at `submit`, so overload is
an explicit `Rejected` result instead of unbounded queueing; when replicas
carry a backend *kind* ("lm", "svm", ...) the deadline test uses that
backend's own cost model and queue depth.
"""
from __future__ import annotations

import hashlib
import itertools
import logging
import threading
import time
from typing import Any, Callable, Dict, List, Optional

log = logging.getLogger(__name__)

from repro.cluster.admission import AdmissionController, Rejected
from repro.cluster.backends import BackendSpec
from collections import OrderedDict

from repro.cluster.metrics import (MetricsRegistry, merge_snapshots,
                                   null_registry, terminal_snapshot_view)
from repro.cluster.overload import BrownoutController, CircuitBreaker
from repro.cluster.replica import (KV_IMPORT_TAG, ClusterRequest,
                                   ReplicaConfig, Status, WaitTimeout)
from repro.cluster.tracing import current_recorder, current_tracer
from repro.cluster.transport import Transport, make_transport

POLICIES = ("round_robin", "least_loaded", "session_affinity")


def _rendezvous_weight(session_key: str, rid: int) -> int:
    h = hashlib.md5(f"{session_key}|{rid}".encode()).digest()
    return int.from_bytes(h[:8], "little")


class Router:
    """Front door over N replica :class:`Transport` s."""

    def __init__(self, policy: str = "round_robin",
                 admission: Optional[AdmissionController] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 max_retries: int = 2,
                 requeue_timeout_s: float = 5.0,
                 retry_backoff_base_s: float = 0.05,
                 retry_backoff_max_s: float = 1.0,
                 poison_threshold: int = 2,
                 breaker: Optional[CircuitBreaker] = None,
                 brownout: Optional[BrownoutController] = None):
        if policy not in POLICIES:
            raise ValueError(f"policy {policy!r} not in {POLICIES}")
        self.policy = policy
        self.metrics = metrics if metrics is not None else null_registry()
        self.admission = admission
        self.max_retries = max_retries
        self.requeue_timeout_s = requeue_timeout_s
        # retry budget: each respill waits base * 2^(attempt-1) (capped)
        # before re-offering — a crash's burst spreads instead of slamming
        # survivors in lockstep
        self.retry_backoff_base_s = retry_backoff_base_s
        self.retry_backoff_max_s = retry_backoff_max_s
        # poison detection: a request whose dispatch has now killed this
        # many *distinct* replicas terminates with finish_reason="poison"
        # instead of cascading through the rest of the fleet
        self.poison_threshold = poison_threshold
        self.breaker = breaker if breaker is not None else CircuitBreaker()
        self.brownout = brownout
        self._replicas: Dict[int, Transport] = {}
        self._lock = threading.Lock()
        self._rr = itertools.count()
        self._rids = itertools.count(1)
        # session placement ledger: session_key -> replica rid of the last
        # successful dispatch.  A drain reads it twice: to *report* which
        # sessions remap (last_remapped_sessions) and to *migrate* the
        # drained backend's exported KV state to those sessions' new
        # rendezvous homes (_migrate_kv).  Bounded: old entries evict
        # LRU-ish rather than growing with total sessions ever served —
        # an evicted key only loses the warm hand-off, never correctness.
        self._session_homes: Dict[str, int] = {}
        self.session_ledger_cap = 65536
        self.last_remapped_sessions: Dict[int, List[str]] = {}
        self._latency = self.metrics.histogram("router.latency_s")
        self._completed = self.metrics.counter("router.completed")
        self._failed = self.metrics.counter("router.failed")
        self._requeued = self.metrics.counter("router.requeued")
        self._submitted = self.metrics.counter("router.submitted")
        # optional SLO engine (wired by serve/telemetry setup): a firing
        # burn alert feeds extra pressure into the brownout ladder
        self.slo: Optional[Any] = None
        # terminal snapshots of departed replicas: a removed/dead worker's
        # last-merged counters stay in cluster_snapshot() so cluster-wide
        # counters (and .le<i> histogram counts) never regress when a
        # worker leaves.  Bounded FIFO by rid; gauges/percentiles are
        # filtered out at capture (terminal_snapshot_view).
        self._departed: "OrderedDict[int, Dict[str, float]]" = OrderedDict()
        self.departed_cap = 32

    # -------------------------------------------------- replica pool
    def add_replica(self, backend=None, cfg: ReplicaConfig = ReplicaConfig(),
                    rid: Optional[int] = None, *,
                    spec: Optional[BackendSpec] = None,
                    transport: str = "thread",
                    kind: Optional[str] = None,
                    **transport_kwargs) -> Transport:
        """Add one replica.  ``backend`` (a live object) keeps PR 1's
        signature and runs on a thread; ``spec=`` + ``transport="process"``
        places the same replica in a spawned worker process instead.
        Extra keyword arguments pass through to ``make_transport`` — e.g.
        ``transport="socket"`` accepts ``artifacts=`` (the weight store
        fetches resolve against), ``listener=``, ``token=``, and
        ``spawn=False`` for operator-run remote workers."""
        worker = make_transport(transport, backend=backend, spec=spec,
                                cfg=cfg, rid=rid, metrics=self.metrics,
                                on_spill=self._on_spill, kind=kind,
                                **transport_kwargs)
        worker.start()
        with self._lock:
            self._replicas[worker.rid] = worker
        self._set_pool_gauge()
        return worker

    def remove_replica(self, rid: int, drain: bool = True,
                       migrate: bool = True) -> None:
        """Take a replica out of rotation; by default let it finish its
        inbox first (graceful drain).

        Removing a replica remaps its rendezvous-hashed sessions — and
        *only* its sessions: every key homed on a surviving replica keeps
        its placement (the rendezvous property,
        ``tests/test_cluster.py::test_drain_remaps_only_drained_sessions``).
        With ``migrate=True`` (the default) the drained backend's exported
        KV state — published by the replica driver just before the drained
        signal — is shipped to each remapped session's new rendezvous
        home, so those sessions resume *warm* (block-exact prefix reuse)
        instead of restarting cold.  Backends that publish nothing (echo
        workers, dense engines) keep the old log-and-forget behavior via
        ``last_remapped_sessions`` / ``router.sessions_remapped``."""
        with self._lock:
            worker = self._replicas.pop(rid, None)
        remapped = self._note_remapped_sessions(rid)
        self._set_pool_gauge()
        self.breaker.forget(rid)
        if worker is not None and drain:
            worker.drain()
            if migrate:
                self._migrate_kv(worker, remapped)
        if worker is not None:
            # after the drain: the final heartbeat's snapshot is the
            # freshest view of the worker's lifetime counters
            self._retain_departed(worker)

    def _migrate_kv(self, worker: Transport,
                    remapped: List[str]) -> None:
        """Warm session migration: ship the drained worker's KV export to
        each remapped session's new rendezvous home as a
        ``(KV_IMPORT_TAG, state)`` payload, offered directly (admission
        was already paid by the original requests).  One frame per
        distinct target replica; imports are idempotent on the far side,
        so at-least-once delivery — and a later retry landing the same
        sessions' requests next to the import in one batch — is safe."""
        state = getattr(worker, "kv_state", None)
        if state is None or not remapped:
            return
        same_kind = [w for w in self.alive_replicas()
                     if w.kind == worker.kind]
        if not same_kind:
            return
        targets: Dict[int, Transport] = {}
        for key in remapped:
            home = max(same_kind,
                       key=lambda w: _rendezvous_weight(key, w.rid))
            targets[home.rid] = home
        shipped = 0
        for home in targets.values():
            req = ClusterRequest((KV_IMPORT_TAG, state), kind=worker.kind,
                                 rid=next(self._rids),
                                 submitted_s=time.monotonic())
            if home.offer(req):
                shipped += 1
            else:
                self.metrics.counter("router.kv_migrate_failed").inc()
        if shipped:
            self.metrics.counter("router.sessions_migrated") \
                .inc(len(remapped))
            self.metrics.counter("router.kv_migrations").inc(shipped)
            current_recorder().record("session_migrated",
                                      replica=worker.rid,
                                      sessions=len(remapped),
                                      targets=shipped)

    def _note_remapped_sessions(self, rid: int) -> List[str]:
        with self._lock:
            remapped = sorted(k for k, home in self._session_homes.items()
                              if home == rid)
            for k in remapped:
                del self._session_homes[k]
            if not remapped and rid in self.last_remapped_sessions:
                # second notification for the same replica (e.g. a drain
                # followed by its death spill): don't clobber the export
                return []
            self.last_remapped_sessions[rid] = remapped
            while len(self.last_remapped_sessions) > 64:  # bounded history
                self.last_remapped_sessions.pop(
                    next(iter(self.last_remapped_sessions)))
        if remapped:
            self.metrics.counter("router.sessions_remapped") \
                .inc(len(remapped))
            log.info("replica %d removed: %d session(s) remap: %s", rid,
                     len(remapped),
                     ", ".join(remapped[:16]) +
                     (" …" if len(remapped) > 16 else ""))
        return remapped

    def _retain_departed(self, worker: Transport) -> None:
        """Keep a departed replica's monotone counters in the cluster
        merge (bounded; see ``cluster_snapshot``).  Thread replicas share
        the router registry and ship an empty snapshot — nothing to do."""
        snap = terminal_snapshot_view(worker.metrics_snapshot())
        if not snap:
            return
        with self._lock:
            self._departed[worker.rid] = snap
            while len(self._departed) > self.departed_cap:
                self._departed.popitem(last=False)

    def alive_replicas(self) -> List[Transport]:
        with self._lock:
            return [w for w in self._replicas.values() if w.alive]

    def n_alive(self) -> int:
        return len(self.alive_replicas())

    def queue_depth(self, kind: Optional[str] = None) -> int:
        """Outstanding cost (inbox + in-flight) over alive replicas —
        cluster-wide, or restricted to one backend kind."""
        return sum(w.outstanding_cost() for w in self.alive_replicas()
                   if kind is None or w.kind == kind)

    def _set_pool_gauge(self):
        self.metrics.gauge("router.replicas").set(self.n_alive())

    # -------------------------------------------------- dispatch policies
    def _ranked(self, req: ClusterRequest) -> List[Transport]:
        """Alive replicas in dispatch-preference order for this request.
        Dead transports are never candidates (see
        ``tests/test_transport.py`` for the property test)."""
        alive = sorted((w for w in self.alive_replicas()
                        if self.breaker.allow(w.rid)),
                       key=lambda w: w.rid)
        if req.kind is not None:
            # strict: a kind with no live replica sheds explicitly rather
            # than falling back to wrong-kind backends (whose process()
            # would raise on the foreign payload and cascade-kill the pool)
            alive = [w for w in alive if w.kind == req.kind]
        if not alive:
            return []
        if self.policy == "least_loaded":
            return sorted(alive, key=lambda w: (w.outstanding_cost(), w.rid))
        if self.policy == "session_affinity" and req.session_key is not None:
            return sorted(alive, key=lambda w: _rendezvous_weight(
                req.session_key, w.rid), reverse=True)
        k = next(self._rr) % len(alive)
        return alive[k:] + alive[:k]

    # -------------------------------------------------- submission
    def submit(self, payload: Any, *, cost: int = 1,
               session_key: Optional[str] = None,
               kind: Optional[str] = None,
               timeout_s: float = 30.0,
               on_partial: Optional[Callable[[Any], None]] = None,
               ) -> ClusterRequest:
        """``on_partial(frame)`` streams partial results (e.g. per-K-step
        token slices from an LM engine) while the request is in flight;
        the final result still arrives through :meth:`wait`."""
        now = time.monotonic()
        req = ClusterRequest(payload, cost=cost, session_key=session_key,
                             kind=kind, deadline_s=now + timeout_s,
                             rid=next(self._rids), submitted_s=now,
                             on_partial=on_partial, metrics=self.metrics)
        self._submitted.inc()
        # trace root: the sampling decision for this request's entire
        # cross-host span tree is made here, once
        root = current_tracer().span("request", rid=req.rid, cost=cost,
                                     kind=kind)
        if root.recording:
            req.trace_span = root
            req.trace_ctx = root.context()
        current_recorder().record("submit", rid=req.rid, cost=cost,
                                  backend=kind)
        self._tick_brownout()
        if self.admission is not None:
            with current_tracer().span("admission.decide",
                                       parent=root) as asp:
                kv_frac = None
                if self.admission.cfg.min_kv_headroom_frac > 0:
                    kv_frac = self.kv_free_fraction()
                scale = self.brownout.admission_scale() \
                    if self.brownout is not None else 1.0
                shed = self.admission.decide(self.queue_depth(kind), cost,
                                             req.deadline_s, now, kind=kind,
                                             kv_free_frac=kv_frac,
                                             scale=scale)
                asp.tag(shed=shed is not None)
            if shed is not None:
                current_recorder().record("shed", rid=req.rid,
                                          reason=shed.reason)
                req.reject(shed)
                return req
        with current_tracer().span("router.dispatch", parent=root) as dsp:
            self._dispatch(req)
            if req.replica_rid is None and not req.done.is_set():
                dsp.tag(replica="pending")
        return req

    def _tick_brownout(self) -> int:
        """Advance the brownout ladder from the live overload signals
        (queue occupancy vs the admission bound, KV-pool occupancy) and
        broadcast the level to every replica on a transition."""
        bo = self.brownout
        if bo is None:
            return 0
        qmax = self.admission.cfg.max_queue_cost \
            if self.admission is not None else 0
        qfrac = self.queue_depth() / qmax if qmax else 0.0
        kv = self.kv_free_fraction()
        slo_pressure = self.slo.pressure() if self.slo is not None else 0.0
        lvl = bo.tick(qfrac, 1.0 - kv if kv is not None else 0.0,
                      extra=slo_pressure)
        self.metrics.gauge("router.brownout_level").set(lvl)
        if bo.changed:
            current_recorder().record("brownout_level", level=lvl,
                                      queue_frac=round(qfrac, 3))
            self.metrics.counter("router.brownout_transitions").inc()
            for w in self.alive_replicas():
                fn = getattr(w, "set_brownout", None)
                if fn is not None:
                    fn(lvl)
        return lvl

    def kv_free_fraction(self) -> Optional[float]:
        """Cluster-wide paged-KV headroom: free / total blocks summed over
        the router registry (thread replicas write it directly) and every
        alive worker's last heartbeat snapshot.  Reads just the two
        ``engine.kv_blocks_*`` gauges — this runs on every admission
        decision, so it must not pay ``cluster_snapshot``'s full
        merge-and-recompute-percentiles cost.  None when no replica
        reports a pool (dense engines, non-LM backends)."""
        total = self.metrics.gauge("engine.kv_blocks_total").value
        free = self.metrics.gauge("engine.kv_blocks_free").value
        for w in self.alive_replicas():
            snap = w.metrics_snapshot()
            total += snap.get("engine.kv_blocks_total", 0.0)
            free += snap.get("engine.kv_blocks_free", 0.0)
        if total <= 0:
            return None
        return free / total

    def _note_session_home(self, key: str, rid: int) -> None:
        with self._lock:
            self._session_homes.pop(key, None)    # refresh insertion order
            self._session_homes[key] = rid
            while len(self._session_homes) > self.session_ledger_cap:
                self._session_homes.pop(next(iter(self._session_homes)))

    def _dispatch(self, req: ClusterRequest) -> None:
        if req.cancelled:
            # a cancel can only precede dispatch on the respill path, but
            # the guard is cheap and makes "never re-dispatched" local
            req.finish_cancelled()
            return
        for worker in self._ranked(req):
            attempts_before = req.attempts
            if worker.offer(req):
                # offer() may report True because a concurrent spill took
                # ownership (the fault path requeues it elsewhere and bumps
                # req.attempts); only an undisturbed accept makes this
                # worker the session's home
                self.breaker.note_dispatch(worker.rid)
                if req.session_key is not None and \
                        req.attempts == attempts_before:
                    self._note_session_home(req.session_key, worker.rid)
                self.metrics.gauge("router.queue_depth").set(self.queue_depth())
                return
        # every alive inbox full (or pool empty): explicit backpressure
        self.metrics.counter("router.shed_backpressure").inc()
        req.reject(Rejected("queue_full", "all replica inboxes full"))

    def wait(self, req: ClusterRequest,
             timeout: Optional[float] = None) -> Any:
        """Block for the result.  On timeout the request is *still in
        flight* and a typed :class:`WaitTimeout` comes back instead of a
        leaked falsy result — the documented follow-up is
        ``router.cancel(req)`` (a later wait can still observe the
        terminal state the cancel produces)."""
        out = req.wait(timeout)
        if not req.done.is_set():
            self.metrics.counter("router.wait_timeout").inc()
            return WaitTimeout(rid=req.rid,
                               waited_s=timeout if timeout is not None
                               else 0.0)
        if req.status is Status.OK:
            self._completed.inc()
            self._latency.observe(req.finished_s - req.submitted_s)
            if req.replica_rid is not None:
                # a clean completion resolves that replica's half-open
                # probe (if this request happened to be it)
                self.breaker.record_ack(req.replica_rid)
        return out

    def cancel(self, req: ClusterRequest) -> None:
        """Cancel an in-flight request: flag it so no router path ever
        moves it again (dispatch, spill, requeue), then fan a best-effort
        ``("cancel", rid)`` to every alive replica — rids are globally
        unique and never reused, so broadcasting is race-free even while
        the request migrates between replicas.  The terminal state arrives
        either as the replica's ``Terminal("cancelled")`` ack (with any
        partial tokens) or, if the request is currently between homes,
        from the requeue loop observing the flag.  A cancel that loses the
        race with a genuine completion is a no-op: the first terminal
        state wins."""
        if req.done.is_set():
            return
        req.cancelled = True
        self.metrics.counter("router.cancelled").inc()
        current_recorder().record("cancelled", rid=req.rid, where="router")
        for w in self.alive_replicas():
            fn = getattr(w, "cancel", None)
            if fn is not None:
                fn(req.rid)

    # -------------------------------------------------- fault path
    def _on_spill(self, spilled: List[ClusterRequest],
                  dead: Transport) -> None:
        """Requeue a spilling replica's unacknowledged requests.

        Two spill sources share this path: a *dead* transport (crash,
        heartbeat timeout) is removed from the pool and its requests go to
        survivors only; a transport that is merely *disconnected* (socket
        drop inside its reconnect window, ``dead.alive`` still True) stays
        in the pool and may even re-accept its own spilled requests once
        the worker reconnects.  At-least-once either way: a request whose
        batch finished compute but was never acknowledged is re-executed;
        none are lost."""
        if not dead.alive:
            with self._lock:
                self._replicas.pop(dead.rid, None)
            self._retain_departed(dead)
            self._note_remapped_sessions(dead.rid)
            self._set_pool_gauge()
            # a dead transport leaves the pool for good (rids are never
            # reused) — drop its breaker state instead of growing the map
            self.breaker.forget(dead.rid)
        # circuit breaker: a spill from a transport that *stays* in the
        # pool (socket flap inside its reconnect window) is a strike — a
        # crash-looping replica trips into quarantine instead of being
        # ranked first on the very next dispatch
        elif self.breaker.record_crash(dead.rid):
            self.metrics.counter("router.quarantined").inc()
            current_recorder().record("quarantine", replica=dead.rid,
                                      state=self.breaker.state(dead.rid))
        exclude = dead.rid if not dead.alive else None
        for req in spilled:
            req.attempts += 1
            if not dead.alive:
                req.killed_replicas.add(dead.rid)
            # the replacement replica re-runs from scratch and re-streams
            # every token: reset the partial-frame view so incremental
            # consumers don't render the first attempt's prefix twice
            req.reset_partials()
            # refresh the dispatched context's attempt number so spans
            # from the dead attempt stay tagged apart from the retry's
            if req.trace_span is not None:
                req.trace_ctx = req.trace_span.context(
                    attempt=req.attempts)
            current_recorder().record("spill", rid=req.rid,
                                      replica=dead.rid,
                                      attempt=req.attempts)
            if req.cancelled:
                # never re-dispatch a cancelled rid — terminal right here
                req.finish_cancelled()
                self.metrics.counter("router.cancelled_on_spill").inc()
                continue
            if len(req.killed_replicas) >= self.poison_threshold:
                # this request has now taken down N distinct replicas:
                # stop feeding it to the fleet
                req.finish_reason = "poison"
                self.metrics.counter("router.poisoned").inc()
                current_recorder().record(
                    "poison", rid=req.rid,
                    replicas=sorted(req.killed_replicas))
                req.fail(RuntimeError(
                    f"request {req.rid}: poison — killed "
                    f"{len(req.killed_replicas)} replicas "
                    f"{sorted(req.killed_replicas)}"))
                self._failed.inc()
                continue
            if req.attempts > self.max_retries:
                req.fail(RuntimeError(
                    f"request {req.rid}: retries exhausted after replica "
                    f"{dead.rid} crash"))
                self._failed.inc()
                continue
            # bounded exponential backoff before the re-offer: a crash
            # dumps a burst — attempt 1 waits base, attempt 2 waits 2x,
            # ... capped, so survivors absorb the wave instead of a
            # synchronized stampede
            delay = min(self.retry_backoff_base_s * (2 ** (req.attempts - 1)),
                        self.retry_backoff_max_s)
            if delay > 0:
                self.metrics.counter("router.retry_backoff").inc()
                current_recorder().record("retry_backoff", rid=req.rid,
                                          attempt=req.attempts,
                                          delay_s=round(delay, 4))
                time.sleep(delay)
            if not self._requeue_blocking(req, exclude=exclude):
                req.fail(RuntimeError(
                    f"request {req.rid}: no surviving replica accepted it"))
                self._failed.inc()
            elif not req.done.is_set():
                self._requeued.inc()

    def _requeue_blocking(self, req: ClusterRequest,
                          exclude: Optional[int]) -> bool:
        """Offer to survivors, waiting out transient inbox fullness (a crash
        dumps a burst on the pool) up to ``requeue_timeout_s``.  Returns
        True when the request was *handled* — accepted by a survivor, or
        terminally finished here because it was cancelled / expired while
        waiting (re-dispatching either would waste a survivor's slot on
        work nobody wants)."""
        t_end = time.monotonic() + self.requeue_timeout_s
        while True:
            if req.cancelled or req.done.is_set():
                req.finish_cancelled()      # no-op if already terminal
                return True
            now = time.monotonic()
            if now > req.deadline_s:
                current_recorder().record("deadline_expired", rid=req.rid,
                                          where="requeue")
                self.metrics.counter("router.expired_on_requeue").inc()
                req.finish_expired()
                return True
            if now >= t_end:
                return False
            ranked = [w for w in self._ranked(req) if w.rid != exclude]
            if not ranked:
                return False
            for worker in ranked:
                attempts_before = req.attempts
                if worker.offer(req):
                    self.breaker.note_dispatch(worker.rid)
                    if req.session_key is not None and \
                            req.attempts == attempts_before:
                        self._note_session_home(req.session_key, worker.rid)
                    return True
            time.sleep(0.002)

    # -------------------------------------------------- service bridge
    def process_batch(self, payloads: List[Any],
                      timeout_s: float = 30.0,
                      cost_fn: Optional[Callable[[Any], int]] = None,
                      session_fn: Optional[Callable[[Any], Optional[str]]] = None,
                      ) -> List[Any]:
        """Fan a batch out over the pool and wait for every result — the
        ``step_fn`` contract, so an ``MLaaSService`` front can target a
        cluster exactly like a local step (see ``as_step_fn``).

        Per-payload outcomes: the backend result, a :class:`Rejected`, or
        ``None`` for a failed request (retries exhausted)."""
        reqs = [self.submit(p,
                            cost=cost_fn(p) if cost_fn else 1,
                            session_key=session_fn(p) if session_fn else None,
                            timeout_s=timeout_s)
                for p in payloads]
        return [self.wait(r, timeout=timeout_s + self.requeue_timeout_s)
                for r in reqs]

    def as_step_fn(self, **kwargs) -> Callable[[List[Any]], List[Any]]:
        return lambda payloads: self.process_batch(payloads, **kwargs)

    # -------------------------------------------------- telemetry
    def cluster_snapshot(self) -> Dict[str, float]:
        """One flat view of the whole service: the router-side registry
        merged with each alive worker's last shipped snapshot (process
        replicas report their counters over the heartbeat channel; thread
        replicas already share the registry) plus the retained terminal
        snapshots of departed replicas — cluster counters and histogram
        bucket counts stay monotone when a worker dies or is removed."""
        with self._lock:
            departed = list(self._departed.values())
        return merge_snapshots(self.metrics.snapshot(),
                               [w.metrics_snapshot()
                                for w in self.alive_replicas()] + departed)

    # -------------------------------------------------- lifecycle
    def stop(self, drain: bool = True) -> None:
        with self._lock:
            workers = list(self._replicas.values())
            self._replicas.clear()
        for w in workers:
            if drain:
                w.drain()
            else:
                w.inject_crash()
                w.join()
            self._retain_departed(w)
        self._set_pool_gauge()
