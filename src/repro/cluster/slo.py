"""SLO objectives, error-budget accounting and multi-window burn-rate
alerts over the PR 9 finish-reason taxonomy.

An :class:`SLOObjective` states what "good" means for a backend kind:

* **latency** — a threshold and a target fraction ("99% of requests
  finish under 1s"), evaluated from the windowed bucket deltas of a
  latency histogram stem (:class:`~repro.cluster.timeseries
  .TimeSeriesStore`), so the burn rate reflects *recent* requests, not
  lifetime averages;
* **availability** — the fraction of terminal requests that did not burn
  budget.  ``deadline`` misses, ``poison`` quarantines and
  ``kv_pool_exhausted`` victims burn; ``cancelled`` is the caller's
  choice and does not (it is excluded from the denominator too).

Alerting follows the SRE multi-window burn-rate pattern: a (fast, slow)
window pair fires only when BOTH exceed the pair's burn threshold — the
fast window gives low detection latency, the slow window keeps a blip
from paging — and clears after ``clear_after`` consecutive quiet ticks
(hysteresis against flapping).  Transitions emit FlightRecorder events
(``slo_burn_fired`` / ``slo_burn_cleared``) and every evaluation
publishes ``slo.*`` gauges into the registry, which the stats endpoint
and dashboard read back out of the snapshot.  A firing alert can
optionally be fed into :class:`~repro.cluster.overload
.BrownoutController` as extra pressure via :meth:`SLOEngine.pressure`.

Window lengths here default to production-ish scales; tests use
:func:`test_scaled_objective` to shrink them to the chaos-harness
timescale (sub-second windows) without changing any of the logic.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from .metrics import HIST_BUCKET_BOUNDS, MetricsRegistry
from .timeseries import TimeSeriesStore

__all__ = ["BurnWindow", "SLOObjective", "SLOEngine",
           "test_scaled_objective", "BAD_FINISH_REASONS",
           "NEUTRAL_FINISH_REASONS"]

# PR 9 finish-reason taxonomy, split by budget impact.  ``deadline``:
# the service missed the caller's deadline; ``poison``: quarantined
# after repeatedly killing replicas; ``kv_pool_exhausted``: victimized
# for capacity.  ``cancelled`` is caller-initiated and neutral.
BAD_FINISH_REASONS: Tuple[str, ...] = ("deadline", "poison",
                                       "kv_pool_exhausted")
NEUTRAL_FINISH_REASONS: Tuple[str, ...] = ("cancelled",)


@dataclasses.dataclass(frozen=True)
class BurnWindow:
    """One (fast, slow) window pair with its burn-rate threshold: the
    alert condition is ``burn(fast) > threshold AND burn(slow) >
    threshold``."""
    fast_s: float
    slow_s: float
    threshold: float


@dataclasses.dataclass(frozen=True)
class SLOObjective:
    kind: str = "any"                      # backend kind this SLO covers
    latency_stem: str = "router.latency_s"
    latency_threshold_s: float = 1.0
    latency_target: float = 0.99           # fraction under the threshold
    availability_target: float = 0.99
    # classic page/ticket pairs (fractions of a 30-day budget)
    windows: Tuple[BurnWindow, ...] = (
        BurnWindow(fast_s=300.0, slow_s=3600.0, threshold=14.4),
        BurnWindow(fast_s=1800.0, slow_s=21600.0, threshold=6.0),
    )
    bad_reasons: Tuple[str, ...] = BAD_FINISH_REASONS
    neutral_reasons: Tuple[str, ...] = NEUTRAL_FINISH_REASONS
    clear_after: int = 2                   # quiet ticks before clearing

    @property
    def latency_budget(self) -> float:
        return max(1.0 - self.latency_target, 1e-9)

    @property
    def availability_budget(self) -> float:
        return max(1.0 - self.availability_target, 1e-9)


def test_scaled_objective(kind: str = "any",
                          fast_s: float = 0.4, slow_s: float = 1.2,
                          threshold: float = 2.0,
                          **overrides: Any) -> SLOObjective:
    """The same objective shrunk to chaos-harness timescales: one window
    pair of sub-second fast/slow windows and a low burn threshold, so an
    injected fault burst trips the alert within a few sampler ticks."""
    kw: Dict[str, Any] = dict(
        kind=kind,
        windows=(BurnWindow(fast_s=fast_s, slow_s=slow_s,
                            threshold=threshold),),
        clear_after=1,
    )
    kw.update(overrides)
    return SLOObjective(**kw)


class _Alert:
    """Firing/clearing state machine for one (objective, sub-objective)."""

    __slots__ = ("state", "quiet_ticks", "fired_count", "cleared_count",
                 "last_burns")

    def __init__(self):
        self.state = "ok"
        self.quiet_ticks = 0
        self.fired_count = 0
        self.cleared_count = 0
        self.last_burns: List[Tuple[float, float, float]] = []

    def step(self, exceeding: bool, clear_after: int) -> Optional[str]:
        """Advance one tick; returns 'fired'/'cleared' on a transition."""
        if exceeding:
            self.quiet_ticks = 0
            if self.state == "ok":
                self.state = "firing"
                self.fired_count += 1
                return "fired"
            return None
        if self.state == "firing":
            self.quiet_ticks += 1
            if self.quiet_ticks >= clear_after:
                self.state = "ok"
                self.quiet_ticks = 0
                self.cleared_count += 1
                return "cleared"
        return None


class SLOEngine:
    """Evaluate objectives against a :class:`TimeSeriesStore` each tick;
    publish gauges, emit FlightRecorder events on transitions, account
    the lifetime error budget, and expose brownout pressure."""

    def __init__(self, objectives: Sequence[SLOObjective],
                 registry: MetricsRegistry,
                 recorder: Optional[Any] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.objectives = list(objectives)
        self.registry = registry
        self.recorder = recorder
        self._clock = clock
        self._alerts: Dict[Tuple[str, str], _Alert] = {}
        # lifetime budget accounting, accumulated from per-tick deltas
        self._cum: Dict[Tuple[str, str], List[float]] = {}
        self._last_tick_t: Optional[float] = None
        self.ticks = 0

    # -- burn-rate math -------------------------------------------------
    @staticmethod
    def _latency_bad_fraction(store: TimeSeriesStore, stem: str,
                              threshold_s: float, window_s: float,
                              now: float) -> Tuple[float, float]:
        """(bad_fraction, total) of windowed observations over the latency
        threshold, with linear partial credit inside the bucket that
        straddles the threshold (bucket-resolution exactness)."""
        counts = store.window_bucket_counts(stem, window_s, now=now)
        total = sum(counts)
        if total <= 0:
            return 0.0, 0.0
        good = 0.0
        for i, c in enumerate(counts):
            if c <= 0:
                continue
            if i >= len(HIST_BUCKET_BOUNDS):
                continue                       # overflow: all bad
            lo = HIST_BUCKET_BOUNDS[i - 1] if i else 0.0
            hi = HIST_BUCKET_BOUNDS[i]
            if hi <= threshold_s:
                good += c
            elif lo < threshold_s:
                good += c * (threshold_s - lo) / (hi - lo)
        return max(1.0 - good / total, 0.0), total

    @staticmethod
    def _availability_bad_fraction(store: TimeSeriesStore,
                                   obj: SLOObjective, window_s: float,
                                   now: float) -> Tuple[float, float]:
        bad = sum(store.increase(f"router.finish.{r}", window_s, now=now)
                  for r in obj.bad_reasons)
        total = store.increase("router.finish.total", window_s, now=now)
        total -= sum(store.increase(f"router.finish.{r}", window_s,
                                    now=now) for r in obj.neutral_reasons)
        if total <= 0:
            return 0.0, 0.0
        return min(bad / total, 1.0), total

    def _burn(self, store: TimeSeriesStore, obj: SLOObjective, sub: str,
              window_s: float, now: float) -> float:
        if sub == "latency":
            frac, _ = self._latency_bad_fraction(
                store, obj.latency_stem, obj.latency_threshold_s,
                window_s, now)
            return frac / obj.latency_budget
        frac, _ = self._availability_bad_fraction(store, obj, window_s,
                                                  now)
        return frac / obj.availability_budget

    # -- tick -----------------------------------------------------------
    def tick(self, store: TimeSeriesStore,
             now: Optional[float] = None) -> None:
        t = self._clock() if now is None else float(now)
        tick_span = (t - self._last_tick_t
                     if self._last_tick_t is not None else 0.0)
        for obj in self.objectives:
            for sub in ("latency", "availability"):
                key = (obj.kind, sub)
                alert = self._alerts.get(key)
                if alert is None:
                    alert = self._alerts[key] = _Alert()
                burns: List[Tuple[float, float, float]] = []
                exceeding = False
                for w in obj.windows:
                    bf = self._burn(store, obj, sub, w.fast_s, t)
                    bs = self._burn(store, obj, sub, w.slow_s, t)
                    burns.append((bf, bs, w.threshold))
                    if bf > w.threshold and bs > w.threshold:
                        exceeding = True
                alert.last_burns = burns
                transition = alert.step(exceeding, obj.clear_after)
                self._account(store, obj, sub, tick_span, t)
                self._publish(obj, sub, alert, burns)
                if transition and self.recorder is not None:
                    bf, bs, thr = burns[0]
                    self.recorder.record(
                        f"slo_burn_{transition}", objective=obj.kind,
                        slo=sub, burn_fast=round(bf, 3),
                        burn_slow=round(bs, 3), threshold=thr,
                        fast_window_s=obj.windows[0].fast_s,
                        slow_window_s=obj.windows[0].slow_s)
        self._last_tick_t = t
        self.ticks += 1

    def _account(self, store: TimeSeriesStore, obj: SLOObjective,
                 sub: str, tick_span: float, now: float) -> None:
        """Accumulate lifetime (bad, total) from this tick's delta."""
        key = (obj.kind, sub)
        cum = self._cum.get(key)
        if cum is None:
            cum = self._cum[key] = [0.0, 0.0]
        if tick_span <= 0:
            return
        if sub == "latency":
            frac, total = self._latency_bad_fraction(
                store, obj.latency_stem, obj.latency_threshold_s,
                tick_span, now)
        else:
            frac, total = self._availability_bad_fraction(
                store, obj, tick_span, now)
        cum[0] += frac * total
        cum[1] += total

    def _publish(self, obj: SLOObjective, sub: str, alert: _Alert,
                 burns: List[Tuple[float, float, float]]) -> None:
        base = f"slo.{obj.kind}.{sub}"
        bf, bs, _thr = burns[0]
        g = self.registry.gauge
        g(f"{base}.burn_fast").set(bf)
        g(f"{base}.burn_slow").set(bs)
        g(f"{base}.firing").set(1.0 if alert.state == "firing" else 0.0)
        g(f"{base}.fired_total").set(float(alert.fired_count))
        g(f"{base}.budget_remaining").set(
            self.budget_remaining(obj.kind, sub))

    # -- read side ------------------------------------------------------
    def budget_remaining(self, kind: str, sub: str) -> float:
        """Lifetime error budget left, as a fraction of the allowance
        (1.0 = untouched, 0.0 = exhausted, negative = overspent)."""
        obj = next((o for o in self.objectives if o.kind == kind), None)
        cum = self._cum.get((kind, sub))
        if obj is None or cum is None or cum[1] <= 0:
            return 1.0
        budget = (obj.latency_budget if sub == "latency"
                  else obj.availability_budget)
        return 1.0 - (cum[0] / cum[1]) / budget

    def firing(self) -> List[Tuple[str, str]]:
        return [k for k, a in self._alerts.items() if a.state == "firing"]

    def pressure(self) -> float:
        """Extra brownout pressure in [0, 1]: zero while healthy; a
        firing alert contributes its fast-burn overshoot (burn at 2x the
        threshold saturates to 1.0).  Feed into
        ``BrownoutController.tick`` alongside queue/KV pressure."""
        worst = 0.0
        for alert in self._alerts.values():
            if alert.state != "firing":
                continue
            for bf, _bs, thr in alert.last_burns:
                if thr > 0:
                    worst = max(worst, min(bf / thr - 1.0, 1.0))
        return max(worst, 0.0)

    def status(self) -> Dict[str, Any]:
        """Schema served at ``/slo.json`` and rendered on the dash."""
        out: Dict[str, Any] = {"objectives": [], "ticks": self.ticks,
                               "pressure": self.pressure()}
        for obj in self.objectives:
            entry: Dict[str, Any] = {
                "kind": obj.kind,
                "latency_threshold_s": obj.latency_threshold_s,
                "latency_target": obj.latency_target,
                "availability_target": obj.availability_target,
                "alerts": {},
            }
            for sub in ("latency", "availability"):
                alert = self._alerts.get((obj.kind, sub))
                if alert is None:
                    continue
                entry["alerts"][sub] = {
                    "state": alert.state,
                    "fired_count": alert.fired_count,
                    "cleared_count": alert.cleared_count,
                    "burns": [
                        {"fast": bf, "slow": bs, "threshold": thr}
                        for bf, bs, thr in alert.last_burns],
                    "budget_remaining": self.budget_remaining(obj.kind,
                                                              sub),
                }
            out["objectives"].append(entry)
        return out
