"""Reactive autoscaler (paper §3: "run-time infrastructure scaling";
Spark's dynamic allocation, re-read onto replica pools).

Watches two signals and resizes the replica pool between configured bounds:

  * queue pressure — cluster-wide outstanding cost per alive replica above
    ``scale_up_depth`` adds a replica; sustained idleness below
    ``scale_down_depth`` drains one (graceful: it finishes its inbox).
  * fall-behind    — the stream runtime's "processing time exceeds the
    micro-batch period" signal (``StreamRuntime.falling_behind``) forces a
    scale-up even when queues look shallow, because ingest is about to pile
    up (paper Fig. 6b's saturation point).

Weight placement: when a pool resize coincides with a device-mesh change,
pass an ``ElasticRunner`` plus a ``make_mesh(n)`` factory and the scaler
re-places parameters via ``ElasticRunner.rescale`` (mesh-invariant numerics
are covered by ``tests/test_fault.py``).

``tick()`` is deliberately pull-based and side-effect-explicit so tests can
drive it with a fake clock; ``start()`` runs it on a daemon thread.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, List, Optional

from repro.cluster.backends import BackendSpec
from repro.cluster.metrics import MetricsRegistry, null_registry
from repro.cluster.replica import ReplicaConfig
from repro.cluster.router import Router


@dataclasses.dataclass(frozen=True)
class AutoscalerConfig:
    min_replicas: int = 1
    max_replicas: int = 8
    scale_up_depth: float = 8.0       # outstanding cost per replica
    scale_down_depth: float = 1.0
    cooldown_s: float = 1.0           # min gap between scale actions
    idle_ticks_to_drain: int = 3      # consecutive calm ticks before drain
    replica_cfg: ReplicaConfig = ReplicaConfig()


@dataclasses.dataclass
class ScaleEvent:
    t: float
    action: str                       # "up" | "down"
    n_replicas: int                   # pool size after the action
    reason: str


class Autoscaler:
    def __init__(self, router: Router, backend_factory: Callable[[], object],
                 cfg: AutoscalerConfig = AutoscalerConfig(),
                 fall_behind: Optional[Callable[[], bool]] = None,
                 elastic=None, make_mesh: Optional[Callable[[int], object]] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 clock: Callable[[], float] = time.monotonic,
                 transport: str = "thread"):
        # ``backend_factory`` may return a live backend (placed on a thread)
        # or a serializable ``BackendSpec`` — required when ``transport`` is
        # "process", where the new replica is a spawned worker.
        self.router = router
        self.backend_factory = backend_factory
        self.transport = transport
        self.cfg = cfg
        self.fall_behind = fall_behind
        self.elastic = elastic
        self.make_mesh = make_mesh
        self.metrics = metrics if metrics is not None else null_registry()
        self.clock = clock
        self.events: List[ScaleEvent] = []
        self._last_action_t = float("-inf")
        self._idle_ticks = 0
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -------------------------------------------------- policy
    def tick(self, now: Optional[float] = None) -> Optional[ScaleEvent]:
        now = self.clock() if now is None else now
        n = self.router.n_alive()
        depth = self.router.queue_depth()
        per_replica = depth / max(n, 1)
        self.metrics.gauge("autoscaler.depth_per_replica").set(per_replica)
        if now - self._last_action_t < self.cfg.cooldown_s:
            return None

        behind = bool(self.fall_behind()) if self.fall_behind else False
        if (per_replica > self.cfg.scale_up_depth or behind) \
                and n < self.cfg.max_replicas:
            self._idle_ticks = 0
            return self._scale_up(now, "fall_behind" if behind
                                  else f"depth/replica={per_replica:.1f}")

        if per_replica < self.cfg.scale_down_depth and n > self.cfg.min_replicas:
            self._idle_ticks += 1
            if self._idle_ticks >= self.cfg.idle_ticks_to_drain:
                self._idle_ticks = 0
                return self._scale_down(now, f"idle x{self.cfg.idle_ticks_to_drain}")
        else:
            self._idle_ticks = 0
        return None

    def _replace_weights(self, n: int):
        if self.elastic is not None and self.make_mesh is not None:
            self.elastic.rescale(self.make_mesh(n))

    def _scale_up(self, now: float, reason: str) -> ScaleEvent:
        # NB: with transport="process" this blocks the tick for the worker
        # spawn (interpreter + backend build; bounded by
        # replica_cfg.spawn_timeout_s) and can fail — a failed spawn must
        # not kill the autoscaler loop, so it becomes an "up_failed" event
        # and the cooldown backs the retry off.
        try:
            made = self.backend_factory()
            if isinstance(made, BackendSpec):
                self.router.add_replica(spec=made, cfg=self.cfg.replica_cfg,
                                        transport=self.transport)
            else:
                self.router.add_replica(made, self.cfg.replica_cfg)
        except Exception as e:          # noqa: BLE001 - spawn/build failure
            self._last_action_t = now
            self.metrics.counter("autoscaler.scale_up_failures").inc()
            ev = ScaleEvent(now, "up_failed", self.router.n_alive(), repr(e))
            self.events.append(ev)
            return ev
        n = self.router.n_alive()
        self._replace_weights(n)
        self._last_action_t = now
        ev = ScaleEvent(now, "up", n, reason)
        self.events.append(ev)
        self.metrics.counter("autoscaler.scale_ups").inc()
        return ev

    def _scale_down(self, now: float, reason: str) -> ScaleEvent:
        # drain the least-loaded replica (cheapest to finish)
        victim = min(self.router.alive_replicas(),
                     key=lambda w: (w.outstanding_cost(), -w.rid))
        self.router.remove_replica(victim.rid, drain=True)
        n = self.router.n_alive()
        self._replace_weights(n)
        self._last_action_t = now
        ev = ScaleEvent(now, "down", n, reason)
        self.events.append(ev)
        self.metrics.counter("autoscaler.scale_downs").inc()
        return ev

    # -------------------------------------------------- background mode
    def start(self, period_s: float = 0.1) -> "Autoscaler":
        def loop():
            while not self._stop.wait(period_s):
                self.tick()
        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="autoscaler")
        self._thread.start()
        return self

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5)
