"""Standalone socket-replica worker: the remote half of
:class:`~repro.cluster.transport.SocketTransport`.

Run on any host that can reach the parent's
:class:`~repro.cluster.wire.WorkerListener`::

    PYTHONPATH=src python -m repro.cluster.worker_main \
        --connect HOST:PORT --token TOKEN [--artifacts DIR]

Life of a worker:

  1. dial the listener and send the versioned hello
     ``("hello", PROTOCOL_VERSION, token, kind|None, spec_hash|None)`` —
     kind/hash are ``None`` on first contact (the spec has not been
     shipped yet) and the announced fingerprint thereafter;
  2. receive ``("welcome", rid, spec, cfg)``; on first contact resolve any
     ``artifact:<sha256>`` kwarg through the local content-addressed
     store, fetching missing blobs from the parent over this connection,
     then ``spec.build()`` the backend (the expensive step: jax import,
     weight load, compile);
  3. run :func:`~repro.cluster.replica.run_replica_loop` over the
     connection until it ends, then decide:
       * crashed (injected fault / backend exception) -> exit; the parent
         spills from its table;
       * drained (parent sent ``("drain",)``) -> clean exit;
       * disconnected (EOF / reset)           -> go to 1 and *reconnect*,
         reusing the already-built backend — a network blip costs a
         handshake, not a rebuild.

A ``("reject", reason)`` at step 1/2 — version mismatch, unknown token,
spec-fingerprint mismatch, dead transport — ends the worker: the parent
has decided this worker must not serve.
"""
from __future__ import annotations

import argparse
import threading
import time
from typing import Optional, Tuple

from repro.cluster.artifacts import (ArtifactStore, resolve_spec,
                                     spec_fingerprint)
from repro.cluster.metrics import MetricsRegistry
from repro.cluster.replica import run_replica_loop
from repro.cluster.transport import WorkerIO
from repro.cluster.wire import (PROTOCOL_VERSION, ChannelClosed,
                                SocketChannel, connect_channel)


def _dial(address: Tuple[str, int], window_s: float,
          retry_s: float = 0.1) -> Optional[SocketChannel]:
    """Retry-connect until the window closes (the listener may not be up
    yet, or a partition may still be healing)."""
    t_end = time.monotonic() + window_s
    while True:
        try:
            return connect_channel(address, timeout=max(retry_s, 1.0))
        except OSError:
            if time.monotonic() >= t_end:
                return None
            time.sleep(retry_s)


def _recv_blocking(chan: SocketChannel, timeout_s: float):
    t_end = time.monotonic() + timeout_s
    while time.monotonic() < t_end:
        msg = chan.recv(0.2)
        if msg is not None:
            return msg
    return None


def _fetch_over(chan: SocketChannel, digest: str, backlog: list,
                timeout_s: float = 15.0) -> Optional[bytes]:
    """Pull one artifact blob from the parent's store by content hash.

    One *attempt*: one ``("fetch", digest)`` frame, one bounded wait.
    ``resolve_spec`` wraps this in ``fetch_with_retry``, so a ``None``
    here (parent busy, frame lost) is retried with jittered backoff and
    each retry re-sends the request frame — the per-attempt timeout is
    deliberately short so retries happen while the build window is still
    open.  A late answer to a timed-out attempt is matched by digest on
    the next attempt; any non-artifact frame read while waiting (a drain
    or crash control frame racing the build) goes into ``backlog`` for
    the WorkerIO to replay — never silently dropped."""
    chan.send(("fetch", digest))
    t_end = time.monotonic() + timeout_s
    while time.monotonic() < t_end:
        msg = chan.recv(0.2)
        if msg is None:
            continue
        if msg[0] == "artifact" and msg[1] == digest:
            return msg[2]
        backlog.append(msg)
    return None


def run_worker(address: Tuple[str, int], token: str,
               artifacts_dir: Optional[str] = None,
               connect_window_s: float = 30.0,
               protocol_version: int = PROTOCOL_VERSION) -> None:
    """Connect-serve-reconnect until crashed, drained, or rejected."""
    address = (str(address[0]), int(address[1]))
    store = ArtifactStore(artifacts_dir)
    registry = MetricsRegistry()
    from repro.cluster.metrics import set_worker_registry
    set_worker_registry(registry)   # builders adopt the heartbeat registry
    # follower-mode tracer (sample_rate=0: never roots a trace, always
    # honors an incoming sampled context) + flight recorder; both are
    # re-labeled with the real rid once the welcome assigns it
    from repro.cluster.tracing import (FlightRecorder, Tracer, set_recorder,
                                       set_tracer)
    tracer = Tracer(enabled=True, sample_rate=0.0, replica="worker")
    set_tracer(tracer)
    recorder = FlightRecorder(replica="worker")
    set_recorder(recorder)
    backend = None
    announce_kind: Optional[str] = None
    announce_hash: Optional[str] = None
    window = connect_window_s
    while True:
        chan = _dial(address, window)
        if chan is None:
            return                      # listener unreachable: give up
        try:
            chan.send(("hello", protocol_version, token,
                       announce_kind, announce_hash))
            msg = _recv_blocking(chan, timeout_s=10.0)
        except ChannelClosed:
            chan.close()
            continue                    # races with listener churn: redial
        if msg is None or not isinstance(msg, (tuple, list)) \
                or msg[0] != "welcome":
            chan.close()
            return                      # rejected (or garbled): stand down
        _tag, rid, spec, cfg = msg[:4]
        tracer.replica = str(rid)
        recorder.replica = str(rid)
        backlog: list = []
        if backend is None:
            announce_kind = spec.kind
            announce_hash = spec_fingerprint(spec)
            # keepalive during the build: a *replacement* worker (same
            # token, parent already past first-ready) is under the
            # parent's heartbeat-timeout regime, and spec.build() can be a
            # minutes-long jax import + compile with no other traffic
            stop_keepalive = threading.Event()

            def _keepalive():
                while not stop_keepalive.wait(cfg.heartbeat_interval_s):
                    try:
                        chan.send(("hb", 0, 0.0, {}))
                    except ChannelClosed:
                        return

            ka = threading.Thread(target=_keepalive, daemon=True,
                                  name="build-keepalive")
            ka.start()
            try:
                resolved = resolve_spec(
                    spec, store,
                    fetch=lambda d: _fetch_over(chan, d, backlog))
                backend = resolved.build()
            except ChannelClosed:
                # network blip mid-fetch: the contract says a disconnect
                # costs a handshake, not the worker — redial and retry
                stop_keepalive.set()
                chan.close()
                backend = None
                window = max(cfg.heartbeat_timeout_s, 1.0)
                continue
            except BaseException as e:  # noqa: BLE001 - report, don't raise
                try:
                    chan.send(("dead", repr(e)))
                except ChannelClosed:
                    pass
                chan.close()
                return
            finally:
                stop_keepalive.set()
                ka.join(timeout=2.0)
        io = WorkerIO(chan, cfg, rid, registry, heartbeat_thread=True,
                      backlog=backlog)
        io.send_ready()
        try:
            run_replica_loop(backend, cfg, io)
        finally:
            io.stop()
        if io.crashed or not io.disconnected:
            chan.close()
            return                      # crash or clean drain: done
        chan.close()
        # disconnected mid-service: the parent spilled our unacked work;
        # reconnect within its heartbeat window and resume on the same rid
        window = max(cfg.heartbeat_timeout_s, 1.0)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="standalone socket replica worker")
    ap.add_argument("--connect", required=True, metavar="HOST:PORT",
                    help="the parent WorkerListener address")
    ap.add_argument("--token", required=True,
                    help="worker token registered by the parent transport")
    ap.add_argument("--artifacts", default=None,
                    help="local content-addressed artifact cache dir "
                         "(default: a shared tempdir)")
    ap.add_argument("--connect-window", type=float, default=30.0,
                    help="seconds to keep retrying the first connect")
    args = ap.parse_args(argv)
    host, _, port = args.connect.rpartition(":")
    run_worker((host or "127.0.0.1", int(port)), args.token,
               artifacts_dir=args.artifacts,
               connect_window_s=args.connect_window)


if __name__ == "__main__":
    main()
