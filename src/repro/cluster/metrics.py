"""Unified service metrics (paper §6: the evaluation reports rates, latency
and fall-behind — production MLaaS needs the same signals live).

One thread-safe :class:`MetricsRegistry` replaces the ad-hoc ``stats`` dicts
that ``MLaaSService``, ``Engine`` and ``StreamRuntime`` each grew on their
own: counters (monotonic), gauges (last value), and histograms (bounded
reservoir, exact percentiles over the sample).  Every cluster component
(router, replicas, admission controller, autoscaler) reports into the same
registry so a single ``snapshot()`` describes the whole service.
"""
from __future__ import annotations

import bisect
import re
import threading
import time
from contextlib import contextmanager
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

# Shared log-spaced histogram bucket upper bounds (seconds): 1e-4 .. 1e3 at
# four buckets per decade (resolution factor 10^(1/4) ~ 1.78x), plus an
# implicit overflow bucket — the top must clear a worker's first-batch jax
# compile (minutes).  Fixed module-wide so worker-side snapshots and the
# router-side registry always agree on bucket meaning — that is what makes
# cluster-wide percentile *merging* exact up to bucket resolution
# (``merge_snapshots``), instead of the old max-across-workers upper bound.
HIST_BUCKET_BOUNDS: Sequence[float] = tuple(
    float(10.0 ** (e / 4.0)) for e in range(-16, 13))
_N_BUCKETS = len(HIST_BUCKET_BOUNDS) + 1          # + overflow
_BUCKET_KEY_RE = re.compile(r"^(?P<stem>.+)\.le(?P<i>\d+)$")

# ----------------------------------------------------------------------
# Flat snapshots erase metric types — every consumer that needs to treat
# a key as "level" rather than "monotone count" (departed-replica
# retention in Router.cluster_snapshot, TimeSeriesStore windowing) has to
# re-derive them, so the classification lives here, next to the metrics
# themselves.  Histogram-derived keys are recognized structurally; gauges
# by name.  Everything else is a counter.
GAUGE_KEYS = frozenset({
    "engine.kv_blocks_total", "engine.kv_blocks_free",
    "engine.kv_blocks_cached",
    "router.replicas", "router.queue_depth", "router.brownout_level",
    "service.queue_depth", "stream.falling_behind",
    "autoscaler.depth_per_replica",
})
GAUGE_PREFIXES = ("slo.", "timeseries.")
_HIST_DERIVED_SUFFIXES = (".mean", ".p50", ".p95", ".p99")


def is_gauge_key(key: str) -> bool:
    """True for keys that carry a *level* (last-value semantics): named
    gauges and the histogram-derived mean/percentile keys.  Histogram
    ``.count``/``.le<i>`` keys and plain counters are monotone and return
    False."""
    if key in GAUGE_KEYS or key.startswith(GAUGE_PREFIXES):
        return True
    return key.endswith(_HIST_DERIVED_SUFFIXES)


def terminal_snapshot_view(snap: Dict[str, float]) -> Dict[str, float]:
    """What of a departed replica's final snapshot stays in the cluster
    merge: monotone counters, histogram ``.count``/``.le<i>`` buckets and
    ``.mean`` s (the count-weighted mean merge stays correct).  Levels
    drop — a dead replica holds no queue depth or KV blocks, and
    retaining its gauges would inflate cluster capacity forever — and so
    do lifetime percentiles, whose max-merge would otherwise pin the
    cluster tail to a corpse's worst sample."""
    return {k: v for k, v in snap.items()
            if k.endswith(".mean") or not is_gauge_key(k)}


class Counter:
    __slots__ = ("_value", "_lock")

    def __init__(self):
        self._value = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        return self._value


class Gauge:
    __slots__ = ("_value", "_lock")

    def __init__(self):
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def add(self, dv: float) -> None:
        with self._lock:
            self._value += dv

    @property
    def value(self) -> float:
        return self._value


class Histogram:
    """Bounded reservoir of observations with exact percentiles over the
    retained sample (uniform reservoir replacement once full), plus fixed
    log-spaced bucket counts (:data:`HIST_BUCKET_BOUNDS`) so snapshots can
    be *merged* across workers with bucket-resolution percentiles."""

    __slots__ = ("_samples", "_count", "_sum", "_cap", "_rng", "_lock",
                 "_buckets")

    def __init__(self, cap: int = 4096):
        self._samples: List[float] = []
        self._count = 0
        self._sum = 0.0
        self._cap = cap
        self._rng = np.random.RandomState(0)
        self._lock = threading.Lock()
        self._buckets = [0] * _N_BUCKETS

    def observe(self, v: float) -> None:
        with self._lock:
            self._count += 1
            self._sum += v
            self._buckets[bisect.bisect_left(HIST_BUCKET_BOUNDS,
                                             float(v))] += 1
            if len(self._samples) < self._cap:
                self._samples.append(float(v))
            else:                     # reservoir: keep each obs w.p. cap/count
                j = self._rng.randint(self._count)
                if j < self._cap:
                    self._samples[j] = float(v)

    def bucket_counts(self) -> List[int]:
        with self._lock:
            return list(self._buckets)

    # count/sum/mean take the lock: `observe` mutates ``_count`` and
    # ``_sum`` as two separate writes, so lock-free reads could pair a
    # post-observe count with a pre-observe sum (a torn read that shows
    # up as a wrong mean under concurrent load).
    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def mean(self) -> float:
        with self._lock:
            return self._sum / self._count if self._count else 0.0

    def percentile(self, p: float) -> float:
        with self._lock:
            if not self._samples:
                return 0.0
            return float(np.percentile(np.asarray(self._samples), p))

    def stats(self) -> Dict[str, Any]:
        """Every derived figure read under ONE lock acquisition, so a
        snapshot's count/mean/percentiles/buckets describe the same set of
        observations (separate property reads interleave with writers)."""
        with self._lock:
            count, total = self._count, self._sum
            if self._samples:
                pct = np.percentile(np.asarray(self._samples), (50, 95, 99))
                pct = {50: float(pct[0]), 95: float(pct[1]),
                       99: float(pct[2])}
            else:
                pct = {50: 0.0, 95: 0.0, 99: 0.0}
            return {"count": count, "sum": total,
                    "mean": total / count if count else 0.0,
                    "percentiles": pct, "buckets": list(self._buckets)}


class MetricsRegistry:
    """Create-or-get named metrics; ``snapshot()`` flattens everything."""

    def __init__(self, prefix: str = ""):
        self.prefix = prefix
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._hists: Dict[str, Histogram] = {}

    def _key(self, name: str) -> str:
        return f"{self.prefix}{name}" if self.prefix else name

    # get-or-create without eagerly constructing the default:
    # ``setdefault(k, Histogram())`` would build (and discard) a fresh
    # metric on every hot-path lookup — Histogram.__init__ alone seeds a
    # RandomState, ~0.1ms per call inside the serving engine's finish path
    def counter(self, name: str) -> Counter:
        k = self._key(name)
        with self._lock:
            c = self._counters.get(k)
            if c is None:
                c = self._counters[k] = Counter()
            return c

    def gauge(self, name: str) -> Gauge:
        k = self._key(name)
        with self._lock:
            g = self._gauges.get(k)
            if g is None:
                g = self._gauges[k] = Gauge()
            return g

    def histogram(self, name: str, cap: int = 4096) -> Histogram:
        k = self._key(name)
        with self._lock:
            h = self._hists.get(k)
            if h is None:
                h = self._hists[k] = Histogram(cap)
            return h

    @contextmanager
    def timer(self, name: str):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.histogram(name).observe(time.perf_counter() - t0)

    def snapshot(self) -> Dict[str, float]:
        """Flat view: counters/gauges by name, histograms expanded to
        count/mean/p50/p95/p99 plus their non-empty bucket counts
        (``<name>.le<i>`` against :data:`HIST_BUCKET_BOUNDS`), which is
        what lets ``merge_snapshots`` combine percentiles exactly."""
        out: Dict[str, float] = {}
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            hists = dict(self._hists)
        for k, c in counters.items():
            out[k] = c.value
        for k, g in gauges.items():
            out[k] = g.value
        for k, h in hists.items():
            st = h.stats()              # one lock: a consistent view
            out[f"{k}.count"] = st["count"]
            out[f"{k}.mean"] = st["mean"]
            for p in (50, 95, 99):
                out[f"{k}.p{p}"] = st["percentiles"][p]
            for i, n in enumerate(st["buckets"]):
                if n:
                    out[f"{k}.le{i}"] = float(n)
        return out

    def report(self) -> str:
        snap = self.snapshot()
        return "\n".join(f"{k}={snap[k]:.6g}" for k in sorted(snap))


def bucket_percentile(counts: Sequence[float], p: float) -> float:
    """Percentile estimate from :data:`HIST_BUCKET_BOUNDS` bucket counts,
    linearly interpolated within the containing bucket (exact up to the
    10^(1/4)x bucket resolution).  A percentile landing in the overflow
    bucket returns ``inf`` — the buckets cannot bound it, and the caller
    falls back to a conservative estimate rather than under-reporting the
    tail."""
    total = float(sum(counts))
    if total <= 0:
        return 0.0
    target = (p / 100.0) * total
    cum = 0.0
    for i, c in enumerate(counts):
        if c <= 0:
            continue
        if cum + c >= target:
            if i >= len(HIST_BUCKET_BOUNDS):      # overflow bucket
                return float("inf")
            lo = HIST_BUCKET_BOUNDS[i - 1] if i > 0 else 0.0
            hi = HIST_BUCKET_BOUNDS[i]
            return float(lo + (hi - lo) * max(target - cum, 0.0) / c)
        cum += c
    return float("inf")


def merge_snapshots(base: Dict[str, float],
                    worker_snaps: List[Dict[str, float]]) -> Dict[str, float]:
    """Aggregate worker-side snapshots into one cluster view.

    Remote replicas cannot write into the parent's registry, so they ship
    ``snapshot()`` dicts over the heartbeat channel and the parent merges:
    counters/gauges, histogram ``.count`` s and bucket ``.le<i>`` counts
    sum; histogram ``.mean`` s combine count-weighted.

    Percentile merging is decided *per stem*, deterministically, from the
    full contributor set (base + every worker) before anything merges: a
    stem whose every non-empty contributor ships bucket counts gets its
    percentiles recomputed from the summed buckets — a true cluster-wide
    percentile up to bucket resolution — while a stem with even one
    legacy contributor (observations but no ``.le<i>`` keys) keeps the
    conservative max-across-contributors upper bound for ALL of its
    contributors.  Recomputing such a stem from its partial bucket sums
    would ignore the legacy workers' observations entirely and could
    report a percentile *below* data the merge has already seen.
    """
    out = dict(base)
    # classify stems over every contributor first (order-independent):
    # bucketed = ships .le<i> keys; legacy = has observations but no
    # buckets.  An empty histogram (count 0) ships no buckets by design
    # and must not demote its stem to legacy.
    bucketed_stems: set = set()
    legacy_stems: set = set()
    for snap in [base] + list(worker_snaps):
        with_buckets = {m.group("stem") for k in snap
                        if (m := _BUCKET_KEY_RE.match(k))}
        bucketed_stems |= with_buckets
        for k, v in snap.items():
            if k.endswith(".count") and v > 0 and \
                    k[:-len(".count")] not in with_buckets:
                legacy_stems.add(k[:-len(".count")])
    recompute_stems = bucketed_stems - legacy_stems
    for snap in worker_snaps:
        # counts *before* this worker is merged, for mean re-weighting
        pre = {k: out.get(k, 0.0) for k in snap if k.endswith(".count")}
        for k, v in snap.items():
            if k not in out:
                out[k] = v
            elif k.endswith((".p50", ".p95", ".p99")):
                out[k] = max(out[k], v)
            elif k.endswith(".mean"):
                stem = k[:-len(".mean")]
                n_out = pre.get(f"{stem}.count", 0.0)
                n_new = snap.get(f"{stem}.count", 0.0)
                total = n_out + n_new
                out[k] = (out[k] * n_out + v * n_new) / total if total \
                    else 0.0
            else:
                out[k] = out[k] + v
    for stem in recompute_stems:
        counts = [out.get(f"{stem}.le{i}", 0.0) for i in range(_N_BUCKETS)]
        if sum(counts) <= 0:
            continue
        for p in (50, 95, 99):
            est = bucket_percentile(counts, p)
            if est != float("inf"):
                out[f"{stem}.p{p}"] = est
            # overflow: keep the max-merged value already in `out` — an
            # observation beyond the last bound (e.g. a first-batch
            # compile) must not be *under*-reported as the bound itself
    return out


_NULL: Optional[MetricsRegistry] = None


def null_registry() -> MetricsRegistry:
    """Shared sink for components constructed without an explicit registry."""
    global _NULL
    if _NULL is None:
        _NULL = MetricsRegistry()
    return _NULL


# ----------------------------------------------------------------------
# The registry a remote worker ships over its heartbeat channel.  Workers
# rebuild their backend from a BackendSpec, which cannot carry a live
# registry — so the worker entry points publish theirs here before
# ``spec.build()`` and builders adopt it.  Without this, backend-level
# metrics (``engine.*`` counters, the paged-KV ``engine.kv_blocks_*``
# gauges the admission headroom gate reads) would sit in a private
# registry no heartbeat ever sees.
_WORKER_REGISTRY: Optional[MetricsRegistry] = None


def set_worker_registry(registry: Optional[MetricsRegistry]) -> None:
    global _WORKER_REGISTRY
    _WORKER_REGISTRY = registry


def worker_registry() -> Optional[MetricsRegistry]:
    """The heartbeat-shipped registry of this worker process, if any."""
    return _WORKER_REGISTRY
