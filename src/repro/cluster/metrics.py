"""Unified service metrics (paper §6: the evaluation reports rates, latency
and fall-behind — production MLaaS needs the same signals live).

One thread-safe :class:`MetricsRegistry` replaces the ad-hoc ``stats`` dicts
that ``MLaaSService``, ``Engine`` and ``StreamRuntime`` each grew on their
own: counters (monotonic), gauges (last value), and histograms (bounded
reservoir, exact percentiles over the sample).  Every cluster component
(router, replicas, admission controller, autoscaler) reports into the same
registry so a single ``snapshot()`` describes the whole service.
"""
from __future__ import annotations

import threading
import time
from contextlib import contextmanager
from typing import Dict, List, Optional

import numpy as np


class Counter:
    __slots__ = ("_value", "_lock")

    def __init__(self):
        self._value = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        return self._value


class Gauge:
    __slots__ = ("_value", "_lock")

    def __init__(self):
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def add(self, dv: float) -> None:
        with self._lock:
            self._value += dv

    @property
    def value(self) -> float:
        return self._value


class Histogram:
    """Bounded reservoir of observations with exact percentiles over the
    retained sample (uniform reservoir replacement once full)."""

    __slots__ = ("_samples", "_count", "_sum", "_cap", "_rng", "_lock")

    def __init__(self, cap: int = 4096):
        self._samples: List[float] = []
        self._count = 0
        self._sum = 0.0
        self._cap = cap
        self._rng = np.random.RandomState(0)
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        with self._lock:
            self._count += 1
            self._sum += v
            if len(self._samples) < self._cap:
                self._samples.append(float(v))
            else:                     # reservoir: keep each obs w.p. cap/count
                j = self._rng.randint(self._count)
                if j < self._cap:
                    self._samples[j] = float(v)

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    def mean(self) -> float:
        return self._sum / self._count if self._count else 0.0

    def percentile(self, p: float) -> float:
        with self._lock:
            if not self._samples:
                return 0.0
            return float(np.percentile(np.asarray(self._samples), p))


class MetricsRegistry:
    """Create-or-get named metrics; ``snapshot()`` flattens everything."""

    def __init__(self, prefix: str = ""):
        self.prefix = prefix
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._hists: Dict[str, Histogram] = {}

    def _key(self, name: str) -> str:
        return f"{self.prefix}{name}" if self.prefix else name

    def counter(self, name: str) -> Counter:
        with self._lock:
            return self._counters.setdefault(self._key(name), Counter())

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            return self._gauges.setdefault(self._key(name), Gauge())

    def histogram(self, name: str, cap: int = 4096) -> Histogram:
        with self._lock:
            return self._hists.setdefault(self._key(name), Histogram(cap))

    @contextmanager
    def timer(self, name: str):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.histogram(name).observe(time.perf_counter() - t0)

    def snapshot(self) -> Dict[str, float]:
        """Flat view: counters/gauges by name, histograms expanded to
        count/mean/p50/p95/p99."""
        out: Dict[str, float] = {}
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            hists = dict(self._hists)
        for k, c in counters.items():
            out[k] = c.value
        for k, g in gauges.items():
            out[k] = g.value
        for k, h in hists.items():
            out[f"{k}.count"] = h.count
            out[f"{k}.mean"] = h.mean()
            for p in (50, 95, 99):
                out[f"{k}.p{p}"] = h.percentile(p)
        return out

    def report(self) -> str:
        snap = self.snapshot()
        return "\n".join(f"{k}={snap[k]:.6g}" for k in sorted(snap))


def merge_snapshots(base: Dict[str, float],
                    worker_snaps: List[Dict[str, float]]) -> Dict[str, float]:
    """Aggregate worker-side snapshots into one cluster view.

    Process replicas cannot write into the parent's registry, so they ship
    ``snapshot()`` dicts over the heartbeat channel and the parent merges:
    counters/gauges and histogram ``.count`` s sum; histogram ``.mean`` s
    combine count-weighted; percentiles take the max across workers (an
    upper bound — exact cluster-wide percentiles would need the samples).
    """
    out = dict(base)
    for snap in worker_snaps:
        # counts *before* this worker is merged, for mean re-weighting
        pre = {k: out.get(k, 0.0) for k in snap if k.endswith(".count")}
        for k, v in snap.items():
            if k not in out:
                out[k] = v
            elif k.endswith((".p50", ".p95", ".p99")):
                out[k] = max(out[k], v)
            elif k.endswith(".mean"):
                stem = k[:-len(".mean")]
                n_out = pre.get(f"{stem}.count", 0.0)
                n_new = snap.get(f"{stem}.count", 0.0)
                total = n_out + n_new
                out[k] = (out[k] * n_out + v * n_new) / total if total \
                    else 0.0
            else:
                out[k] = out[k] + v
    return out


_NULL: Optional[MetricsRegistry] = None


def null_registry() -> MetricsRegistry:
    """Shared sink for components constructed without an explicit registry."""
    global _NULL
    if _NULL is None:
        _NULL = MetricsRegistry()
    return _NULL
