"""Wire framing shared by every remote transport carrier.

msgpack for the control plane (tags, rids, heartbeat snapshots — known
plain types), pickle for anything carrying *user* payloads or results
(``pickle_only=True``): msgpack would silently round-trip tuples as lists,
making a backend behave differently across a process or host boundary.
One tag byte keeps decode unambiguous.  The same frames travel over a
``multiprocessing`` pipe (process transport) or a length-prefixed TCP
stream (socket transport, see ``cluster/wire.py``).
"""
from __future__ import annotations

import pickle
from typing import Any

try:
    import msgpack
except ImportError:                                   # pragma: no cover - env
    msgpack = None


def encode_frame(obj: Any, pickle_only: bool = False) -> bytes:
    if not pickle_only and msgpack is not None:
        try:
            return b"M" + msgpack.packb(obj, use_bin_type=True)
        except (TypeError, ValueError, OverflowError):
            pass
    return b"P" + pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)


def decode_frame(buf: bytes) -> Any:
    tag, body = buf[:1], buf[1:]
    if tag == b"M":
        if msgpack is None:
            raise RuntimeError("msgpack frame received without msgpack")
        return msgpack.unpackb(body, raw=False)
    if tag == b"P":
        return pickle.loads(body)
    raise ValueError(f"unknown frame tag {tag!r}")
