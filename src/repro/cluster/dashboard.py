"""Zero-dependency stats endpoint + live cluster dashboard.

A stdlib :mod:`http.server` attached to the Router — the repo's first
outward-facing port, deliberately paving the HTTP-front-door roadmap
item.  Four read-only GET routes:

* ``/metrics``          — Prometheus text exposition of the merged
  cluster snapshot (``tracing.prometheus_text``);
* ``/timeseries.json``  — the windowed view of the
  :class:`~repro.cluster.timeseries.TimeSeriesStore` (rates, windowed
  percentiles, EWMAs; bounded payload, no raw rings);
* ``/slo.json``         — burn-rate alert states and error budgets
  (:meth:`~repro.cluster.slo.SLOEngine.status`);
* ``/dash``             — a self-contained HTML page with inline-SVG
  sparklines per stage/kind, server-side rendered on each request (meta
  refresh; no JavaScript frameworks, no external assets).

Trust boundary: the server binds ``127.0.0.1`` by default, serves GET
only, renders JSON/text/HTML it generated itself, and nothing in this
module touches ``pickle`` — exposing it beyond localhost is an explicit
operator decision (``host=``), not a default.

There is also a terminal renderer (:func:`render_watch`) for
``serve.py --watch`` — the same numbers without the browser.
"""
from __future__ import annotations

import html
import json
import math
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .timeseries import TimeSeriesStore
from .tracing import prometheus_text

__all__ = ["StatsServer", "render_dash", "render_watch"]


# ----------------------------------------------------------------------
# Formatting helpers

def _fmt_s(v: Optional[float]) -> str:
    """Human seconds: 12µs / 3.4ms / 1.2s."""
    if v is None or not math.isfinite(v):
        return "–"
    if v <= 0:
        return "0"
    if v < 1e-3:
        return f"{v * 1e6:.0f}µs"
    if v < 1.0:
        return f"{v * 1e3:.1f}ms"
    return f"{v:.2f}s"


def _fmt_rate(v: Optional[float]) -> str:
    if v is None or not math.isfinite(v):
        return "–"
    if v >= 100:
        return f"{v:.0f}/s"
    return f"{v:.1f}/s"


# ----------------------------------------------------------------------
# Inline-SVG sparkline (server-side rendered, no scripts)

def _spark_svg(points: Sequence[Tuple[float, float]],
               width: int = 220, height: int = 48,
               color: str = "var(--series-1)",
               fmt=lambda v: f"{v:.3g}",
               title: str = "") -> str:
    """One sparkline: 2px round-capped line over a 10%-opacity area wash,
    an end-dot (r=4) with a 2px surface ring, and native ``<title>``
    hover targets per point.  Values render in ink tokens beside the
    mark, never in the series color."""
    pts = [(t, v) for t, v in points if math.isfinite(v)]
    if len(pts) < 2:
        return (f'<svg class="spark" width="{width}" height="{height}" '
                f'role="img"><text x="4" y="{height - 6}" '
                f'class="muted">no data yet</text></svg>')
    t0, t1 = pts[0][0], pts[-1][0]
    vmax = max(v for _, v in pts)
    vmin = min(0.0, min(v for _, v in pts))
    span_t = (t1 - t0) or 1.0
    span_v = (vmax - vmin) or 1.0
    pad_top, pad_bot = 6, 6
    usable = height - pad_top - pad_bot

    def xy(t: float, v: float) -> Tuple[float, float]:
        x = (t - t0) / span_t * (width - 12) + 2
        y = height - pad_bot - (v - vmin) / span_v * usable
        return round(x, 1), round(y, 1)

    coords = [xy(t, v) for t, v in pts]
    line = " ".join(f"{x},{y}" for x, y in coords)
    base_y = height - pad_bot
    area = (f"2,{base_y} " + line + f" {coords[-1][0]},{base_y}")
    ex, ey = coords[-1]
    hovers = "".join(
        f'<circle cx="{x}" cy="{y}" r="7" fill="transparent">'
        f"<title>{html.escape(fmt(v))}</title></circle>"
        for (x, y), (_, v) in zip(coords, pts))
    label = html.escape(title) or "sparkline"
    return (
        f'<svg class="spark" width="{width}" height="{height}" role="img" '
        f'aria-label="{label}">'
        f'<line x1="2" y1="{base_y}" x2="{width - 2}" y2="{base_y}" '
        f'class="axis"/>'
        f'<polygon points="{area}" fill="{color}" fill-opacity="0.1"/>'
        f'<polyline points="{line}" fill="none" stroke="{color}" '
        f'stroke-width="2" stroke-linecap="round" '
        f'stroke-linejoin="round"/>'
        f'<circle cx="{ex}" cy="{ey}" r="6" fill="var(--surface-1)"/>'
        f'<circle cx="{ex}" cy="{ey}" r="4" fill="{color}"/>'
        f"{hovers}</svg>")


_STYLE = """
:root { color-scheme: light dark; }
body {
  margin: 0; padding: 16px 20px;
  font-family: system-ui, -apple-system, "Segoe UI", sans-serif;
  background: var(--page); color: var(--text-primary);
  --surface-1: #fcfcfb; --page: #f9f9f7;
  --text-primary: #0b0b0b; --text-secondary: #52514e;
  --muted: #898781; --grid: #e1e0d9; --baseline: #c3c2b7;
  --border: rgba(11,11,11,0.10);
  --series-1: #2a78d6; --series-2: #eb6834;
  --status-good: #0ca30c; --status-critical: #d03b3b;
}
@media (prefers-color-scheme: dark) {
  body {
    --surface-1: #1a1a19; --page: #0d0d0d;
    --text-primary: #ffffff; --text-secondary: #c3c2b7;
    --muted: #898781; --grid: #2c2c2a; --baseline: #383835;
    --border: rgba(255,255,255,0.10);
    --series-1: #3987e5; --series-2: #d95926;
  }
}
h1 { font-size: 18px; font-weight: 600; margin: 0 0 2px; }
.sub { color: var(--text-secondary); font-size: 12px; margin: 0 0 16px; }
.muted { fill: var(--muted); color: var(--muted); font-size: 11px; }
.axis { stroke: var(--baseline); stroke-width: 1; }
.tiles { display: flex; flex-wrap: wrap; gap: 12px; margin-bottom: 16px; }
.tile {
  background: var(--surface-1); border: 1px solid var(--border);
  border-radius: 8px; padding: 10px 14px; min-width: 130px;
}
.tile .label { font-size: 12px; color: var(--text-secondary); }
.tile .value { font-size: 26px; font-weight: 600; margin-top: 2px; }
.tile .hint { font-size: 11px; color: var(--muted); margin-top: 2px; }
.grid { display: grid; gap: 12px;
        grid-template-columns: repeat(auto-fill, minmax(250px, 1fr)); }
.card {
  background: var(--surface-1); border: 1px solid var(--border);
  border-radius: 8px; padding: 10px 12px;
}
.card .name { font-size: 12px; color: var(--text-secondary);
              margin-bottom: 2px; overflow-wrap: anywhere; }
.card .now { font-size: 16px; font-weight: 600; }
.card .now small { font-weight: 400; color: var(--muted); font-size: 11px; }
.slo-row { display: flex; gap: 8px; align-items: baseline;
           font-size: 13px; padding: 3px 0; }
.slo-state { font-weight: 600; font-size: 12px; }
.slo-state.firing { color: var(--status-critical); }
.slo-state.ok { color: var(--status-good); }
section h2 { font-size: 13px; font-weight: 600; margin: 18px 0 8px;
             color: var(--text-secondary);
             text-transform: uppercase; letter-spacing: 0.04em; }
table.tbl { border-collapse: collapse; font-size: 12px;
            background: var(--surface-1); border: 1px solid var(--border);
            border-radius: 8px; }
table.tbl th, table.tbl td {
  text-align: right; padding: 4px 10px;
  border-bottom: 1px solid var(--grid);
  font-variant-numeric: tabular-nums;
}
table.tbl th { color: var(--text-secondary); font-weight: 600; }
table.tbl td:first-child, table.tbl th:first-child { text-align: left; }
.legend { display: flex; gap: 14px; font-size: 11px;
          color: var(--text-secondary); margin: 4px 0 2px; }
.key { display: inline-block; width: 14px; height: 2px;
       vertical-align: middle; margin-right: 4px; }
"""


def render_dash(store: TimeSeriesStore,
                slo_status: Optional[Dict[str, Any]] = None,
                snapshot: Optional[Dict[str, float]] = None,
                window_s: float = 10.0,
                refresh_s: int = 2,
                max_cards: int = 24) -> str:
    """The ``/dash`` page: stat tiles, SLO alert states, and a card grid
    of sparklines (windowed p99 per latency stem — stage/kind cards from
    the span-tree attribution — plus counter rates), with a plain table
    carrying every number the sparklines summarize."""
    snap = snapshot or {}
    now = None
    tiles: List[str] = []

    def tile(label: str, value: str, hint: str = "") -> None:
        tiles.append(
            f'<div class="tile"><div class="label">{html.escape(label)}'
            f'</div><div class="value">{html.escape(value)}</div>'
            + (f'<div class="hint">{html.escape(hint)}</div>' if hint
               else "") + "</div>")

    arrival = store.last("timeseries.arrival_rate_hz")
    service = store.last("timeseries.service_rate_hz")
    tile("Arrival rate", _fmt_rate(arrival), "EWMA of submits")
    tile("Service rate", _fmt_rate(service), "EWMA of completions")
    replicas = store.last("router.replicas") or snap.get("router.replicas")
    tile("Replicas", f"{replicas:.0f}" if replicas is not None else "–")
    p99 = store.window_percentile("router.latency_s", 99, window_s)
    tile(f"p99 latency ({window_s:g}s)", _fmt_s(p99) if p99 else "–",
         "windowed, bucket-exact")
    depth = store.last("router.queue_depth")
    tile("Queue depth", f"{depth:.0f}" if depth is not None else "–")

    # SLO alert rows: state is icon+label text in status colors, never
    # color alone
    slo_html = ""
    if slo_status and slo_status.get("objectives"):
        rows = []
        for obj in slo_status["objectives"]:
            for sub, alert in sorted(obj.get("alerts", {}).items()):
                state = alert["state"]
                burns = alert["burns"][0] if alert["burns"] else {}
                rows.append(
                    '<div class="slo-row">'
                    f'<span class="slo-state {state}">'
                    f'{"▲ FIRING" if state == "firing" else "● ok"}</span>'
                    f'<span>{html.escape(obj["kind"])} · {sub}</span>'
                    f'<span class="muted">burn fast '
                    f'{burns.get("fast", 0.0):.2f} / slow '
                    f'{burns.get("slow", 0.0):.2f} (thr '
                    f'{burns.get("threshold", 0.0):g}) · budget left '
                    f'{alert.get("budget_remaining", 1.0) * 100.0:.0f}%'
                    "</span></div>")
        slo_html = ("<section><h2>SLO burn-rate alerts</h2>"
                    + "".join(rows) + "</section>")

    # sparkline cards: histogram stems (windowed p99), router.latency_s
    # and stage.* first, then counters by rate
    cards: List[str] = []
    table_rows: List[str] = []
    stems = store.histogram_stems()
    order = ([s for s in stems if s == "router.latency_s"]
             + sorted(s for s in stems if s.startswith("stage."))
             + sorted(s for s in stems
                      if s != "router.latency_s"
                      and not s.startswith("stage.")))
    for stem in order[:max_cards]:
        series = store.percentile_series(stem, 99, window_s,
                                         max_points=48)
        cur = store.window_percentile(stem, 99, window_s)
        n = store.window_count(stem, window_s)
        cards.append(
            f'<div class="card"><div class="name">{html.escape(stem)}'
            f' · p99</div><div class="now">{_fmt_s(cur)}'
            f' <small>{n:.0f} obs/{window_s:g}s</small></div>'
            + _spark_svg(series, fmt=_fmt_s, title=f"{stem} p99")
            + "</div>")
        table_rows.append(
            f"<tr><td>{html.escape(stem)}</td>"
            f"<td>{_fmt_s(store.window_percentile(stem, 50, window_s))}"
            f"</td><td>{_fmt_s(cur)}</td><td>{n:.0f}</td>"
            f"<td>{_fmt_s(store.last(stem + '.p99'))}</td></tr>")

    counter_cards: List[str] = []
    for key in ("router.submitted", "router.finish.total",
                "router.finish.deadline", "engine.tokens"):
        if store.last(key) is None:
            continue
        series = store.rate_series(key, window_s, max_points=48)
        counter_cards.append(
            f'<div class="card"><div class="name">{html.escape(key)}'
            f' · rate</div>'
            f'<div class="now">{_fmt_rate(store.rate(key, window_s))}'
            "</div>"
            + _spark_svg(series, fmt=_fmt_rate, title=f"{key} rate")
            + "</div>")

    rate_legend = (
        '<div class="legend">'
        '<span><span class="key" style="background:var(--series-1)">'
        "</span>arrival</span>"
        '<span><span class="key" style="background:var(--series-2)">'
        "</span>service</span></div>")
    arr_series = store.points("timeseries.arrival_rate_hz")[-48:]
    svc_series = store.points("timeseries.service_rate_hz")[-48:]
    rates_card = (
        '<div class="card"><div class="name">arrival vs service rate'
        "</div>" + rate_legend
        + _spark_svg(arr_series, fmt=_fmt_rate, title="arrival rate")
        + _spark_svg(svc_series, color="var(--series-2)", fmt=_fmt_rate,
                     title="service rate")
        + "</div>")

    mem = (f"{store.n_points}/{store.max_points} points · "
           f"{len(store.keys())}/{store.max_stems} keys · "
           f"{store.dropped_keys} dropped")
    return f"""<!DOCTYPE html>
<html lang="en"><head><meta charset="utf-8">
<meta http-equiv="refresh" content="{refresh_s}">
<title>cluster dashboard</title><style>{_STYLE}</style></head>
<body>
<h1>cluster dashboard</h1>
<p class="sub">windowed over trailing {window_s:g}s · refreshes every
{refresh_s}s · store {html.escape(mem)}</p>
<div class="tiles">{''.join(tiles)}</div>
{slo_html}
<section><h2>latency p99 by stage / kind</h2>
<div class="grid">{''.join(cards)}</div></section>
<section><h2>throughput</h2>
<div class="grid">{rates_card}{''.join(counter_cards)}</div></section>
<section><h2>table view</h2>
<table class="tbl"><tr><th>stem</th><th>p50 ({window_s:g}s)</th>
<th>p99 ({window_s:g}s)</th><th>obs</th><th>lifetime p99</th></tr>
{''.join(table_rows)}</table></section>
</body></html>
"""


def render_watch(store: TimeSeriesStore,
                 slo_status: Optional[Dict[str, Any]] = None,
                 window_s: float = 10.0, width: int = 78) -> str:
    """Terminal one-screen rendering of the same numbers (serve.py
    ``--watch``): rates, windowed percentiles, SLO alert states."""
    bar = "─" * width
    lines = [bar]
    arrival = store.last("timeseries.arrival_rate_hz") or 0.0
    service = store.last("timeseries.service_rate_hz") or 0.0
    replicas = store.last("router.replicas") or 0.0
    depth = store.last("router.queue_depth") or 0.0
    lines.append(f" arrival {_fmt_rate(arrival):>9}   service "
                 f"{_fmt_rate(service):>9}   replicas {replicas:>3.0f}   "
                 f"queue {depth:>5.0f}")
    if slo_status:
        for obj in slo_status.get("objectives", []):
            for sub, alert in sorted(obj.get("alerts", {}).items()):
                burns = alert["burns"][0] if alert["burns"] else {}
                state = ("FIRING" if alert["state"] == "firing"
                         else "ok    ")
                lines.append(
                    f" slo {obj['kind']}/{sub:<12} {state} "
                    f"burn {burns.get('fast', 0.0):6.2f}/"
                    f"{burns.get('slow', 0.0):6.2f} "
                    f"budget {alert.get('budget_remaining', 1.0) * 100:5.0f}%")
    lines.append(bar)
    lines.append(f" {'stem':<38}{'p50':>9}{'p99':>9}{'obs':>7}{'rate':>10}")
    for stem in store.histogram_stems()[:20]:
        n = store.window_count(stem, window_s)
        lines.append(
            f" {stem[:38]:<38}"
            f"{_fmt_s(store.window_percentile(stem, 50, window_s)):>9}"
            f"{_fmt_s(store.window_percentile(stem, 99, window_s)):>9}"
            f"{n:>7.0f}{_fmt_rate(n / window_s):>10}")
    lines.append(bar)
    return "\n".join(lines)


# ----------------------------------------------------------------------
# The HTTP server

class StatsServer:
    """Serve the stats routes from a daemon thread.

    ``port=0`` binds an ephemeral port (read it back from ``.port``);
    ``host`` defaults to loopback — never expose this beyond localhost
    without meaning to.
    """

    def __init__(self, snapshot_fn, store: TimeSeriesStore,
                 slo: Optional[Any] = None,
                 host: str = "127.0.0.1", port: int = 0,
                 window_s: float = 10.0):
        self.snapshot_fn = snapshot_fn
        self.store = store
        self.slo = slo
        self.window_s = window_s
        outer = self

        class Handler(BaseHTTPRequestHandler):
            def log_message(self, *a):        # quiet: telemetry, not access
                pass                          # logs

            def do_GET(self):                 # noqa: N802 (stdlib name)
                try:
                    body, ctype = outer._route(self.path)
                except Exception as e:        # noqa: BLE001
                    self.send_error(500, str(e))
                    return
                if body is None:
                    self.send_error(404, "unknown route")
                    return
                data = body.encode("utf-8")
                self.send_response(200)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(data)))
                self.send_header("Cache-Control", "no-store")
                self.end_headers()
                self.wfile.write(data)

        self._httpd = ThreadingHTTPServer((host, port), Handler)
        self._httpd.daemon_threads = True
        self.host, self.port = self._httpd.server_address[:2]
        self._thread: Optional[threading.Thread] = None

    # route -> (body, content-type); None = 404
    def _route(self, path: str):
        path = path.split("?", 1)[0]
        if path == "/metrics":
            return prometheus_text(self.snapshot_fn()), \
                "text/plain; version=0.0.4; charset=utf-8"
        if path == "/timeseries.json":
            return json.dumps(self.store.to_json(
                windows=(self.window_s, 6 * self.window_s))), \
                "application/json"
        if path == "/slo.json":
            status = self.slo.status() if self.slo is not None else {
                "objectives": [], "ticks": 0, "pressure": 0.0}
            return json.dumps(status), "application/json"
        if path in ("/", "/dash"):
            status = self.slo.status() if self.slo is not None else None
            return render_dash(self.store, slo_status=status,
                               snapshot=None,
                               window_s=self.window_s), \
                "text/html; charset=utf-8"
        return None, ""

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self) -> "StatsServer":
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._httpd.serve_forever, name="stats-server",
                daemon=True)
            self._thread.start()
        return self

    def stop(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None
