"""Serializable backend specifications.

A thread replica can own any in-process object, but a *worker process* must
be able to rebuild its backend from scratch after ``spawn`` — so the unit
of deployment is a :class:`BackendSpec`: a dotted path to a module-level
builder plus picklable kwargs (config values and a weights *path*, never a
closure or a live array).  ``spec.build()`` runs on whichever side of the
process boundary the transport puts it.

Builders for the repo's three backend families live here; anything
module-level and importable works (tests add their own).  Heavy imports
(jax, models) happen inside the builders so that spawning a worker for a
pure-Python backend never pays the jax import.
"""
from __future__ import annotations

import dataclasses
import importlib
import threading
import time
from typing import Any, Dict, Optional

# Backend kinds — the admission controller's per-backend cost-model keys.
KIND_FN = "fn"        # arbitrary step functions (cost unit: requests)
KIND_LM = "lm"        # LM engine (cost unit: tokens)
KIND_SVM = "svm"      # SVM stream runtime (cost unit: rows)


@dataclasses.dataclass(frozen=True)
class BackendSpec:
    """``target`` is ``"module.path:callable"``; ``kwargs`` must pickle.

    ``kind`` tags the backend family for per-backend admission cost models
    and metrics; it defaults to :data:`KIND_FN`.
    """
    target: str
    kwargs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    kind: str = KIND_FN

    def build(self):
        mod_name, sep, fn_name = self.target.partition(":")
        if not sep:
            raise ValueError(f"BackendSpec target {self.target!r} must be "
                             f"'module.path:callable'")
        fn = getattr(importlib.import_module(mod_name), fn_name)
        return fn(**dict(self.kwargs))


# ----------------------------------------------------------------------
# Builders (module-level: importable from a spawned worker process).

def build_echo(delay_s: float = 0.0, scale: int = 2, stall_s: float = 0.0,
               poison: Optional[int] = None):
    """Deterministic test/bench backend: ``payload * scale`` after an
    optional per-batch stall (models host-side work).

    ``stall_s`` > 0 turns the replica into a *slow loris*: every batch
    hangs for that long (effectively forever for chaos tests) while the
    worker's liveness signals — process aliveness, the socket heartbeat
    thread — stay green.  Detection is the transports' ack timeout.

    ``poison`` marks one payload value as a replica-killer: any batch
    containing it raises, which spills the batch and ends the replica
    loop on every transport (thread replicas die in place; worker
    processes exit and the parent spills).  This models the
    poison-request pathology — a request that crashes whatever serves it
    — whose blast radius the router's retry budget must bound."""
    from repro.cluster.replica import FnBackend

    def step(payloads):
        if poison is not None and any(p == poison for p in payloads):
            raise RuntimeError(f"poison payload {poison!r} in batch")
        if stall_s:
            time.sleep(stall_s)
        if delay_s:
            time.sleep(delay_s)
        return [p * scale for p in payloads]

    return FnBackend(step)


def build_stream(feat_dim: int = 256, claim_capacity: int = 64,
                 evid_capacity: int = 128, period: float = 1.0,
                 capacity: int = 256, scope: str = "window",
                 window: float = 10.0, ring_capacity: int = 512,
                 ingest_ms: float = 0.0, model_seed: int = 7):
    """One SVM stream runtime, rebuilt from config alone.  The MARGOT SVM
    models are derived deterministically from ``model_seed`` (the repo has
    no trained-weights artifact for them), so every worker process converges
    on identical models without shipping arrays."""
    from repro.cluster.replica import StreamBackend
    from repro.core.pipeline import PipelineConfig
    from repro.core.stream import StreamConfig, StreamRuntime
    from repro.data.text import margot_models

    pcfg = PipelineConfig(feat_dim=feat_dim, claim_capacity=claim_capacity,
                          evid_capacity=evid_capacity)
    scfg = StreamConfig(period=period, capacity=capacity, scope=scope,
                        window=window, ring_capacity=ring_capacity)
    models, _ = margot_models(pcfg, link_seed=model_seed)
    runtime = StreamRuntime(models, pcfg, scfg)
    fetch = None
    if ingest_ms > 0:
        fetch = lambda p: (time.sleep(ingest_ms * 1e-3), p)[1]  # noqa: E731
    return StreamBackend(runtime, fetch=fetch)


# One compiled fn bundle per distinct (cfg, scfg) per process: thread pools
# share XLA compiles across replicas, and a worker process that rebuilds its
# backend after a reconnect reuses its first compile instead of re-jitting.
_ENGINE_FNS_CACHE: Dict[Any, Any] = {}
_ENGINE_FNS_LOCK = threading.Lock()


def shared_engine_fns(cfg, scfg):
    """Process-local shared-jit cache keyed by the full static config."""
    import dataclasses as _dc

    key = (cfg, tuple(sorted(_dc.asdict(scfg).items())))
    with _ENGINE_FNS_LOCK:
        if key not in _ENGINE_FNS_CACHE:
            from repro.serving import make_engine_fns
            _ENGINE_FNS_CACHE[key] = make_engine_fns(cfg, scfg)
        return _ENGINE_FNS_CACHE[key]


def build_engine(arch: str = "internlm2-1.8b", max_len: int = 64,
                 slots: int = 2, reduce: bool = True, seed: int = 0,
                 weights_path: Optional[str] = None,
                 ingest_ms: float = 0.0, fused: bool = True,
                 sync_every: int = 8, temperature: float = 0.0,
                 prefill_bucketing: bool = True, paged: bool = False,
                 block_size: int = 16, kv_blocks: int = 0,
                 prefix_cache: bool = True, speculative: bool = False,
                 spec_draft: int = 3, kv_swap: bool = False,
                 swap_tier: str = "host"):
    """One continuous-batching LM engine.  Weights come from
    ``weights_path`` (a ``checkpoint.Checkpointer`` directory) when given,
    else from deterministic init at ``seed`` — either way the worker holds
    its own copy in its own JAX runtime, which is the whole point of the
    process transport.  ``fused``/``sync_every``/``temperature``/
    ``prefill_bucketing`` select the engine hot path (all plain scalars, so
    the spec still pickles across process/socket workers); jitted fns are
    shared per-process via :func:`shared_engine_fns`."""
    import jax

    from repro.cluster.replica import EngineBackend
    from repro.configs import get_config
    from repro.configs.base import reduced
    from repro.models import api
    from repro.serving import Engine, ServeConfig

    cfg = get_config(arch)
    if reduce:
        cfg = reduced(cfg)
    params, _ = api.init(jax.random.PRNGKey(seed), cfg)
    if weights_path is not None:
        from repro.checkpoint import Checkpointer
        params = Checkpointer(weights_path).restore(params)
    scfg = ServeConfig(max_len=max_len, slots=slots, fused=fused,
                       sync_every=sync_every, temperature=temperature,
                       prefill_bucketing=prefill_bucketing, paged=paged,
                       block_size=block_size, kv_blocks=kv_blocks,
                       prefix_cache=prefix_cache, speculative=speculative,
                       spec_draft=spec_draft, kv_swap=kv_swap,
                       swap_tier=swap_tier)
    # inside a remote worker, report into the registry its heartbeats
    # ship — that is how engine.* counters and the paged engine's
    # kv_blocks_* gauges reach the router's admission headroom gate
    from repro.cluster.metrics import worker_registry
    engine = Engine(params, cfg, scfg, metrics=worker_registry(),
                    shared_fns=shared_engine_fns(cfg, scfg))
    if ingest_ms > 0:
        class _IngestEngineBackend(EngineBackend):
            def process(self, payloads):
                time.sleep(ingest_ms * 1e-3 * len(payloads))
                return super().process(payloads)
        return _IngestEngineBackend(engine)
    return EngineBackend(engine)


# ----------------------------------------------------------------------
# Spec helpers: the canonical way callers name a backend family.

def echo_spec(**kwargs) -> BackendSpec:
    return BackendSpec("repro.cluster.backends:build_echo", kwargs, KIND_FN)


def stream_spec(**kwargs) -> BackendSpec:
    return BackendSpec("repro.cluster.backends:build_stream", kwargs, KIND_SVM)


def engine_spec(**kwargs) -> BackendSpec:
    return BackendSpec("repro.cluster.backends:build_engine", kwargs, KIND_LM)
