"""Admission control and backpressure for the serving cluster.

The paper's service must stay responsive for "evergrowing user bases"; when
offered load exceeds capacity the failure mode must be an *explicit, cheap
rejection* at the front door — not silent deadline misses deep in the queue
(the pathology the stream runtime calls "falling behind").

Two shedding rules, both O(1) per request:

  * queue-full   — a bounded global queue (count or cost units); requests
                   beyond it are shed immediately.
  * deadline     — the ``CostModel`` slack test that used to live inline in
                   ``MLaaSService._loop``: if the fitted service-time estimate
                   for the work already queued ahead says the deadline cannot
                   be met, reject now instead of missing later.

Rejected requests complete with an explicit :class:`Rejected` result so
callers can distinguish "shed by policy" from "failed".
"""
from __future__ import annotations

import dataclasses
import time
from typing import Mapping, Optional

from repro.core.partitioner import CostModel
from repro.cluster.metrics import MetricsRegistry, null_registry


@dataclasses.dataclass(frozen=True)
class Rejected:
    """Explicit overload result: the request was shed, not processed."""
    reason: str                       # "queue_full" | "deadline" | "shutdown"
    detail: str = ""


def deadline_slack(deadline_s: float, now: float, est_service_s: float) -> float:
    """Slack = time to deadline minus the estimated service time.

    This is the batching/shedding criterion shared by the service front
    (flush when the oldest request's slack runs out) and the admission
    controller (reject when slack is negative on arrival).
    """
    return deadline_s - now - est_service_s


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    max_queue_cost: int = 1024        # bound on queued cost units (≈ requests)
    cost_model: Optional[CostModel] = None
    min_slack_s: float = 0.0          # extra safety margin on the deadline test
    # Per-backend cost models, keyed by backend kind ("lm", "svm", ...):
    # an LM token and an SVM row cost very different service time, so one
    # global model either over-sheds the cheap backend or under-sheds the
    # expensive one.  Falls back to ``cost_model`` for unknown kinds.
    cost_models: Optional[Mapping[str, CostModel]] = None
    # KV-pool headroom gate (paged LM engines): shed when the cluster's
    # free-block fraction (engine.kv_blocks_free / engine.kv_blocks_total,
    # shipped through replica heartbeats) drops below this.  Queue depth
    # alone cannot see memory pressure — a paged replica with short queues
    # can still be out of blocks for *long* sequences, and admitting into
    # a starved pool turns into in-engine deferral (or mid-decode pool
    # exhaustion) instead of a cheap front-door rejection.  0 disables.
    min_kv_headroom_frac: float = 0.0


class AdmissionController:
    """Front-door policy: decide admit/shed from global queue state."""

    def __init__(self, cfg: AdmissionConfig = AdmissionConfig(),
                 metrics: Optional[MetricsRegistry] = None):
        self.cfg = cfg
        self.metrics = metrics if metrics is not None else null_registry()
        self._admitted = self.metrics.counter("admission.admitted")
        self._shed_full = self.metrics.counter("admission.shed_queue_full")
        self._shed_deadline = self.metrics.counter("admission.shed_deadline")
        self._shed_kv = self.metrics.counter("admission.shed_kv_pressure")

    def _model_for(self, kind: Optional[str]) -> Optional[CostModel]:
        if kind is not None and self.cfg.cost_models:
            cm = self.cfg.cost_models.get(kind)
            if cm is not None:
                return cm
        return self.cfg.cost_model

    def _estimate(self, queued_cost: int, kind: Optional[str] = None) -> float:
        cm = self._model_for(kind)
        return cm.time(max(queued_cost, 1)) if cm else 0.0

    def decide(self, queued_cost: int, cost: int, deadline_s: float,
               now: Optional[float] = None,
               kind: Optional[str] = None,
               kv_free_frac: Optional[float] = None,
               scale: float = 1.0) -> Optional[Rejected]:
        """Returns None to admit, or a :class:`Rejected` describing the shed.

        ``queued_cost`` is the outstanding cost ahead of this request (the
        router passes the per-kind queue depth when ``kind`` is given, else
        cluster-wide); ``cost`` the new request's own cost units; ``kind``
        selects a per-backend cost model for the deadline test;
        ``kv_free_frac`` is the backend pool's free-KV-block fraction when
        known (paged LM engines export it via ``engine.kv_blocks_*``);
        ``scale`` tightens the queue bound under brownout (the router
        passes the overload controller's admission scale — level 3 halves
        the effective front-door budget so load sheds cheaply here instead
        of expiring deep in replica queues).
        """
        bound = self.cfg.max_queue_cost * scale
        if queued_cost + cost > bound:
            self._shed_full.inc()
            return Rejected("queue_full",
                            f"queued={queued_cost} + {cost} > "
                            f"{bound:g}"
                            + (f" (brownout scale {scale:g})"
                               if scale != 1.0 else ""))
        if self.cfg.min_kv_headroom_frac > 0 and kv_free_frac is not None \
                and kv_free_frac < self.cfg.min_kv_headroom_frac:
            self._shed_kv.inc()
            return Rejected("kv_pressure",
                            f"free kv blocks {kv_free_frac:.3f} < "
                            f"{self.cfg.min_kv_headroom_frac} headroom "
                            f"(kind={kind or 'global'})")
        now = time.monotonic() if now is None else now
        est = self._estimate(queued_cost + cost, kind)
        slack = deadline_slack(deadline_s, now, est)
        if slack < self.cfg.min_slack_s:
            self._shed_deadline.inc()
            return Rejected("deadline",
                            f"slack={slack:.4f}s < {self.cfg.min_slack_s}s "
                            f"(est={est:.4f}s, kind={kind or 'global'})")
        self._admitted.inc()
        return None
