"""Cross-host request tracing, flight recorder, and trace exporters.

The paper's evaluation (§6) reasons from end-to-end timings; a request in
this repo now crosses five stages (admission -> router -> transport ->
replica loop -> engine prefill / K-step decode), so "where did this
request spend its time" needs per-stage spans, not one wall-clock delta.

Three pieces, all cheap enough to leave compiled in:

  * :class:`Tracer` — thread-safe span factory writing finished spans
    (plain dicts) into a bounded per-process ring buffer.  Disabled
    tracers return a shared no-op span (one branch per call site);
    enabled tracers sample *per root* (``sample_rate``), and every child
    inherits the root's decision through its :class:`TraceContext`, so a
    request is traced everywhere or nowhere.
  * :class:`TraceContext` — the four scalars that cross the process /
    socket boundary (trace id, parent span id, sampled flag, attempt
    number).  It rides as an optional trailing element on ``("req", ...)``
    frames; worker-side spans ship back on the existing heartbeat channel
    exactly like metrics snapshots, and the parent's
    :meth:`Tracer.ingest` re-homes them so one buffer holds the complete
    cross-host timeline.  The at-least-once machinery bumps ``attempt``
    on every respill, so spans from a dead attempt stay distinguishable
    from the retry's instead of silently merging.
  * :class:`FlightRecorder` — an always-on ring buffer of the last N
    structured events (admits, dispatches, spills, COW copies, KV
    evictions, reconnects, partitions).  Remote workers ship increments
    over heartbeats; on replica death / ack timeout the transport dumps
    the merged event log to the artifact store (``transport.py``) so a
    chaos postmortem starts from evidence, not print statements.

Exporters: :func:`to_chrome_trace` (Chrome trace-event JSON, loadable in
Perfetto / ``chrome://tracing``, one track per replica and per stage) and
:func:`prometheus_text` (text exposition of a merged registry snapshot).
Opt-in ``jax.profiler`` hooks (:func:`start_profiling` /
:func:`annotate`) put device time in the same timeline.

Leaf module: imports nothing from the cluster package except
``metrics`` (itself a leaf), so every layer — wire, transport, replica,
router, engine — may import it freely.

Clock note: span times are ``time.monotonic()`` with a wall-clock anchor
recorded per span.  CLOCK_MONOTONIC is shared by every process on one
Linux host, so same-host spans (thread / process / loopback-socket
replicas) land on one comparable axis; truly remote hosts are aligned
only as well as their wall clocks (the ``wall`` anchor) — good enough
for ms-scale serving stages, and explicitly not NTP-grade.
"""
from __future__ import annotations

import itertools
import json
import os
import random
import re
import threading
import time
from collections import deque
from contextlib import nullcontext
from typing import Any, Dict, List, Optional, Sequence

from repro.cluster.metrics import HIST_BUCKET_BOUNDS

_N_BUCKETS = len(HIST_BUCKET_BOUNDS) + 1


def _scalar(v: Any) -> Any:
    """Coerce a tag value to something msgpack/json-safe."""
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    if isinstance(v, (list, tuple)):
        return [_scalar(x) for x in v]
    item = getattr(v, "item", None)         # numpy scalars
    if callable(item):
        try:
            return item()
        except Exception:                   # noqa: BLE001 - best-effort tag
            pass
    return str(v)


class TraceContext:
    """What propagates across the process/socket boundary: enough to
    parent a remote span and to honor the root's sampling decision."""

    __slots__ = ("trace_id", "span_id", "sampled", "attempt")

    def __init__(self, trace_id: str, span_id: str, sampled: bool = True,
                 attempt: int = 0):
        self.trace_id = trace_id
        self.span_id = span_id
        self.sampled = sampled
        self.attempt = attempt

    def to_wire(self) -> list:
        return [self.trace_id, self.span_id,
                1 if self.sampled else 0, self.attempt]

    @staticmethod
    def from_wire(w) -> Optional["TraceContext"]:
        if not w:
            return None
        try:
            return TraceContext(str(w[0]), str(w[1]), bool(w[2]), int(w[3]))
        except (IndexError, TypeError, ValueError):
            return None                     # malformed ctx: drop, don't die

    def __repr__(self):
        return (f"TraceContext({self.trace_id!r}, {self.span_id!r}, "
                f"sampled={self.sampled}, attempt={self.attempt})")


class Span:
    """One in-progress span.  ``end()`` (or ``with``-exit) freezes it into
    a plain dict in the tracer's buffer; after that it is inert."""

    __slots__ = ("_tracer", "trace_id", "span_id", "parent_id", "name",
                 "tags", "_t0", "_done")

    def __init__(self, tracer: "Tracer", trace_id: str, span_id: str,
                 parent_id: Optional[str], name: str):
        self._tracer = tracer
        self.trace_id = trace_id
        self.span_id = span_id
        self.parent_id = parent_id
        self.name = name
        self.tags: Dict[str, Any] = {}
        self._t0 = time.monotonic()
        self._done = False

    @property
    def recording(self) -> bool:
        return True

    def context(self, attempt: int = 0) -> TraceContext:
        """Context for children of this span (carried over the wire)."""
        return TraceContext(self.trace_id, self.span_id, True, attempt)

    @property
    def ctx(self) -> TraceContext:
        return self.context()

    def tag(self, **kv) -> "Span":
        for k, v in kv.items():
            self.tags[k] = _scalar(v)
        return self

    def end(self) -> None:
        if self._done:
            return
        self._done = True
        self._tracer._record({
            "trace": self.trace_id, "span": self.span_id,
            "parent": self.parent_id, "name": self.name,
            "t0": self._t0, "t1": time.monotonic(),
            # wall derived from the tracer's one-time base: a span start
            # costs one clock read, not two (this is the decode hot path)
            "wall": self._t0 + self._tracer._wall_base,
            "replica": self._tracer.replica, "tags": self.tags,
        })

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, etype, exc, tb) -> bool:
        if exc is not None:
            self.tag(error=repr(exc))
        self.end()
        return False


class _NullSpan:
    """Shared no-op span: the entire cost of disabled/unsampled tracing is
    returning this singleton.  Its ``ctx`` is None, so nothing propagates
    and downstream stages also no-op."""

    __slots__ = ()
    recording = False
    ctx = None

    def context(self, attempt: int = 0) -> None:
        return None

    def tag(self, **kv) -> "_NullSpan":
        return self

    def end(self) -> None:
        pass

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, etype, exc, tb) -> bool:
        return False


NULL_SPAN = _NullSpan()


class Tracer:
    """Thread-safe span factory over a bounded per-process buffer.

    ``span(name)`` with no parent is a *root*: it makes the sampling
    decision.  ``span(name, parent=ctx_or_span)`` is a child: it inherits
    the root's decision (an unsampled root handed out a ``None`` ctx, so
    its children never reach this tracer at all).
    """

    def __init__(self, enabled: bool = True, sample_rate: float = 1.0,
                 capacity: int = 8192, replica: str = "parent"):
        self.enabled = enabled
        self.sample_rate = float(sample_rate)
        self.replica = str(replica)
        self._lock = threading.Lock()
        self._spans: deque = deque(maxlen=capacity)
        self.dropped = 0
        self._ids = itertools.count(1)
        self._prefix = f"{random.getrandbits(32):08x}"
        self._rng = random.Random(os.getpid() ^ random.getrandbits(30))
        self._wall_base = time.time() - time.monotonic()

    # -- span creation ---------------------------------------------------
    def _new_id(self) -> str:
        return f"{self._prefix}-{next(self._ids):x}"

    def span(self, name: str, parent: Any = None, **tags) -> Any:
        """Start a span.  ``parent`` may be None (root), a
        :class:`TraceContext`, or another :class:`Span`."""
        if not self.enabled:
            return NULL_SPAN
        if parent is None:
            if self.sample_rate < 1.0 and \
                    self._rng.random() >= self.sample_rate:
                return NULL_SPAN
            sp = Span(self, self._new_id(), self._new_id(), None, name)
        else:
            if isinstance(parent, (Span, _NullSpan)):
                parent = parent.ctx
            if parent is None or not parent.sampled:
                return NULL_SPAN
            sp = Span(self, parent.trace_id, self._new_id(),
                      parent.span_id, name)
            if parent.attempt:
                sp.tags["attempt"] = parent.attempt
        if tags:
            sp.tag(**tags)
        return sp

    # -- buffer ----------------------------------------------------------
    def _record(self, span_dict: Dict[str, Any]) -> None:
        with self._lock:
            if len(self._spans) == self._spans.maxlen:
                self.dropped += 1
            self._spans.append(span_dict)

    def ingest(self, spans: Sequence[Dict[str, Any]],
               replica: Any = None) -> None:
        """Adopt spans shipped from a remote worker (heartbeat payload).
        ``replica`` re-homes spans the worker recorded under its own
        default label."""
        if not spans:
            return
        with self._lock:
            for s in spans:
                if not isinstance(s, dict) or "span" not in s:
                    continue                # malformed: drop, don't die
                if replica is not None and \
                        s.get("replica") in (None, "", "worker"):
                    s = dict(s)
                    s["replica"] = str(replica)
                if len(self._spans) == self._spans.maxlen:
                    self.dropped += 1
                self._spans.append(s)

    def drain(self) -> List[Dict[str, Any]]:
        """Take-and-clear: how a worker ships its spans over heartbeats."""
        with self._lock:
            out = list(self._spans)
            self._spans.clear()
        return out

    def spans(self) -> List[Dict[str, Any]]:
        """Non-destructive snapshot (export / assertions)."""
        with self._lock:
            return list(self._spans)


#: shared disabled tracer: the default for every component that was not
#: handed (or globally given) a real one.
NULL_TRACER = Tracer(enabled=False, capacity=1)


_TRACER: Tracer = NULL_TRACER
_TRACER_LOCK = threading.Lock()


def set_tracer(tracer: Optional[Tracer]) -> None:
    """Install the process-wide tracer (mirrors
    ``metrics.set_worker_registry``): worker entry points install theirs
    before ``spec.build()`` so backends adopt it; the parent installs one
    before constructing the router.  ``None`` restores the no-op."""
    global _TRACER
    with _TRACER_LOCK:
        _TRACER = tracer if tracer is not None else NULL_TRACER


def current_tracer() -> Tracer:
    return _TRACER


# ----------------------------------------------------------------------
# Flight recorder: the last N structured events, always on.

class FlightRecorder:
    """Bounded ring of ``{"seq", "t", "wall", "kind", ...fields}`` events.

    ``seq`` is monotonic per recorder, so remote workers can ship
    *increments* over heartbeats (:meth:`since`) and the parent-side
    mirror never double-counts.  Recording is one lock + dict build —
    cheap enough for per-request cluster events and per-sync engine
    events, which is the point: the buffer must already be populated when
    the crash happens."""

    def __init__(self, capacity: int = 512, replica: str = ""):
        self.capacity = capacity
        self.replica = str(replica)
        self._lock = threading.Lock()
        self._events: deque = deque(maxlen=capacity)
        self._seq = 0

    def record(self, kind: str, **fields) -> None:
        with self._lock:
            self._seq += 1
            evt = {"seq": self._seq, "t": time.monotonic(),
                   "wall": time.time(), "kind": kind}
            if self.replica:
                evt["replica"] = self.replica
            for k, v in fields.items():
                evt[k] = _scalar(v)
            self._events.append(evt)

    def events(self) -> List[Dict[str, Any]]:
        with self._lock:
            return list(self._events)

    def since(self, seq: int) -> List[Dict[str, Any]]:
        """Events with ``seq`` strictly greater than ``seq`` (heartbeat
        increments)."""
        with self._lock:
            return [e for e in self._events if e["seq"] > seq]

    @property
    def last_seq(self) -> int:
        return self._seq

    def dump_json(self, **extra) -> bytes:
        doc = dict(extra)
        doc["events"] = self.events()
        return json.dumps(doc, sort_keys=True, default=str).encode()


_RECORDER: Optional[FlightRecorder] = None
_RECORDER_LOCK = threading.Lock()


def set_recorder(recorder: Optional[FlightRecorder]) -> None:
    global _RECORDER
    with _RECORDER_LOCK:
        _RECORDER = recorder


def current_recorder() -> FlightRecorder:
    """Process-wide flight recorder, lazily created (always on: the ring
    must be full of history *before* anything goes wrong)."""
    global _RECORDER
    if _RECORDER is None:
        with _RECORDER_LOCK:
            if _RECORDER is None:
                _RECORDER = FlightRecorder()
    return _RECORDER


# ----------------------------------------------------------------------
# Exporter 1: Chrome trace-event JSON (Perfetto / chrome://tracing).

def to_chrome_trace(spans: Sequence[Dict[str, Any]]) -> Dict[str, Any]:
    """Complete ("X") events on one track per (replica, stage).

    ``pid`` maps replicas, ``tid`` maps stage names within a replica, and
    metadata events give both human names, so Perfetto renders one lane
    per replica with its stages stacked.  ``ts`` is the span's monotonic
    start in µs (same-host comparable; see module docstring), ``args``
    carries ids + tags so a span's tree is reconstructible from the file.
    """
    events: List[Dict[str, Any]] = []
    pids: Dict[str, int] = {}
    tids: Dict[tuple, int] = {}
    for s in spans:
        replica = str(s.get("replica", "parent"))
        if replica not in pids:
            pids[replica] = len(pids) + 1
            events.append({"ph": "M", "name": "process_name",
                           "pid": pids[replica], "tid": 0,
                           "args": {"name": f"replica:{replica}"}})
        key = (replica, s["name"])
        if key not in tids:
            tids[key] = len([k for k in tids if k[0] == replica]) + 1
            events.append({"ph": "M", "name": "thread_name",
                           "pid": pids[replica], "tid": tids[key],
                           "args": {"name": s["name"]}})
        args = {"trace_id": s.get("trace"), "span_id": s.get("span"),
                "parent_id": s.get("parent")}
        args.update(s.get("tags") or {})
        events.append({
            "ph": "X", "cat": "repro", "name": s["name"],
            "pid": pids[replica], "tid": tids[(replica, s["name"])],
            "ts": float(s["t0"]) * 1e6,
            "dur": max(float(s["t1"]) - float(s["t0"]), 0.0) * 1e6,
            "args": args,
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


# ----------------------------------------------------------------------
# Exporter 2: Prometheus text exposition of a (merged) registry snapshot.

_PROM_SANITIZE = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str, prefix: str) -> str:
    out = _PROM_SANITIZE.sub("_", name)
    if out and out[0].isdigit():
        out = "_" + out
    return f"{prefix}_{out}" if prefix else out


def prometheus_text(snapshot: Dict[str, float],
                    prefix: str = "repro") -> str:
    """Render a flat ``snapshot()`` / ``cluster_snapshot()`` dict as
    Prometheus text exposition.

    Histogram stems (keys shipping ``.count`` + ``.p50``) become native
    histograms — cumulative ``_bucket{le=...}`` series rebuilt from the
    ``.le<i>`` counts against :data:`~repro.cluster.metrics.
    HIST_BUCKET_BOUNDS`, plus ``_sum`` (mean x count) and ``_count`` —
    with the snapshot's percentile estimates exported alongside as
    ``<stem>_p50`` etc. gauges.  Everything else exports as a gauge.

    Conformance hardening (all repairs, never assertions — the exporter
    runs on telemetry paths and must not raise on a weird merge):

    * ``_bucket`` series are monotone non-decreasing by construction —
      negative per-bucket increments (a torn merge) clamp to zero;
    * ``le="+Inf"`` always equals ``_count``, including for legacy
      bucket-less stems, and both are raised to the bucket total when
      the buckets have seen more than ``.count`` reports;
    * every metric gets a ``# HELP`` line before its ``# TYPE``;
    * two source keys sanitizing to the same metric name do not
      interleave: the later (sorted) key is emitted under a
      deterministic ``_dup<n>`` suffix instead.
    """
    lines: List[str] = []
    consumed = set()
    used_names: Dict[str, str] = {}     # emitted base name -> source key

    def unique(name: str, source: str, *derived: str) -> str:
        """Claim ``name`` (and histogram-derived series names) for
        ``source``; on a collision pick the first free ``_dup<n>``."""
        base, n = name, 1
        while any(d in used_names for d in (name, *[f"{name}{s}"
                                                    for s in derived])):
            n += 1
            name = f"{base}_dup{n}"
        used_names[name] = source
        for s in derived:
            used_names[f"{name}{s}"] = source
        return name

    stems = sorted(k[:-len(".count")] for k in snapshot
                   if k.endswith(".count")
                   and f"{k[:-len('.count')]}.p50" in snapshot)
    for stem in stems:
        name = unique(_prom_name(stem, prefix), stem,
                      "_bucket", "_sum", "_count")
        count = snapshot[f"{stem}.count"]
        mean = snapshot.get(f"{stem}.mean", 0.0)
        consumed.update({f"{stem}.count", f"{stem}.mean"})
        lines.append(f"# HELP {name} histogram of {stem} "
                     f"(merged cluster snapshot)")
        lines.append(f"# TYPE {name} histogram")
        cum = 0.0
        for i, bound in enumerate(HIST_BUCKET_BOUNDS):
            cum += max(snapshot.get(f"{stem}.le{i}", 0.0), 0.0)
            consumed.add(f"{stem}.le{i}")
            lines.append(f'{name}_bucket{{le="{bound:.6g}"}} {cum:.6g}')
        overflow_key = f"{stem}.le{len(HIST_BUCKET_BOUNDS)}"
        consumed.add(overflow_key)
        # +Inf must equal _count even for legacy snapshots with no
        # buckets, and must not dip below the finite-bucket cumulative
        total = max(count, cum + max(snapshot.get(overflow_key, 0.0), 0.0))
        lines.append(f'{name}_bucket{{le="+Inf"}} {total:.6g}')
        lines.append(f"{name}_sum {mean * count:.6g}")
        lines.append(f"{name}_count {total:.6g}")
        for p in (50, 95, 99):
            key = f"{stem}.p{p}"
            if key in snapshot:
                consumed.add(key)
                pname = unique(f"{name}_p{p}", key)
                lines.append(f"# HELP {pname} p{p} estimate of {stem}")
                lines.append(f"# TYPE {pname} gauge")
                lines.append(f"{pname} {snapshot[key]:.6g}")
    for k in sorted(snapshot):
        if k in consumed:
            continue
        name = unique(_prom_name(k, prefix), k)
        lines.append(f"# HELP {name} value of {k}")
        lines.append(f"# TYPE {name} gauge")
        lines.append(f"{name} {snapshot[k]:.6g}")
    return "\n".join(lines) + "\n"


# ----------------------------------------------------------------------
# Opt-in jax.profiler hooks: device time in the same timeline.

_PROFILING = False


def start_profiling(log_dir: str) -> None:
    """Start a ``jax.profiler`` trace into ``log_dir`` and arm
    :func:`annotate` (until then it is a ``nullcontext``)."""
    global _PROFILING
    import jax
    jax.profiler.start_trace(log_dir)
    _PROFILING = True


def stop_profiling() -> None:
    global _PROFILING
    if not _PROFILING:
        return
    _PROFILING = False
    import jax
    jax.profiler.stop_trace()


def annotate(name: str):
    """``TraceAnnotation`` around a jitted call while profiling is active
    (so host-side stage names land in the device timeline); otherwise a
    free ``nullcontext`` — safe to leave on every hot path."""
    if not _PROFILING:
        return nullcontext()
    from jax.profiler import TraceAnnotation
    return TraceAnnotation(name)
