"""Time-series telemetry over the flat cluster snapshot (paper §6: the
evaluation is about rates, latency and fall-behind over *time*).

``cluster_snapshot()`` answers point-in-time questions only — lifetime
counters and lifetime-reservoir percentiles never recover after a spike
and cannot express "what is the p99 *right now*".  This module samples
the merged snapshot at heartbeat cadence into a fixed-memory ring buffer
(:class:`TimeSeriesStore`) and types every stem:

* **counter** → reset-safe windowed :meth:`TimeSeriesStore.rate` (sum of
  positive consecutive increments — a restarted worker's residual reset
  clamps to zero instead of emitting a negative rate);
* **gauge** (``is_gauge_key``) → last value / EWMA;
* **histogram** → **windowed percentiles from bucket-count deltas**:
  the ``.le<i>`` keys are themselves monotone counters against
  :data:`~repro.cluster.metrics.HIST_BUCKET_BOUNDS`, so the per-bucket
  increment over a trailing window is an exact histogram of the window's
  observations, and ``bucket_percentile`` over those deltas is a true
  10-second p99, exact up to bucket resolution (10^(1/4)x).

On top ride the EWMA arrival-rate / service-rate estimators (published
as ``timeseries.*`` gauges for the predictive autoscaler), per-stage
latency attribution from the PR 6 span tree (:class:`StageAttributor`),
and the :class:`TelemetrySampler` thread that drives all of it plus the
optional SLO engine.

Memory is strictly bounded: at most ``max_stems`` tracked keys, each a
ring of ``capacity`` ``(t, value)`` pairs — ``max_points`` is the hard
ceiling, asserted in tests.
"""
from __future__ import annotations

import math
import threading
import time
from collections import OrderedDict, deque
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from .metrics import (_BUCKET_KEY_RE, _N_BUCKETS, MetricsRegistry,
                      bucket_percentile, is_gauge_key)

__all__ = [
    "TimeSeriesStore", "EwmaRate", "StageAttributor", "TelemetrySampler",
]


class TimeSeriesStore:
    """Fixed-memory ring buffer of sampled snapshot values, typed per stem.

    ``sample()`` appends every numeric key of a flat snapshot dict with a
    timestamp; readers derive windowed rates, EWMAs and bucket-delta
    percentiles.  All methods are thread-safe; reads take a snapshot of
    the relevant ring under the lock and compute outside critical
    sections where possible (rings are small — ``capacity`` defaults to
    240 samples ≈ one minute at heartbeat cadence).
    """

    def __init__(self, capacity: int = 240, max_stems: int = 1024,
                 clock: Callable[[], float] = time.monotonic):
        if capacity < 2:
            raise ValueError("capacity must be >= 2")
        self.capacity = int(capacity)
        self.max_stems = int(max_stems)
        self._clock = clock
        self._lock = threading.Lock()
        self._series: Dict[str, deque] = {}
        self._ticks: deque = deque(maxlen=self.capacity)
        self.dropped_keys = 0          # keys refused by the max_stems bound

    # -- bounds ---------------------------------------------------------
    @property
    def max_points(self) -> int:
        """Hard memory ceiling: ring capacity x stem bound."""
        return self.capacity * self.max_stems

    @property
    def n_points(self) -> int:
        with self._lock:
            return sum(len(d) for d in self._series.values())

    def keys(self) -> List[str]:
        with self._lock:
            return list(self._series)

    # -- writing --------------------------------------------------------
    def sample(self, snap: Dict[str, float],
               now: Optional[float] = None) -> None:
        """Record one snapshot.  Non-numeric values are skipped; keys
        beyond ``max_stems`` are counted in ``dropped_keys`` rather than
        grown unboundedly."""
        t = self._clock() if now is None else float(now)
        with self._lock:
            self._ticks.append(t)
            for k, v in snap.items():
                if isinstance(v, bool) or not isinstance(v, (int, float)):
                    continue
                d = self._series.get(k)
                if d is None:
                    if len(self._series) >= self.max_stems:
                        self.dropped_keys += 1
                        continue
                    d = self._series[k] = deque(maxlen=self.capacity)
                d.append((t, float(v)))

    # -- typing ---------------------------------------------------------
    @staticmethod
    def key_type(key: str) -> str:
        """'bucket' | 'counter' | 'gauge' for a flat snapshot key."""
        if _BUCKET_KEY_RE.match(key):
            return "bucket"              # .le<i>: monotone counter series
        if key.endswith(".count"):
            return "counter"
        if is_gauge_key(key):
            return "gauge"
        return "counter"

    def histogram_stems(self) -> List[str]:
        """Stems that ship bucketed counts (``<stem>.le<i>`` keys)."""
        with self._lock:
            stems = {m.group("stem") for k in self._series
                     if (m := _BUCKET_KEY_RE.match(k))}
        return sorted(stems)

    # -- reading: points ------------------------------------------------
    def last(self, key: str) -> Optional[float]:
        with self._lock:
            d = self._series.get(key)
            return d[-1][1] if d else None

    def points(self, key: str,
               window_s: Optional[float] = None,
               now: Optional[float] = None) -> List[Tuple[float, float]]:
        with self._lock:
            d = self._series.get(key)
            pts = list(d) if d else []
        if window_s is not None:
            t = (self._clock() if now is None else now) - window_s
            pts = [p for p in pts if p[0] >= t]
        return pts

    def ewma(self, key: str, halflife_s: float = 5.0,
             now: Optional[float] = None) -> float:
        """Exponentially-weighted last value over the stored ring
        (irregular sampling handled via per-step decay)."""
        pts = self.points(key)
        if not pts:
            return 0.0
        est, t_prev = pts[0][1], pts[0][0]
        for t, v in pts[1:]:
            dt = max(t - t_prev, 0.0)
            alpha = 1.0 - math.exp(-dt * math.log(2.0) / max(halflife_s,
                                                             1e-9))
            est += alpha * (v - est)
            t_prev = t
        return est

    # -- reading: windowed counter math ---------------------------------
    def _window_increase(self, key: str, window_s: float,
                         now: float) -> Tuple[float, float]:
        """(total positive increase, seconds covered) for a counter key
        over the trailing window.

        Counter resets (a restarted worker shrinking the merged total)
        clamp each negative consecutive delta to zero — the increase is
        the sum of positive steps, never negative.  A key first seen
        mid-window counts its full first value as an increase *only* if
        the store was already ticking before it appeared (absent key ==
        zero); a store attaching to a long-running source must not credit
        lifetime totals as fresh traffic.
        """
        cutoff = now - window_s
        with self._lock:
            d = self._series.get(key)
            pts = list(d) if d else []
            ticks = list(self._ticks)
        if not pts:
            return 0.0, 0.0
        # baseline: last sample at or before the cutoff, else a synthetic
        # zero at the last pre-appearance store tick inside the window
        base: Optional[Tuple[float, float]] = None
        in_win: List[Tuple[float, float]] = []
        for p in pts:
            if p[0] <= cutoff:
                base = p
            else:
                in_win.append(p)
        if not in_win:
            return 0.0, 0.0
        if base is None:
            first_t = in_win[0][0]
            prev_ticks = [t for t in ticks if cutoff <= t < first_t]
            if prev_ticks:
                base = (prev_ticks[-1], 0.0)
        seq = ([base] if base is not None else []) + in_win
        inc = 0.0
        for (t0, v0), (t1, v1) in zip(seq, seq[1:]):
            inc += max(v1 - v0, 0.0)
        covered = now - (seq[0][0] if base is not None else in_win[0][0])
        return inc, max(covered, 0.0)

    def increase(self, key: str, window_s: float,
                 now: Optional[float] = None) -> float:
        """Reset-clamped total increase of a counter over the window."""
        t = self._clock() if now is None else float(now)
        inc, _ = self._window_increase(key, window_s, t)
        return inc

    def rate(self, key: str, window_s: float,
             now: Optional[float] = None) -> float:
        """Windowed per-second rate of a counter key; >= 0 always (resets
        clamp to zero rather than going negative)."""
        t = self._clock() if now is None else float(now)
        inc, covered = self._window_increase(key, window_s, t)
        if covered <= 0.0:
            return 0.0
        return inc / covered

    # -- reading: windowed histogram math -------------------------------
    def window_bucket_counts(self, stem: str, window_s: float,
                             now: Optional[float] = None) -> List[float]:
        """Per-bucket observation counts for the trailing window, from
        ``.le<i>`` counter deltas."""
        t = self._clock() if now is None else float(now)
        return [self.increase(f"{stem}.le{i}", window_s, now=t)
                for i in range(_N_BUCKETS)]

    def window_count(self, stem: str, window_s: float,
                     now: Optional[float] = None) -> float:
        return self.increase(f"{stem}.count", window_s, now=now)

    def window_percentile(self, stem: str, p: float, window_s: float,
                          now: Optional[float] = None) -> float:
        """Percentile of the observations that fell inside the trailing
        window — exact up to bucket resolution.  0.0 on an empty window;
        ``inf`` when the percentile lands in the overflow bucket."""
        counts = self.window_bucket_counts(stem, window_s, now=now)
        if sum(counts) <= 0:
            return 0.0
        return bucket_percentile(counts, p)

    def window_mean(self, stem: str, window_s: float,
                    now: Optional[float] = None) -> float:
        """Approximate windowed mean from bucket midpoints (the flat
        snapshot has no windowed sum; good to bucket resolution)."""
        from .metrics import HIST_BUCKET_BOUNDS
        counts = self.window_bucket_counts(stem, window_s, now=now)
        total = sum(counts)
        if total <= 0:
            return 0.0
        acc = 0.0
        for i, c in enumerate(counts):
            if c <= 0:
                continue
            if i >= len(HIST_BUCKET_BOUNDS):
                mid = HIST_BUCKET_BOUNDS[-1]       # overflow: floor at top
            else:
                lo = HIST_BUCKET_BOUNDS[i - 1] if i else 0.0
                mid = 0.5 * (lo + HIST_BUCKET_BOUNDS[i])
            acc += c * mid
        return acc / total

    # -- series views (for sparklines) ----------------------------------
    def rate_series(self, key: str, window_s: float,
                    now: Optional[float] = None,
                    max_points: int = 60) -> List[Tuple[float, float]]:
        """Windowed rate evaluated at each stored tick (trailing)."""
        t_now = self._clock() if now is None else float(now)
        with self._lock:
            ticks = list(self._ticks)
        ticks = [t for t in ticks if t <= t_now][-max_points:]
        return [(t, self.rate(key, window_s, now=t)) for t in ticks]

    def percentile_series(self, stem: str, p: float, window_s: float,
                          now: Optional[float] = None,
                          max_points: int = 60) -> List[Tuple[float, float]]:
        t_now = self._clock() if now is None else float(now)
        with self._lock:
            ticks = list(self._ticks)
        ticks = [t for t in ticks if t <= t_now][-max_points:]
        return [(t, self.window_percentile(stem, p, window_s, now=t))
                for t in ticks]

    # -- export ---------------------------------------------------------
    def to_json(self, windows: Sequence[float] = (10.0, 60.0),
                now: Optional[float] = None) -> Dict[str, Any]:
        """Schema served at ``/timeseries.json`` — windowed views only,
        no raw rings (bounded payload regardless of capacity)."""
        t = self._clock() if now is None else float(now)
        hist_stems = set(self.histogram_stems())
        hist_members = set()
        for s in hist_stems:
            hist_members.add(f"{s}.count")
            hist_members.add(f"{s}.mean")
            for p in (50, 95, 99):
                hist_members.add(f"{s}.p{p}")
            for i in range(_N_BUCKETS):
                hist_members.add(f"{s}.le{i}")
        counters: Dict[str, Any] = {}
        gauges: Dict[str, Any] = {}
        for k in self.keys():
            if k in hist_members:
                continue
            if self.key_type(k) == "gauge":
                gauges[k] = {"last": self.last(k), "ewma": self.ewma(k)}
            else:
                counters[k] = {
                    "last": self.last(k),
                    "rate": {f"{w:g}s": self.rate(k, w, now=t)
                             for w in windows},
                }
        hists: Dict[str, Any] = {}
        for s in sorted(hist_stems):
            hists[s] = {
                "count_rate": {f"{w:g}s": (self.window_count(s, w, now=t)
                                           / w) for w in windows},
                **{f"p{p}": {f"{w:g}s": _finite(
                    self.window_percentile(s, p, w, now=t))
                    for w in windows} for p in (50, 95, 99)},
                "mean": {f"{w:g}s": self.window_mean(s, w, now=t)
                         for w in windows},
                "lifetime_p99": self.last(f"{s}.p99"),
            }
        return {
            "now": t,
            "windows": [float(w) for w in windows],
            "n_keys": len(self.keys()),
            "n_points": self.n_points,
            "max_points": self.max_points,
            "dropped_keys": self.dropped_keys,
            "counters": counters,
            "gauges": gauges,
            "histograms": hists,
        }


def _finite(v: float) -> float:
    """JSON has no inf: clamp overflow-bucket percentiles to a sentinel
    (the top histogram bound is ~1e3 s; 1e9 is unambiguous)."""
    return v if math.isfinite(v) else 1e9


class EwmaRate:
    """EWMA per-second rate from a monotone counter, robust to irregular
    update intervals and counter resets (negative deltas clamp to 0)."""

    def __init__(self, halflife_s: float = 5.0):
        self.halflife_s = float(halflife_s)
        self._rate = 0.0
        self._last_v: Optional[float] = None
        self._last_t: Optional[float] = None

    def update(self, value: float, now: float) -> float:
        if self._last_t is None:
            self._last_v, self._last_t = float(value), float(now)
            return self._rate
        dt = now - self._last_t
        if dt <= 0:
            return self._rate
        inst = max(float(value) - self._last_v, 0.0) / dt
        alpha = 1.0 - math.exp(-dt * math.log(2.0) /
                               max(self.halflife_s, 1e-9))
        self._rate += alpha * (inst - self._rate)
        self._last_v, self._last_t = float(value), float(now)
        return self._rate

    @property
    def rate(self) -> float:
        return self._rate


# ----------------------------------------------------------------------
# Per-stage latency attribution from the span tree.

# span name -> dashboard segment (spans whose wall time IS the segment)
_SEGMENT_SPANS = {
    "admission.decide": "admission",
    "router.dispatch": "dispatch",
    "engine.prefill": "prefill",
    "engine.decode_sync": "decode",
    "engine.stream_emit": "stream",
}
# spans that mark the start of replica-side execution: queue time is the
# gap between the transport handing the request off (transport.inflight
# t0) and the first of these
_EXEC_START_SPANS = ("replica.batch", "engine.request", "engine.admit")


class StageAttributor:
    """Derive ``stage.<kind>.<segment>_s`` histograms from the existing
    span tree, so the dashboard shows *where* p99 lives.

    Spans are polled non-destructively (``Tracer.spans()``) so the
    Chrome-trace exporter still sees everything; a bounded seen-set
    dedups across polls.  Segments buffer per trace until the root
    ``request`` span arrives with the backend-kind tag, then flush into
    per-kind and aggregate (``stage.any.*``) histograms; traces whose
    root never shows (dropped from the ring) flush as ``any`` on
    eviction.
    """

    def __init__(self, registry: MetricsRegistry,
                 max_pending: int = 1024, max_seen: int = 65536):
        self.registry = registry
        self._pending: "OrderedDict[str, Dict[str, Any]]" = OrderedDict()
        self._max_pending = max_pending
        self._seen: "OrderedDict[Tuple[str, str], None]" = OrderedDict()
        self._max_seen = max_seen
        self._lock = threading.Lock()

    def _entry(self, trace: str) -> Dict[str, Any]:
        e = self._pending.get(trace)
        if e is None:
            e = self._pending[trace] = {
                "segments": [], "inflight_t0": None, "exec_t0": None,
                "kind": None,
            }
            while len(self._pending) > self._max_pending:
                old_trace, old = self._pending.popitem(last=False)
                self._flush(old)
        return e

    def consume(self, spans: Sequence[Dict[str, Any]]) -> None:
        with self._lock:
            for s in spans:
                sid = (s.get("trace"), s.get("span"))
                if sid in self._seen:
                    continue
                self._seen[sid] = None
                while len(self._seen) > self._max_seen:
                    self._seen.popitem(last=False)
                self._ingest(s)

    def _ingest(self, s: Dict[str, Any]) -> None:
        trace = s.get("trace")
        if not trace:
            return
        name = s.get("name", "")
        tags = s.get("tags") or {}
        e = self._entry(trace)
        if name in _SEGMENT_SPANS:
            e["segments"].append((_SEGMENT_SPANS[name],
                                  float(s.get("wall", 0.0))))
        elif name == "transport.inflight":
            t0 = s.get("t0")
            if t0 is not None and (e["inflight_t0"] is None
                                   or t0 < e["inflight_t0"]):
                e["inflight_t0"] = t0
            if tags.get("kind"):
                e["kind"] = str(tags["kind"])
        elif name in _EXEC_START_SPANS:
            t0 = s.get("t0")
            if t0 is not None and (e["exec_t0"] is None
                                   or t0 < e["exec_t0"]):
                e["exec_t0"] = t0
        if name == "request":
            if tags.get("kind"):
                e["kind"] = str(tags["kind"])
            self._pending.pop(trace, None)
            self._flush(e)

    def _flush(self, e: Dict[str, Any]) -> None:
        kind = e.get("kind") or "any"
        segs = list(e["segments"])
        if e["inflight_t0"] is not None and e["exec_t0"] is not None:
            segs.append(("queue",
                         max(e["exec_t0"] - e["inflight_t0"], 0.0)))
        for seg, dur in segs:
            self.registry.histogram(f"stage.any.{seg}_s").observe(dur)
            if kind != "any":
                self.registry.histogram(
                    f"stage.{kind}.{seg}_s").observe(dur)


class TelemetrySampler:
    """Background thread driving the telemetry loop at heartbeat cadence:
    sample ``snapshot_fn()`` into the store, update the EWMA arrival /
    service rate gauges, attribute stage latency from the tracer, and
    tick the SLO engine.  ``tick()`` is public so tests (and the
    ``--watch`` renderer) can drive it deterministically without the
    thread."""

    def __init__(self, snapshot_fn: Callable[[], Dict[str, float]],
                 store: TimeSeriesStore,
                 registry: Optional[MetricsRegistry] = None,
                 tracer: Optional[Any] = None,
                 slo: Optional[Any] = None,
                 period_s: float = 0.25,
                 clock: Callable[[], float] = time.monotonic):
        self.snapshot_fn = snapshot_fn
        self.store = store
        self.registry = registry
        self.period_s = float(period_s)
        self.tracer = tracer
        self.slo = slo
        self._clock = clock
        self.arrival = EwmaRate(halflife_s=max(4 * self.period_s, 1.0))
        self.service = EwmaRate(halflife_s=max(4 * self.period_s, 1.0))
        self.attributor = (StageAttributor(registry)
                           if registry is not None else None)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.ticks = 0

    # -- one step (deterministic entry point) ---------------------------
    def tick(self, now: Optional[float] = None) -> Dict[str, float]:
        t = self._clock() if now is None else float(now)
        if self.attributor is not None and self.tracer is not None:
            # attribute first so stage.* stems appear in this snapshot
            self.attributor.consume(self.tracer.spans())
        snap = self.snapshot_fn()
        arrival = self.arrival.update(snap.get("router.submitted", 0.0), t)
        service = self.service.update(
            snap.get("router.finish.total", 0.0), t)
        if self.registry is not None:
            self.registry.gauge("timeseries.arrival_rate_hz").set(arrival)
            self.registry.gauge("timeseries.service_rate_hz").set(service)
            snap = dict(snap)
            snap["timeseries.arrival_rate_hz"] = arrival
            snap["timeseries.service_rate_hz"] = service
        self.store.sample(snap, now=t)
        if self.slo is not None:
            self.slo.tick(self.store, now=t)
        self.ticks += 1
        return snap

    # -- thread ---------------------------------------------------------
    def start(self) -> "TelemetrySampler":
        if self._thread is not None:
            return self
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._run, name="telemetry-sampler", daemon=True)
        self._thread.start()
        return self

    def _run(self) -> None:
        while not self._stop.is_set():
            try:
                self.tick()
            except Exception:               # telemetry must never take
                pass                        # the service down with it
            self._stop.wait(self.period_s)

    def stop(self) -> None:
        self._stop.set()
        t = self._thread
        if t is not None:
            t.join(timeout=5.0)
            self._thread = None
