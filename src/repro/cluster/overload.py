"""Overload control: replica circuit breaking and graded brownout.

Two small, independently testable controllers the router consults on its
hot paths:

* :class:`CircuitBreaker` — a replica that crash-loops (N deaths inside a
  sliding window) is *quarantined*: the router stops ranking it for
  dispatch for a cooldown, then re-admits it through a **half-open**
  probe — exactly one request is allowed through; an ack closes the
  breaker, another death re-opens it with a fresh cooldown.  This is the
  standard three-state breaker ("Large-Scale Intelligent Microservices"
  calls it the first prerequisite of fleet stability): without it a
  flapping worker keeps winning ranking rounds and every retry lands on
  the same corpse.

* :class:`BrownoutController` — graded degradation *before* shedding.
  Overload pressure is the max of queue occupancy and KV-pool occupancy;
  crossing a level's enter threshold raises the level, and the level only
  drops after pressure falls below the (lower) exit threshold — classic
  hysteresis, so a workload oscillating around a boundary does not flap
  the ladder.  The levels degrade in cost order:

    ===== ==============================================================
    level effect
    ===== ==============================================================
    0     normal service
    1     speculative decode off (frees draft + verify compute)
    2     \\+ effective ``max_new`` halved (streams finish in half the
          decode budget, so *every* admitted stream can meet its
          deadline instead of a few finishing full-length while the
          rest expire)
    3     \\+ admission tightened (queue bound scaled down — load is
          shed at the front door rather than expiring in queues)
    ===== ==============================================================

Both take an injectable clock so tests never sleep.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Deque, Dict


@dataclasses.dataclass(frozen=True)
class BreakerConfig:
    #: deaths within ``window_s`` that trip the breaker
    crash_threshold: int = 3
    window_s: float = 30.0
    #: quarantine duration before the half-open probe
    cooldown_s: float = 5.0


class CircuitBreaker:
    """Per-replica crash-loop breaker: closed -> open -> half_open.

    The router records every replica death (:meth:`record_crash`) and asks
    :meth:`allow` before ranking a replica for dispatch.  ``allow`` is
    side-effect free (a replica may be ranked without being offered work);
    the probe slot is consumed by :meth:`note_dispatch` on the first
    *successful* offer after the cooldown — that request is the probe —
    and the breaker answers False until the probe resolves via
    :meth:`record_ack` (close) or :meth:`record_crash` (re-open, fresh
    cooldown).
    """

    def __init__(self, cfg: BreakerConfig = BreakerConfig(),
                 clock: Callable[[], float] = time.monotonic):
        self.cfg = cfg
        self.clock = clock
        self._crashes: Dict[int, Deque[float]] = {}
        self._state: Dict[int, str] = {}        # default: "closed"
        self._open_until: Dict[int, float] = {}

    def state(self, rid: int) -> str:
        return self._state.get(rid, "closed")

    def record_crash(self, rid: int) -> bool:
        """Note a death; returns True when this crash *trips* (or
        re-trips) the breaker."""
        now = self.clock()
        if self._state.get(rid) == "half_open":
            # the probe failed: straight back to open, fresh cooldown
            self._state[rid] = "open"
            self._open_until[rid] = now + self.cfg.cooldown_s
            return True
        hist = self._crashes.setdefault(
            rid, deque(maxlen=self.cfg.crash_threshold))
        hist.append(now)
        if len(hist) == self.cfg.crash_threshold and \
                now - hist[0] <= self.cfg.window_s and \
                self._state.get(rid) != "open":
            self._state[rid] = "open"
            self._open_until[rid] = now + self.cfg.cooldown_s
            return True
        return False

    def record_ack(self, rid: int) -> None:
        """A completed request closes a half-open breaker (and clears the
        crash history — the replica earned a clean slate)."""
        if self._state.get(rid) == "half_open":
            self._state[rid] = "closed"
            self._crashes.pop(rid, None)

    def allow(self, rid: int) -> bool:
        """May the router rank this replica for dispatch right now?
        Side-effect free — ranking does not imply an offer."""
        st = self._state.get(rid, "closed")
        if st == "closed":
            return True
        if st == "open":
            return self.clock() >= self._open_until.get(rid, 0.0)
        # half_open: the single probe is already in flight
        return False

    def note_dispatch(self, rid: int) -> None:
        """A request was actually offered to this replica.  The first
        offer after an open breaker's cooldown becomes the half-open
        probe; everything else is a no-op."""
        if self._state.get(rid) == "open" and \
                self.clock() >= self._open_until.get(rid, 0.0):
            self._state[rid] = "half_open"

    def forget(self, rid: int) -> None:
        """Replica removed from the pool: drop its breaker state."""
        self._crashes.pop(rid, None)
        self._state.pop(rid, None)
        self._open_until.pop(rid, None)


@dataclasses.dataclass(frozen=True)
class BrownoutConfig:
    #: pressure thresholds entering levels 1..3 (monotone increasing)
    enter: tuple = (0.60, 0.75, 0.90)
    #: pressure thresholds for *leaving* levels 1..3 (each strictly below
    #: its enter threshold — the hysteresis band)
    exit: tuple = (0.45, 0.60, 0.75)

    def __post_init__(self):
        if len(self.enter) != 3 or len(self.exit) != 3:
            raise ValueError("brownout ladder has exactly 3 levels")
        if any(x >= e for e, x in zip(self.enter, self.exit)):
            raise ValueError("each exit threshold must sit below its "
                             "enter threshold (hysteresis band)")


class BrownoutController:
    """Hysteretic overload ladder over a scalar pressure signal.

    ``tick(queue_frac, kv_used_frac)`` folds the two occupancy signals
    into ``pressure = max(...)`` and moves the level at most one rung per
    call: up when pressure crosses the next enter threshold, down when it
    falls below the current level's exit threshold.  Returns the level;
    ``changed`` is True when this tick moved it (the caller broadcasts
    only on transitions).
    """

    def __init__(self, cfg: BrownoutConfig = BrownoutConfig()):
        self.cfg = cfg
        self.level = 0
        self.changed = False

    def tick(self, queue_frac: float, kv_used_frac: float = 0.0,
             extra: float = 0.0) -> int:
        """``extra`` admits additional pressure sources beyond the two
        occupancy signals — e.g. a firing SLO burn alert
        (``SLOEngine.pressure``) browning the service out *before* the
        queues themselves look full."""
        pressure = max(float(queue_frac), float(kv_used_frac),
                       float(extra))
        before = self.level
        if self.level < 3 and pressure >= self.cfg.enter[self.level]:
            self.level += 1
        elif self.level > 0 and pressure < self.cfg.exit[self.level - 1]:
            self.level -= 1
        self.changed = self.level != before
        return self.level

    #: admission scale at each level (L3 tightens the front door to 50%)
    ADMIT_SCALE = (1.0, 1.0, 1.0, 0.5)

    def admission_scale(self) -> float:
        return self.ADMIT_SCALE[self.level]
