"""Content-addressed artifact store for remote backend builds.

A :class:`~repro.cluster.backends.BackendSpec` must be rebuildable on a
host that shares no filesystem with the router — but specs carry *paths*
(``weights_path=...``).  The store closes that gap:

  * the router puts a weights file into its local store and references it
    from the spec as ``"artifact:<sha256>"`` (:func:`artifact_ref`);
  * a socket worker resolving the spec (:func:`resolve_spec`) looks each
    reference up in *its* store and, on a miss, fetches the bytes by hash —
    over the worker's own connection, via a ``("fetch", sha)`` frame the
    parent answers from its store — then verifies the digest before
    trusting the content.

Content addressing makes the cache safe to share between workers and
across restarts: a hash either matches its bytes or the fetch is refused,
and re-fetching an artifact that is already present is free.

:func:`spec_fingerprint` is the handshake's integrity check: parent and
worker hash the spec the same way, so a reconnecting worker built from a
stale spec (an old deployment, a different weights hash) is refused at
the door instead of silently serving wrong results.
"""
from __future__ import annotations

import hashlib
import json
import os
import random
import tempfile
import time
from typing import Callable, Optional

from repro.cluster.backends import BackendSpec

_PREFIX = "artifact:"


def sha256_bytes(data: bytes) -> str:
    return hashlib.sha256(data).hexdigest()


def sha256_file(path: str, chunk: int = 1 << 20) -> str:
    """Streaming digest: verifying a multi-GB checkpoint must not
    materialize it in RAM."""
    h = hashlib.sha256()
    with open(path, "rb") as f:
        while True:
            block = f.read(chunk)
            if not block:
                return h.hexdigest()
            h.update(block)


def artifact_ref(digest: str) -> str:
    return _PREFIX + digest


def is_artifact_ref(value) -> bool:
    return isinstance(value, str) and value.startswith(_PREFIX)


def ref_digest(ref: str) -> str:
    return ref[len(_PREFIX):]


class ArtifactStore:
    """Flat directory of files named by the sha256 of their content."""

    def __init__(self, root: Optional[str] = None):
        if root is None:
            # per-user, 0700: a world-shared fixed tempdir would let any
            # local user pre-plant a file under a victim's weights digest
            uid = getattr(os, "getuid", lambda: "u")()
            root = os.path.join(tempfile.gettempdir(),
                                f"repro-artifacts-{uid}")
        self.root = root
        os.makedirs(self.root, mode=0o700, exist_ok=True)

    def _path(self, digest: str) -> str:
        # strict sha256-hex only: a digest is a filename, so anything else
        # ("..", separators, empty) is a traversal attempt or corruption
        if not isinstance(digest, str) or len(digest) != 64 or \
                any(c not in "0123456789abcdef" for c in digest):
            raise ValueError(f"bad artifact digest {digest!r}")
        return os.path.join(self.root, digest)

    def has(self, digest: str) -> bool:
        return os.path.exists(self._path(digest))

    def _install(self, tmp: str, digest: str) -> None:
        """Atomically publish a fully-written private temp file under its
        digest, *re-verifying the bytes that actually hit disk* first.

        The re-verify closes the corruption window concurrent fetches used
        to have: a torn/short write (full disk, a crash mid-write, an I/O
        error the buffered writer swallowed) would otherwise be renamed
        into place and then *trusted* by every later worker that finds the
        file present.  Because the temp file is private (mkstemp) and the
        publish is a single ``os.replace``, N workers fetching the same
        hash race benignly: each verifies its own bytes, each rename is
        atomic, and the store never exposes a half-written artifact."""
        disk = sha256_file(tmp)
        if disk != digest:
            raise IOError(
                f"artifact write verification failed: wrote bytes hashing "
                f"to {disk}, expected {digest} — refusing to publish a "
                f"corrupt artifact")
        os.replace(tmp, self._path(digest))

    def put_bytes(self, data: bytes) -> str:
        digest = sha256_bytes(data)
        path = self._path(digest)
        # an existing file only short-circuits the write if its content
        # actually hashes to its name — anything else (pre-planted,
        # truncated) is overwritten with the verified bytes
        fresh = not os.path.exists(path) or sha256_file(path) != digest
        if fresh:
            # write-to-temp + digest re-verify + atomic rename: concurrent
            # puts of the same content race benignly to an identical file
            fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
            try:
                with os.fdopen(fd, "wb") as f:
                    f.write(data)
                self._install(tmp, digest)
            finally:
                if os.path.exists(tmp):
                    os.unlink(tmp)
        return digest

    def put_file(self, path: str, chunk: int = 1 << 20) -> str:
        """Streaming put: hash while copying into a private temp file,
        then verify + atomic-rename — a multi-GB checkpoint is never
        materialized in RAM and never observable half-copied."""
        h = hashlib.sha256()
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
        try:
            with open(path, "rb") as src, os.fdopen(fd, "wb") as dst:
                while True:
                    block = src.read(chunk)
                    if not block:
                        break
                    h.update(block)
                    dst.write(block)
            digest = h.hexdigest()
            target = self._path(digest)
            if os.path.exists(target) and sha256_file(target) == digest:
                return digest           # already installed and verified
            self._install(tmp, digest)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        return digest

    def get_path(self, digest: str) -> str:
        path = self._path(digest)
        if not os.path.exists(path):
            raise KeyError(f"artifact {digest} not in store {self.root}")
        return path

    def read_bytes(self, digest: str) -> bytes:
        with open(self.get_path(digest), "rb") as f:
            return f.read()

    def put_ref(self, path: str) -> str:
        """Store a file and return the spec-embeddable reference."""
        return artifact_ref(self.put_file(path))


# ----------------------------------------------------------------------
def fetch_with_retry(fetch: Callable[[str], Optional[bytes]], digest: str,
                     attempts: int = 4, base_s: float = 0.2,
                     max_s: float = 5.0, jitter: float = 0.5,
                     sleep: Optional[Callable[[float], None]] = None,
                     rng: Optional[random.Random] = None,
                     ) -> Optional[bytes]:
    """Bounded retry around a transient-miss-prone ``fetch(digest)``.

    Two failure modes bracket the design: a single transient miss (parent
    briefly mid-restart, a dropped frame) used to degrade straight into a
    hard ``KeyError`` from :func:`resolve_spec`; but unbounded retries
    after a mass reconnect would synchronize every worker into a fetch
    storm against the one parent holding the bytes.  So: cap the attempts
    (total failure stays an explicit, prompt error) and spread them —
    exponential backoff with multiplicative jitter drawn per *worker*
    (``rng`` defaults to OS-seeded, deliberately NOT digest-seeded, which
    would put all workers fetching the same artifact in lockstep).

    Returns the first non-``None`` result, or ``None`` after ``attempts``
    misses.  Exceptions from ``fetch`` propagate immediately — a closed
    channel is not a transient miss.
    """
    rng = rng if rng is not None else random.Random()
    do_sleep = sleep if sleep is not None else time.sleep
    for attempt in range(max(1, attempts)):
        data = fetch(digest)
        if data is not None:
            return data
        if attempt + 1 >= attempts:
            break
        delay = min(base_s * (2 ** attempt), max_s)
        do_sleep(delay * (1.0 + jitter * rng.random()))
    return None


def spec_fingerprint(spec: BackendSpec) -> str:
    """Stable content hash of a spec: target, kind, and kwargs (sorted;
    non-JSON values fall back to ``repr``, which is stable for the
    paths/numbers/strings specs are restricted to)."""
    blob = json.dumps(
        {"target": spec.target, "kind": spec.kind,
         "kwargs": {k: spec.kwargs[k] for k in sorted(spec.kwargs)}},
        sort_keys=True, default=repr).encode()
    return sha256_bytes(blob)


def resolve_spec(spec: BackendSpec, store: ArtifactStore,
                 fetch: Optional[Callable[[str], Optional[bytes]]] = None,
                 ) -> BackendSpec:
    """Rewrite every ``"artifact:<sha>"`` kwarg to a local file path.

    Missing artifacts are pulled via ``fetch(sha) -> bytes`` (the socket
    worker wires this to a ``("fetch", sha)`` round-trip); fetched bytes
    are digest-verified by the store's content addressing before use.
    Misses are retried a bounded number of times with jittered backoff
    (:func:`fetch_with_retry`) before degrading to ``KeyError``.
    """
    kwargs = dict(spec.kwargs)
    for key, value in spec.kwargs.items():
        if not is_artifact_ref(value):
            continue
        digest = ref_digest(value)
        cached_ok = store.has(digest) and \
            sha256_file(store.get_path(digest)) == digest
        # a cache hit is re-verified before trust: a pre-planted or
        # corrupted file under the right name is a miss, not a model
        if not cached_ok:
            data = fetch_with_retry(fetch, digest) \
                if fetch is not None else None
            if data is None:
                raise KeyError(
                    f"artifact {digest} (spec kwarg {key!r}) not in store "
                    f"and not fetchable")
            got = store.put_bytes(data)
            if got != digest:
                raise ValueError(
                    f"artifact {digest} fetch returned content hashing to "
                    f"{got} — refusing corrupt artifact")
        kwargs[key] = store.get_path(digest)
    if kwargs == dict(spec.kwargs):
        return spec
    return BackendSpec(spec.target, kwargs, spec.kind)
