"""Replica requests, backends, and the transport-agnostic worker driver.

The cluster's unit of scale — the paper's "worker node" — is a *replica*:
one backend (LM engine, SVM stream runtime, or any batched step function)
behind a bounded inbox.  A replica:

  * pulls up to ``max_batch`` requests from its inbox and runs them through
    the backend as one batch (the mapPartitions amortization);
  * reports liveness via a heartbeat timestamp and a busy fraction;
  * on a crash (injected fault or backend exception) *spills* every
    unacknowledged request — the batch that was in flight plus the whole
    inbox — so the router can requeue them on survivors.  Semantics are
    at-least-once (a crash between backend completion and acknowledgement
    reprocesses the batch elsewhere), which is the Spark
    lineage-recomputation contract; zero requests are lost.

*Where* the replica runs is a transport concern (``cluster/transport.py``):
``LocalTransport`` runs this driver on a host thread over a ``queue.Queue``
inbox; ``ProcessTransport`` runs the same driver inside a spawned worker
process over an RPC inbox fed by a pipe.  The loop itself —
:func:`run_replica_loop` — is shared, so batching, crash-before-ack, and
graceful-drain semantics are identical on both sides of the process
boundary.
"""
from __future__ import annotations

import dataclasses
import enum
import queue
import threading
import time
from typing import Any, Callable, List, Optional

from repro.cluster.admission import Rejected
from repro.cluster.tracing import current_recorder, current_tracer


class Status(enum.Enum):
    PENDING = "pending"
    OK = "ok"
    REJECTED = "rejected"       # shed by admission control -> Rejected result
    FAILED = "failed"           # retries exhausted / no survivors / shutdown
    CANCELLED = "cancelled"     # Router.cancel() -> work dropped everywhere
    EXPIRED = "expired"         # deadline passed before a useful completion


class Terminal:
    """Picklable terminal-result wrapper a replica acks for work it ended
    early instead of running to completion: deadline expiry (dropped from
    the worker queue, or finished mid-decode by the engine) and
    cancellation.  ``tokens`` carries whatever partial output existed at
    the cut, so a cancelled stream still returns what it produced.
    ``ClusterRequest.complete`` unwraps it into the matching terminal
    status rather than ``Status.OK``."""

    __slots__ = ("reason", "tokens")

    def __init__(self, reason: str, tokens: Any = None):
        self.reason = reason
        self.tokens = tokens if tokens is not None else []

    def __repr__(self) -> str:
        return f"Terminal({self.reason!r}, n_tokens={len(self.tokens)})"


@dataclasses.dataclass(frozen=True)
class WaitTimeout:
    """Typed sentinel returned by ``Router.wait(timeout=)`` when the
    request is still in flight at the timeout — instead of leaking the
    request's (unset) result.  The documented follow-up is
    ``router.cancel(req)``; the request itself is untouched and a later
    ``wait`` can still observe its terminal state."""
    rid: int
    waited_s: float


@dataclasses.dataclass
class ClusterRequest:
    """One end-user request travelling through the cluster."""
    payload: Any
    cost: int = 1                         # load units (e.g. tokens, rows)
    session_key: Optional[str] = None     # affinity key (user/session id)
    kind: Optional[str] = None            # backend kind (admission cost model)
    deadline_s: float = float("inf")      # absolute time.monotonic deadline
    rid: int = -1
    submitted_s: float = 0.0
    attempts: int = 0
    status: Status = Status.PENDING
    result: Any = None
    error: Optional[BaseException] = None
    replica_rid: Optional[int] = None     # replica that completed it
    done: threading.Event = dataclasses.field(default_factory=threading.Event)
    finished_s: float = 0.0
    # resilience: ``cancelled`` is set by ``Router.cancel`` before the
    # cancel frames fan out, so every router-side path (spill, requeue,
    # dispatch) refuses to move the request again; ``finish_reason``
    # mirrors the engine's taxonomy ("deadline", "cancelled", "poison",
    # "" for plain OK/FAILED); ``killed_replicas`` tallies the distinct
    # replicas whose death spilled this request (poison detection).
    cancelled: bool = False
    finish_reason: str = ""
    killed_replicas: set = dataclasses.field(default_factory=set)
    # streaming: partial-result frames forwarded by the replica while the
    # request is still in flight (e.g. per-K-step token slices from an LM
    # engine).  ``on_partial(frame)`` fires on the transport's receive
    # thread; ``partials`` keeps every frame for non-callback consumers.
    on_partial: Optional[Callable[[Any], None]] = None
    partials: List[Any] = dataclasses.field(default_factory=list)
    # tracing: the router-side root span (ended at the terminal state) and
    # the context dispatched with every attempt — the router refreshes
    # ``trace_ctx``'s attempt number on each respill so spans from a dead
    # attempt stay tagged apart from the retry's.
    trace_span: Any = None
    trace_ctx: Any = None
    # telemetry: the router attaches its registry so the single terminal
    # transition below can count every outcome by finish reason
    # (``router.finish.total`` / ``router.finish.<reason>``) — the SLO
    # engine's availability objective is computed from exactly these.
    # Never pickled: only payloads cross the transport boundary.
    metrics: Any = None

    def emit_partial(self, frame: Any) -> None:
        self.partials.append(frame)
        if self.on_partial is not None:
            try:
                self.on_partial(frame)
            except Exception:        # noqa: BLE001 - consumer's bug
                pass                 # streaming must never kill transport IO

    #: sentinel frame sent through ``on_partial`` when a spilled request
    #: is re-dispatched: the replacement replica re-runs from scratch and
    #: will re-stream every token, so incremental consumers must discard
    #: what they rendered for the previous attempt.
    RETRY_FRAME = ("__retry__",)

    def reset_partials(self) -> None:
        """At-least-once streaming: called by the router before a spilled
        request is requeued.  Clears the frame buffer (the authoritative
        ``partials`` view restarts with the new attempt) and signals
        ``on_partial`` consumers with :data:`RETRY_FRAME`."""
        if not self.partials:
            return
        self.partials.clear()
        if self.on_partial is not None:
            try:
                self.on_partial(self.RETRY_FRAME)
            except Exception:        # noqa: BLE001 - consumer's bug
                pass

    def _finish(self, status: Status):
        self.status = status
        self.finished_s = time.monotonic()
        if self.metrics is not None:
            reason = self.finish_reason or status.value
            self.metrics.counter("router.finish.total").inc()
            self.metrics.counter(f"router.finish.{reason}").inc()
        if self.trace_span is not None:
            self.trace_span.tag(status=status.value, attempts=self.attempts)
            self.trace_span.end()
        self.done.set()

    def complete(self, result: Any, replica_rid: int):
        if self.done.is_set():
            # late ack racing a local terminal (cancel after wait-timeout,
            # deadline downgrade): the first terminal state wins; dropping
            # the ack here is what keeps "never double-completed" true
            # without coordinating with every in-flight replica
            return
        self.replica_rid = replica_rid
        if isinstance(result, Terminal):
            # the replica ended this early (queue drop or mid-decode
            # finish) and shipped the partial output with the reason
            self.result = result.tokens
            self.finish_reason = result.reason
            self._finish(Status.CANCELLED if result.reason == "cancelled"
                         else Status.EXPIRED)
            return
        self.result = result
        if time.monotonic() > self.deadline_s:
            # a full result that arrived past the deadline is not a
            # success: nobody is waiting for it any more.  Downgrading at
            # the single completion point makes "nothing expired ever
            # completes ok" hold even for workers that predate deadline
            # propagation (old-build interop) and for acks already in
            # flight when the deadline passed.
            self.finish_reason = "deadline"
            self._finish(Status.EXPIRED)
            return
        self._finish(Status.OK)

    def reject(self, rejected: Rejected):
        self.result = rejected
        self._finish(Status.REJECTED)

    def fail(self, error: BaseException):
        self.error = error
        self._finish(Status.FAILED)

    def finish_cancelled(self):
        """Router-side terminal for a cancel that cannot expect an ack —
        the replica is dead, the request is between dispatches, or it was
        sitting in the requeue loop.  Idempotent against a racing ack."""
        if self.done.is_set():
            return
        self.cancelled = True
        self.finish_reason = "cancelled"
        self.result = None
        self._finish(Status.CANCELLED)

    def finish_expired(self):
        """Router-side terminal for work whose deadline passed while it
        had no live home (spilled, waiting for a survivor): re-dispatching
        it would burn a replica slot on an answer nobody reads."""
        if self.done.is_set():
            return
        self.finish_reason = "deadline"
        self.result = None
        self._finish(Status.EXPIRED)

    @property
    def missed_deadline(self) -> bool:
        return self.done.is_set() and self.finished_s > self.deadline_s

    def wait(self, timeout: Optional[float] = None) -> Any:
        self.done.wait(timeout)
        return self.result


class ReplicaCrash(RuntimeError):
    """Raised inside a worker loop by fault injection (or raised on the
    parent side of a process transport when the worker process dies)."""


# ----------------------------------------------------------------------
# Backends: anything with process(list_of_payloads) -> list_of_results.

class FnBackend:
    """Wrap a batched ``step_fn(payloads) -> results`` (tests, services)."""

    kind = "fn"                     # backend kind (admission cost model,
                                    # per-kind telemetry attribution)

    def __init__(self, step_fn: Callable[[List[Any]], List[Any]]):
        self.step_fn = step_fn

    def process(self, payloads: List[Any]) -> List[Any]:
        return self.step_fn(payloads)


class StreamBackend:
    """One SVM two-phase stream runtime per replica.

    Payloads are micro-batches ``(X, keys, ts)``.  ``fetch`` is the ingest
    stage (the paper's HDFS/storage document read + parse) applied per
    micro-batch before device compute; it blocks the host thread, which is
    exactly what overlapping replicas hide.
    """

    kind = "stream"

    def __init__(self, runtime, fetch: Optional[Callable[[Any], Any]] = None):
        self.runtime = runtime
        self.fetch = fetch

    def process(self, payloads: List[Any]) -> List[Any]:
        out = []
        for payload in payloads:
            if self.fetch is not None:
                payload = self.fetch(payload)
            X, keys, ts = payload
            sc, ok = self.runtime.process_microbatch(X, keys, ts)
            out.append((sc, ok))
        return out


#: payload sentinel tag for warm KV migration: a payload of
#: ``(KV_IMPORT_TAG, state)`` carries a drained replica's exported KV
#: blocks to its sessions' new home, where the engine adopts them before
#: the batch's real requests run (imports are idempotent, so the router's
#: at-least-once delivery is safe).
KV_IMPORT_TAG = "__kv_import__"


class EngineBackend:
    """One continuous-batching LM engine per replica.

    Payloads are ``(prompt_tokens, max_new)``; results are the generated
    token lists.  The whole pulled batch shares the engine's decode slots.
    A ``(KV_IMPORT_TAG, state)`` payload instead adopts a migrated
    replica's KV blocks (see :data:`KV_IMPORT_TAG`) and acks with
    ``("kv_imported", n_blocks)``.

    Streaming: when the driver binds an emitter (:meth:`bind_emitter`),
    each engine host sync forwards a ``(new_tokens, done)`` frame for the
    payload that produced it — partial tokens reach the submitter at
    K-step granularity instead of whole-request acks.
    """

    kind = "engine"

    def __init__(self, engine):
        self.engine = engine
        self._emit = None
        self._trace_ctxs = None
        self._deadlines = None
        self._cancel_poll = None
        self._brownout = 0
        self._spec0 = None     # engine's own speculative setting, lazily

    def bind_emitter(self, emit) -> None:
        """``emit(payload_index, frame)`` forwards a partial-result frame
        for the current batch; rebound by the driver per batch."""
        self._emit = emit

    def bind_trace(self, ctxs) -> None:
        """Per-payload :class:`~repro.cluster.tracing.TraceContext` list
        for the current batch (rebound by the driver, like the emitter),
        so engine-side spans parent into the cluster request's trace."""
        self._trace_ctxs = ctxs

    def bind_deadlines(self, deadlines) -> None:
        """Per-payload absolute ``time.monotonic`` deadlines (or None) for
        the current batch — the engine finishes a session mid-decode with
        ``finish_reason="deadline"`` once its entry passes."""
        self._deadlines = deadlines

    def bind_cancel(self, poll) -> None:
        """``poll(payload_index) -> bool`` checked by the engine each host
        sync; True finishes that session with
        ``finish_reason="cancelled"`` and frees its KV within the sync."""
        self._cancel_poll = poll

    #: brownout ladder, applied per level (cumulative): L1 disables
    #: speculative decode (frees draft+verify compute), L2 additionally
    #: halves the effective ``max_new`` (every admitted stream finishes in
    #: half the decode budget), L3 adds router-side admission tightening.
    def set_brownout(self, level: int) -> None:
        self._brownout = level
        eng = self.engine
        if self._spec0 is None:
            self._spec0 = bool(getattr(eng, "speculative", False))
        if hasattr(eng, "speculative"):
            eng.speculative = self._spec0 and level < 1

    @staticmethod
    def _is_kv_import(payload) -> bool:
        return isinstance(payload, tuple) and len(payload) == 2 and \
            isinstance(payload[0], str) and payload[0] == KV_IMPORT_TAG

    def process(self, payloads: List[Any]) -> List[Any]:
        emit = self._emit
        ctxs = self._trace_ctxs
        if ctxs is None or len(ctxs) != len(payloads):
            ctxs = [None] * len(payloads)
        dls = self._deadlines
        if dls is None or len(dls) != len(payloads):
            dls = [None] * len(payloads)
        poll = self._cancel_poll

        def on_tokens(i):
            if emit is None:
                return None
            return lambda req, toks, done: emit(i, (toks, done))

        def cancel_cb(i):
            if poll is None:
                return None
            return lambda: poll(i)

        results: List[Any] = [None] * len(payloads)
        # adopt migrated KV blocks FIRST so this very batch's requests
        # (the migrated sessions, rerouted here) hit the warm prefixes
        for i, payload in enumerate(payloads):
            if self._is_kv_import(payload):
                imp = getattr(self.engine, "import_kv_state", None)
                results[i] = ("kv_imported",
                              imp(payload[1]) if imp is not None else 0)
        live = [(i, p) for i, p in enumerate(payloads)
                if results[i] is None]
        # brownout L2+: shrink the decode budget so every admitted stream
        # completes inside its deadline at degraded length, instead of a
        # few streams completing full-length while the rest expire
        shrink = self._brownout >= 2
        reqs = [(i, self.engine.submit(
                    prompt,
                    max_new=max(1, max_new // 2) if shrink else max_new,
                    on_tokens=on_tokens(i),
                    trace_ctx=ctxs[i],
                    deadline_s=dls[i],
                    cancel_cb=cancel_cb(i)))
                for i, (prompt, max_new) in live]
        self.engine.run_until_drained()
        for i, r in reqs:
            # expired/cancelled sessions ack a Terminal so the parent can
            # land them in the matching status instead of OK; whatever
            # tokens existed at the cut ride along
            if r.finish_reason in ("deadline", "cancelled"):
                results[i] = Terminal(r.finish_reason, r.out_tokens)
            else:
                results[i] = r.out_tokens
        return results

    def export_kv_state(self):
        """Drain-time hand-off: the engine's migratable KV state (or None
        when there is nothing to ship)."""
        fn = getattr(self.engine, "export_kv_state", None)
        return fn() if fn is not None else None


# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ReplicaConfig:
    inbox_capacity: int = 64
    max_batch: int = 8
    poll_s: float = 0.002
    heartbeat_timeout_s: float = 5.0
    # Slow-loris guard (remote transports): a replica whose liveness signal
    # stays green (process alive / heartbeats flowing) but that has not
    # acknowledged its oldest dispatched request for this long is declared
    # dead, so its work reroutes to survivors.  0 disables the guard (the
    # default: legitimate deep inboxes over slow backends would trip a
    # short universal bound — size it to the deployment's batch SLO).
    ack_timeout_s: float = 0.0
    # process transports only: how often the worker ships a heartbeat +
    # metrics snapshot back to the parent, and how long the parent waits
    # for the spawned interpreter to import + build its backend.
    heartbeat_interval_s: float = 0.25
    spawn_timeout_s: float = 120.0


# ----------------------------------------------------------------------
# The transport-agnostic driver.  A transport hands it an "inbox IO" object:
#
#   rid                      replica id (for error messages)
#   heartbeat()              refresh the liveness signal
#   crash_requested() -> bool   fault injection checkpoint
#   closing() -> bool        graceful drain requested
#   get(timeout) / get_nowait()   next work item (raise queue.Empty)
#   payload(item)            the backend payload carried by an item
#   begin(batch)             batch is now in flight (unacknowledged)
#   emit(item, frame)        [optional] forward a partial-result frame for
#                            an in-flight item (streaming backends)
#   ack(batch, results, busy_s)   acknowledge a completed batch
#   spill(batch, error)      crash path: `batch` was in flight; the
#                            transport must also spill everything still
#                            queued and mark itself dead
#   close()                  graceful-exit path after the loop breaks
#
# Items are opaque to the driver: ``ClusterRequest`` objects on a local
# transport, ``(rid, cost, payload)`` triples inside a worker process.

def run_replica_loop(backend, cfg: ReplicaConfig, io) -> None:
    """Pull -> process -> acknowledge, with crash-before-ack spill
    semantics.  Shared by ``LocalTransport``'s thread and the
    ``ProcessTransport`` worker process."""
    while True:
        io.heartbeat()
        if io.crash_requested():
            io.spill([], ReplicaCrash(f"replica {io.rid}: injected crash"))
            return
        batch: List[Any] = []
        try:
            batch.append(io.get(cfg.poll_s))
            while len(batch) < cfg.max_batch:
                batch.append(io.get_nowait())
        except queue.Empty:
            pass
        if not batch:
            if io.closing():
                break
            continue
        # resilience pre-pass: work that is already pointless — past its
        # deadline while queued, or cancelled by the submitter — is acked
        # as a Terminal immediately, WITHOUT touching the backend, so an
        # overloaded replica burns zero compute on tokens nobody reads
        dl_fn = getattr(io, "deadline", None)
        cx_fn = getattr(io, "is_cancelled", None)
        if dl_fn is not None or cx_fn is not None:
            now = time.monotonic()
            live: List[Any] = []
            dropped: List[Any] = []
            terms: List[Terminal] = []
            for r in batch:
                if cx_fn is not None and cx_fn(r):
                    dropped.append(r)
                    terms.append(Terminal("cancelled"))
                    current_recorder().record("cancelled", replica=io.rid,
                                              where="queue")
                elif dl_fn is not None and (dl_fn(r) or float("inf")) < now:
                    dropped.append(r)
                    terms.append(Terminal("deadline"))
                    current_recorder().record("deadline_expired",
                                              replica=io.rid, where="queue")
                else:
                    live.append(r)
            if dropped:
                io.begin(dropped)
                io.ack(dropped, terms, 0.0)
            batch = live
            if not batch:
                continue
        io.begin(batch)
        # mid-flight resilience: a deadline/cancel-aware backend (the LM
        # engine) gets per-item deadlines and a cancel poll so sessions
        # end mid-decode instead of only at queue boundaries
        if dl_fn is not None and hasattr(backend, "bind_deadlines"):
            backend.bind_deadlines([dl_fn(r) for r in batch])
        if cx_fn is not None and hasattr(backend, "bind_cancel"):
            backend.bind_cancel(lambda i, _b=batch: cx_fn(_b[i]))
        # brownout: apply the router's current degradation level before
        # the batch runs (disable speculation / shrink effective max_new)
        bl_fn = getattr(io, "brownout", None)
        if bl_fn is not None and hasattr(backend, "set_brownout"):
            backend.set_brownout(bl_fn())
        # streaming bridge: a backend that accepts an emitter gets partial
        # frames forwarded through the transport (LocalTransport fires the
        # request's callback directly; remote workers ship ("partial", ...)
        # frames the parent dispatches) — tokens stream at the backend's
        # sync cadence instead of quantizing to whole-request acks
        emit_fn = getattr(io, "emit", None)
        if emit_fn is not None and hasattr(backend, "bind_emitter"):
            backend.bind_emitter(
                lambda i, frame, _b=batch: emit_fn(_b[i], frame))
        # tracing bridge: rehydrated contexts ride the work items; the
        # batch span parents on the first traced item (one batch serves
        # many requests — sibling items are listed in the tags) and a
        # trace-aware backend gets the per-item contexts for its own spans
        ctx_fn = getattr(io, "trace_ctx", None)
        ctxs = [ctx_fn(r) for r in batch] if ctx_fn is not None \
            else [None] * len(batch)
        if hasattr(backend, "bind_trace"):
            backend.bind_trace(ctxs)
        bsp = current_tracer().span(
            "replica.batch",
            parent=next((c for c in ctxs if c is not None), None),
            replica=io.rid, n=len(batch))
        t0 = time.monotonic()
        try:
            results = backend.process([io.payload(r) for r in batch])
            if io.crash_requested():
                # crash before acknowledgement: the whole batch spills
                raise ReplicaCrash(f"replica {io.rid}: crashed before ack")
        except BaseException as e:
            bsp.tag(spilled=True, error=repr(e))
            bsp.end()
            current_recorder().record("batch_spill", replica=io.rid,
                                      n=len(batch), error=repr(e))
            io.spill(batch, e)
            return
        bsp.end()
        io.ack(batch, results, time.monotonic() - t0)
    # graceful drain: a backend holding migratable session state (the LM
    # engine's published KV blocks) exports it now — after the last batch,
    # before the drained frame — and the transport publishes it to the
    # parent, where the router ships it to the sessions' new homes
    export = getattr(backend, "export_kv_state", None)
    publish = getattr(io, "publish_kv_state", None)
    if export is not None and publish is not None:
        try:
            state = export()
        except Exception:       # noqa: BLE001 - hand-off is best-effort
            state = None
        if state is not None:
            publish(state)
    io.close()
