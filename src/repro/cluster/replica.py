"""Replica workers: one backend (LM engine, SVM stream runtime, or any
batched step function) owned by one host thread with a bounded inbox.

This is the cluster's unit of scale — the paper's "worker node".  A replica:

  * pulls up to ``max_batch`` requests from its bounded inbox and runs them
    through the backend as one batch (the mapPartitions amortization);
  * reports liveness via a heartbeat timestamp and a busy fraction;
  * on a crash (injected fault or backend exception) *spills* every
    unacknowledged request — the batch that was in flight plus the whole
    inbox — to an ``on_spill`` callback so the router can requeue them on
    survivors.  Semantics are at-least-once (a crash between backend
    completion and acknowledgement reprocesses the batch elsewhere), which
    is the Spark lineage-recomputation contract; zero requests are lost.
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
import queue
import threading
import time
from typing import Any, Callable, List, Optional

from repro.cluster.admission import Rejected
from repro.cluster.metrics import MetricsRegistry, null_registry


class Status(enum.Enum):
    PENDING = "pending"
    OK = "ok"
    REJECTED = "rejected"       # shed by admission control -> Rejected result
    FAILED = "failed"           # retries exhausted / no survivors / shutdown


@dataclasses.dataclass
class ClusterRequest:
    """One end-user request travelling through the cluster."""
    payload: Any
    cost: int = 1                         # load units (e.g. tokens, rows)
    session_key: Optional[str] = None     # affinity key (user/session id)
    deadline_s: float = float("inf")      # absolute time.monotonic deadline
    rid: int = -1
    submitted_s: float = 0.0
    attempts: int = 0
    status: Status = Status.PENDING
    result: Any = None
    error: Optional[BaseException] = None
    replica_rid: Optional[int] = None     # replica that completed it
    done: threading.Event = dataclasses.field(default_factory=threading.Event)
    finished_s: float = 0.0

    def _finish(self, status: Status):
        self.status = status
        self.finished_s = time.monotonic()
        self.done.set()

    def complete(self, result: Any, replica_rid: int):
        self.result = result
        self.replica_rid = replica_rid
        self._finish(Status.OK)

    def reject(self, rejected: Rejected):
        self.result = rejected
        self._finish(Status.REJECTED)

    def fail(self, error: BaseException):
        self.error = error
        self._finish(Status.FAILED)

    @property
    def missed_deadline(self) -> bool:
        return self.done.is_set() and self.finished_s > self.deadline_s

    def wait(self, timeout: Optional[float] = None) -> Any:
        self.done.wait(timeout)
        return self.result


class ReplicaCrash(RuntimeError):
    """Raised inside a worker loop by fault injection."""


# ----------------------------------------------------------------------
# Backends: anything with process(list_of_payloads) -> list_of_results.

class FnBackend:
    """Wrap a batched ``step_fn(payloads) -> results`` (tests, services)."""

    def __init__(self, step_fn: Callable[[List[Any]], List[Any]]):
        self.step_fn = step_fn

    def process(self, payloads: List[Any]) -> List[Any]:
        return self.step_fn(payloads)


class StreamBackend:
    """One SVM two-phase stream runtime per replica.

    Payloads are micro-batches ``(X, keys, ts)``.  ``fetch`` is the ingest
    stage (the paper's HDFS/storage document read + parse) applied per
    micro-batch before device compute; it blocks the host thread, which is
    exactly what overlapping replicas hide.
    """

    def __init__(self, runtime, fetch: Optional[Callable[[Any], Any]] = None):
        self.runtime = runtime
        self.fetch = fetch

    def process(self, payloads: List[Any]) -> List[Any]:
        out = []
        for payload in payloads:
            if self.fetch is not None:
                payload = self.fetch(payload)
            X, keys, ts = payload
            sc, ok = self.runtime.process_microbatch(X, keys, ts)
            out.append((sc, ok))
        return out


class EngineBackend:
    """One continuous-batching LM engine per replica.

    Payloads are ``(prompt_tokens, max_new)``; results are the generated
    token lists.  The whole pulled batch shares the engine's decode slots.
    """

    def __init__(self, engine):
        self.engine = engine

    def process(self, payloads: List[Any]) -> List[Any]:
        reqs = [self.engine.submit(prompt, max_new=max_new)
                for prompt, max_new in payloads]
        self.engine.run_until_drained()
        return [r.out_tokens for r in reqs]


# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ReplicaConfig:
    inbox_capacity: int = 64
    max_batch: int = 8
    poll_s: float = 0.002
    heartbeat_timeout_s: float = 5.0


class ReplicaWorker:
    """One backend on one thread with a bounded inbox and health reporting."""

    _ids = itertools.count()

    def __init__(self, backend, cfg: ReplicaConfig = ReplicaConfig(),
                 rid: Optional[int] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 on_spill: Optional[Callable[[List[ClusterRequest], "ReplicaWorker"], None]] = None):
        self.rid = next(self._ids) if rid is None else rid
        self.backend = backend
        self.cfg = cfg
        self.metrics = metrics if metrics is not None else null_registry()
        self.on_spill = on_spill
        self.inbox: "queue.Queue[ClusterRequest]" = \
            queue.Queue(maxsize=cfg.inbox_capacity)
        self._lock = threading.Lock()
        self._outstanding_cost = 0
        self._in_flight: List[ClusterRequest] = []
        self._crash = threading.Event()
        self._closing = threading.Event()
        self.alive = False
        self.heartbeat_s = 0.0
        self.started_s = 0.0
        self.busy_s = 0.0
        self.processed = 0
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name=f"replica-{self.rid}")

    # -------------------------------------------------- control surface
    def start(self) -> "ReplicaWorker":
        self.alive = True
        self.started_s = self.heartbeat_s = time.monotonic()
        self._thread.start()
        return self

    def offer(self, req: ClusterRequest) -> bool:
        """Enqueue; False == backpressure (inbox full / replica down)."""
        if not self.alive or self._closing.is_set():
            return False
        try:
            self.inbox.put_nowait(req)
        except queue.Full:
            return False
        with self._lock:
            self._outstanding_cost += req.cost
        if not self.alive:
            # Raced with a concurrent crash: the dying thread may already
            # have drained the inbox, so reclaim whatever is left ourselves
            # and report failure — the caller re-dispatches elsewhere.
            leftovers: List[ClusterRequest] = []
            while True:
                try:
                    leftovers.append(self.inbox.get_nowait())
                except queue.Empty:
                    break
            with self._lock:
                self._outstanding_cost -= sum(r.cost for r in leftovers)
            others = [r for r in leftovers if r is not req]
            if others and self.on_spill is not None:
                self.on_spill(others, self)
            return False
        return True

    def outstanding_cost(self) -> int:
        with self._lock:
            return self._outstanding_cost

    def healthy(self, now: Optional[float] = None) -> bool:
        now = time.monotonic() if now is None else now
        return self.alive and \
            now - self.heartbeat_s < self.cfg.heartbeat_timeout_s

    def busy_fraction(self) -> float:
        wall = time.monotonic() - self.started_s
        return self.busy_s / wall if wall > 0 else 0.0

    def inject_crash(self):
        """Fault injection: the worker dies at its next loop checkpoint and
        spills all unacknowledged requests."""
        self._crash.set()

    def drain(self, timeout: float = 10.0):
        """Graceful: stop accepting, finish the inbox, exit."""
        self._closing.set()
        self._thread.join(timeout)

    def join(self, timeout: float = 10.0):
        self._thread.join(timeout)

    # -------------------------------------------------- worker loop
    def _pull_batch(self) -> List[ClusterRequest]:
        batch: List[ClusterRequest] = []
        try:
            batch.append(self.inbox.get(timeout=self.cfg.poll_s))
            while len(batch) < self.cfg.max_batch:
                batch.append(self.inbox.get_nowait())
        except queue.Empty:
            pass
        return batch

    def _loop(self):
        hist = self.metrics.histogram("replica.batch_s")
        while True:
            self.heartbeat_s = time.monotonic()
            if self._crash.is_set():
                self._die(ReplicaCrash(f"replica {self.rid}: injected crash"))
                return
            batch = self._pull_batch()
            if not batch:
                if self._closing.is_set():
                    break
                continue
            with self._lock:
                self._in_flight = batch
            t0 = time.monotonic()
            try:
                results = self.backend.process([r.payload for r in batch])
                if self._crash.is_set():
                    # crash before acknowledgement: the whole batch spills
                    raise ReplicaCrash(
                        f"replica {self.rid}: crashed before ack")
            except BaseException as e:
                self._die(e)
                return
            dt = time.monotonic() - t0
            self.busy_s += dt
            hist.observe(dt)
            done_cost = 0
            for r, res in zip(batch, results):
                r.complete(res, self.rid)
                done_cost += r.cost
                self.processed += 1
            with self._lock:
                self._in_flight = []
                self._outstanding_cost -= done_cost
        # Graceful exit: refuse new offers first, then finish any request
        # that raced into the inbox between the final empty poll and the
        # flip (offer's post-put aliveness re-check closes the rest of the
        # window by reclaiming and re-dispatching).
        self.alive = False
        time.sleep(self.cfg.poll_s)
        stragglers: List[ClusterRequest] = []
        while True:
            try:
                stragglers.append(self.inbox.get_nowait())
            except queue.Empty:
                break
        if stragglers:
            try:
                results = self.backend.process([r.payload for r in stragglers])
                for r, res in zip(stragglers, results):
                    r.complete(res, self.rid)
                    self.processed += 1
            except BaseException as e:
                if self.on_spill is not None:
                    self.on_spill(stragglers, self)
                else:
                    for r in stragglers:
                        r.fail(e)
        with self._lock:
            self._outstanding_cost = 0

    def _die(self, error: BaseException):
        """Crash path: mark dead, spill in-flight + inbox to the router."""
        self.alive = False
        with self._lock:
            spilled = list(self._in_flight)
            self._in_flight = []
        # Two drain passes with a grace gap: an `offer` that read `alive`
        # just before we flipped it may still land a request (offer's own
        # post-put check is the second line of defence).
        for _ in range(2):
            while True:
                try:
                    spilled.append(self.inbox.get_nowait())
                except queue.Empty:
                    break
            time.sleep(0.005)
        with self._lock:
            self._outstanding_cost = 0
        self.metrics.counter("replica.crashes").inc()
        self.metrics.counter("replica.spilled_requests").inc(len(spilled))
        if self.on_spill is not None:
            self.on_spill(spilled, self)
        else:
            for r in spilled:
                r.fail(error)
