"""TwoPhasePipeline — the paper's contribution as a composable JAX module.

Phase 1 (map):   every instance is scored independently by the broadcast
                 models (claim + evidence detectors).          [Listing 1]
Filter:          static-shape compaction of positives (per shard), which is
                 what bounds the phase-2 shuffle.              [§3.1 / §3.2]
Phase 2 (join+map): compacted claims are all-gathered over the data axis
                 (the shuffle), evidence stays local, and every shard scores
                 its (C_total × E_local) pair block — the "parallel step
                 after the aggregation" the paper prescribes.  [Listing 2]

Distribution is ``shard_map`` over the mesh's data axis; the weights enter
replicated (paper's broadcast variable) or tensor-sharded (policy "tp",
the beyond-paper placement from the paper's own Conclusion).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.filtering import Compacted, compact_by_score
from repro.core.sharding import shard_map_compat
from repro.core import joins
from repro.models import svm as svm_mod


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    feat_dim: int = 1024
    claim_capacity: int = 64        # per shard
    evid_capacity: int = 128        # per shard
    threshold: float = 0.0
    svm_gamma: float = 0.1
    svm_coef0: float = 1.0
    svm_degree: int = 2
    link_rank: int = 0              # 0 -> full bilinear
    use_pair_kernel: bool = False   # route phase 2 through kernels/pair_score


class PipelineOut(NamedTuple):
    link_scores: jax.Array   # (C_total, E) pair scores
    pair_valid: jax.Array    # (C_total, E) bool
    claim_index: jax.Array   # (C_total,) original row ids (-1 invalid)
    evid_index: jax.Array    # (E,)
    claim_keys: jax.Array    # (C_total,)
    evid_keys: jax.Array     # (E,)
    n_dropped: jax.Array     # () compaction overflow count


def init_models(key, pcfg: PipelineConfig, n_sv: int = 1024):
    """Claim/evidence SVMs + link model (the paper's three classifiers)."""
    from repro.core.sharding import split_params
    k1, k2, k3 = jax.random.split(key, 3)
    tree = {
        "claim": svm_mod.init_svm(k1, n_sv, pcfg.feat_dim),
        "evidence": svm_mod.init_svm(k2, n_sv, pcfg.feat_dim),
        "link": svm_mod.init_link(k3, pcfg.feat_dim, rank=pcfg.link_rank),
    }
    return split_params(tree)


# ----------------------------------------------------------------------
def _phase1_local(models, X, keys, pcfg: PipelineConfig):
    kw = dict(gamma=pcfg.svm_gamma, coef0=pcfg.svm_coef0, degree=pcfg.svm_degree)
    c_sc = svm_mod.svm_score(models["claim"], X, **kw)
    e_sc = svm_mod.svm_score(models["evidence"], X, **kw)
    claims = compact_by_score(X, c_sc, keys, pcfg.claim_capacity, pcfg.threshold)
    evid = compact_by_score(X, e_sc, keys, pcfg.evid_capacity, pcfg.threshold)
    return claims, evid


def _phase2_local(models, claims: Compacted, evid: Compacted,
                  pcfg: PipelineConfig):
    if pcfg.use_pair_kernel:
        from repro.kernels import ops as kops
        scores = kops.pair_score(models["link"], claims.feats, evid.feats,
                                 interpret=True)
    else:
        scores = svm_mod.link_score_matrix(models["link"], claims.feats,
                                           evid.feats)
    mask = joins.pair_mask_batch(claims, evid)
    return scores, mask


def batch_step_local(models, X, keys, pcfg: PipelineConfig) -> PipelineOut:
    """Single-shard reference (also the shard-local body)."""
    claims, evid = _phase1_local(models, X, keys, pcfg)
    scores, mask = _phase2_local(models, claims, evid, pcfg)
    return PipelineOut(scores, mask, claims.index, evid.index,
                       claims.keys, evid.keys,
                       claims.n_dropped + evid.n_dropped)


def make_batch_step(pcfg: PipelineConfig, mesh: Optional[Mesh] = None,
                    data_axis: str = "data"):
    """Returns jitted ``step(models, X, keys) -> PipelineOut``.

    With a mesh: X/keys sharded over `data_axis`; claims all-gathered
    (the shuffle); output pair block is (C_total, E_local) per shard.
    """
    if mesh is None:
        @jax.jit
        def step(models, X, keys):
            # offset local indices trivially (single shard)
            return batch_step_local(models, X, keys, pcfg)
        return step

    nshards = mesh.shape[data_axis]

    def body(models, X, keys):
        claims, evid = _phase1_local(models, X, keys, pcfg)
        # global row ids: offset by shard start
        idx = jax.lax.axis_index(data_axis)
        offset = idx * X.shape[0]
        claims = claims._replace(index=jnp.where(claims.valid,
                                                 claims.index + offset, -1))
        evid = evid._replace(index=jnp.where(evid.valid,
                                             evid.index + offset, -1))
        # THE SHUFFLE: gather only the compacted claims (paper §3.1)
        gather = lambda a: jax.lax.all_gather(a, data_axis, tiled=True)
        claims_all = Compacted(*(gather(l) for l in claims[:5]),
                               n_dropped=jax.lax.psum(claims.n_dropped, data_axis))
        scores, mask = _phase2_local(models, claims_all, evid, pcfg)
        n_drop = claims_all.n_dropped + jax.lax.psum(evid.n_dropped, data_axis)
        return PipelineOut(scores, mask, claims_all.index, evid.index,
                           claims_all.keys, evid.keys, n_drop)

    dspec = P(data_axis)
    out_specs = PipelineOut(
        link_scores=P(None, data_axis), pair_valid=P(None, data_axis),
        claim_index=P(), evid_index=P(data_axis),
        claim_keys=P(), evid_keys=P(data_axis), n_dropped=P())
    fn = shard_map_compat(body, mesh=mesh, in_specs=(P(), dspec, dspec),
                          out_specs=out_specs)
    return jax.jit(fn)


# ----------------------------------------------------------------------
def extract_links(out: PipelineOut, threshold: float = 0.0):
    """Host-side: positive, valid (claim_row, evidence_row, score) triples."""
    import numpy as np
    sc = np.asarray(out.link_scores)
    ok = np.asarray(out.pair_valid) & (sc > threshold)
    ci, ei = np.nonzero(ok)
    return [(int(out.claim_index[c]), int(out.evid_index[e]), float(sc[c, e]))
            for c, e in zip(ci, ei)]
