"""Weight placement: the paper's broadcast variable, and beyond.

``place_params`` ships a parameter tree onto the mesh under a policy:

  broadcast — replicate on every chip (the paper's §3.1 solution: the model
              is immutable during prediction, send it once).
  tp        — shard ff/heads/vocab/experts over the `model` axis (the
              paper Conclusion's "portion of the trained model per node").
  fsdp_tp   — tp + ZeRO-3 parameter sharding over data axes (training).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.core.sharding import ShardingCtx, _rules, use_sharding, param_shardings


def placement_shardings(axes_tree, mesh: Mesh, policy: str):
    ctx = ShardingCtx(mesh, policy, _rules(policy, mesh.axis_names))
    return param_shardings(axes_tree, ctx)


def place_params(params, axes_tree, mesh: Mesh, policy: str = "broadcast"):
    """device_put the tree under the policy; returns (placed, shardings)."""
    sh = placement_shardings(axes_tree, mesh, policy)
    placed = jax.device_put(params, sh)
    return placed, sh


def broadcast_bytes(params) -> int:
    """Bytes a pure-broadcast placement ships to EVERY chip (cost of the
    paper's placement — reported in EXPERIMENTS.md)."""
    return int(sum(np.prod(p.shape) * p.dtype.itemsize
                   for p in jax.tree_util.tree_leaves(params)))


def per_chip_bytes(params, shardings) -> int:
    """Bytes per chip under a sharded placement."""
    total = 0
    for p, s in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(shardings, is_leaf=lambda x: isinstance(x, NamedSharding))):
        n_shards = np.prod([s.mesh.shape[a] for spec_part in s.spec
                            for a in ((spec_part,) if isinstance(spec_part, str)
                                      else (spec_part or ()))]) or 1
        total += int(np.prod(p.shape) * p.dtype.itemsize / n_shards)
    return total
