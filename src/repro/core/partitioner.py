"""mapPartitions analogue (paper §3.1 challenge #3, §3.2 trade-off).

On Spark the knob is partition size: model loading is paid once per
partition, but oversized partitions lose parallelism.  On TPU the per-call
cost is dispatch + weight streaming from HBM, amortized by micro-batch size;
oversized micro-batches lose latency and (for streams) fall behind the
period.  The autotuner measures the step at a few sizes, fits the linear
cost model  t(m) = overhead + per_item * m,  and picks the smallest size
whose efficiency (per-item share of the call) exceeds a target while meeting
a latency budget — the quantitative form of the paper's recommendation.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class CostModel:
    overhead_s: float        # fixed per-call cost ("model load")
    per_item_s: float        # marginal per-instance cost
    r2: float                # fit quality

    def time(self, m: int) -> float:
        return self.overhead_s + self.per_item_s * m

    def efficiency(self, m: int) -> float:
        t = self.time(m)
        return (self.per_item_s * m) / t if t > 0 else 0.0

    def throughput(self, m: int) -> float:
        return m / self.time(m)


def fit_cost_model(sizes: Sequence[int], times: Sequence[float]) -> CostModel:
    x = np.asarray(sizes, np.float64)
    y = np.asarray(times, np.float64)
    A = np.stack([np.ones_like(x), x], axis=1)
    (b, c), *_ = np.linalg.lstsq(A, y, rcond=None)
    pred = A @ np.array([b, c])
    ss_res = float(np.sum((y - pred) ** 2))
    ss_tot = float(np.sum((y - y.mean()) ** 2)) or 1.0
    return CostModel(max(b, 0.0), max(c, 1e-12), 1.0 - ss_res / ss_tot)


def measure_step(step_fn: Callable[[int], None], sizes: Sequence[int],
                 warmup: int = 1, repeats: int = 3) -> CostModel:
    """step_fn(m) runs (and blocks on) one call with micro-batch size m."""
    times: List[float] = []
    for m in sizes:
        for _ in range(warmup):
            step_fn(m)
        t0 = time.perf_counter()
        for _ in range(repeats):
            step_fn(m)
        times.append((time.perf_counter() - t0) / repeats)
    return fit_cost_model(sizes, times)


def choose_partition_size(model: CostModel, *, latency_budget_s: float,
                          target_efficiency: float = 0.8,
                          max_size: int = 1 << 16) -> int:
    """Smallest m with efficiency >= target, subject to t(m) <= budget;
    falls back to the largest m inside the budget."""
    m = 1
    while m <= max_size:
        if model.efficiency(m) >= target_efficiency and \
                model.time(m) <= latency_budget_s:
            return m
        m *= 2
    # budget-bound fallback
    m_budget = int((latency_budget_s - model.overhead_s) / model.per_item_s)
    return max(1, min(m_budget, max_size))
