"""Static-shape filtering: the TPU translation of the paper's
``.filter(score > 0)`` (Listing 1, lines 30-31).

XLA needs static shapes, so "keep instances with positive score" becomes
"compact the top-`capacity` instances by score into a fixed buffer + validity
mask".  Exactness is preserved whenever the number of true positives fits the
capacity; overflows drop the *lowest-scoring* positives and are counted so
callers can observe saturation (tests assert zero drops at the calibrated
capacity).  This is also the paper's §3.1 bottleneck fix: the compacted
buffer — not the full input — is what the phase-2 join shuffles.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Compacted(NamedTuple):
    feats: jax.Array      # (capacity, d)   compacted feature rows
    scores: jax.Array     # (capacity,)
    keys: jax.Array       # (capacity,)     join key (doc id / window slot)
    index: jax.Array      # (capacity,)     original row index (host decode)
    valid: jax.Array      # (capacity,)     bool
    n_dropped: jax.Array  # ()              positives that didn't fit


def compact_by_score(feats, scores, keys, capacity: int,
                     threshold: float = 0.0) -> Compacted:
    """Select rows with score > threshold, densely packed, fixed capacity."""
    n = scores.shape[0]
    pos = scores > threshold
    # order: positives first (by score desc), then the rest
    sort_key = jnp.where(pos, scores, -jnp.inf)
    order = jnp.argsort(-sort_key)
    take = order[:capacity]
    valid = pos[take]
    n_pos = jnp.sum(pos.astype(jnp.int32))
    return Compacted(
        feats=jnp.where(valid[:, None], feats[take], 0.0),
        scores=jnp.where(valid, scores[take], 0.0),
        keys=jnp.where(valid, keys[take], -1),
        index=jnp.where(valid, take, -1),
        valid=valid,
        n_dropped=jnp.maximum(n_pos - capacity, 0),
    )


def concat_compacted(a: Compacted, b: Compacted) -> Compacted:
    return Compacted(*[jnp.concatenate([x, y], axis=0) for x, y in
                       list(zip(a, b))[:5]],
                     n_dropped=a.n_dropped + b.n_dropped)
