"""Process-wide tracing flags.

COST_MODE: set by the dry-run's cost pass.  XLA's cost_analysis counts a
while/scan body ONCE regardless of trip count (validated empirically), so
for cost extraction the dry-run lowers depth-reduced configs with every
inner scan (flash kv loop, SSM/RG-LRU chunk loops) python-unrolled and with
coarser chunk sizes (kernel-realistic block granularity) to keep HLO size
manageable.  The memory/compile pass runs with the production scan config.
"""
COST_MODE = False
