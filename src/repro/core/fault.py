"""Fault tolerance & elasticity (paper §3: "autonomous fault-tolerant
mechanisms and run-time infrastructure scaling").

Spark gets these from RDD lineage + speculative execution + dynamic
allocation.  The TPU-native equivalents implemented here:

  * speculative_map — straggler mitigation: partitions whose latency exceeds
    `straggler_factor` x the running median are speculatively re-dispatched;
    first completion wins (Spark's `spark.speculation`).  Worker failures
    (exceptions) are retried on other workers up to `max_retries`.
  * ReplayLog — deterministic micro-batch replay: each processed micro-batch
    id (+ rng seed + input offset) is appended to a jsonl log; after a crash
    the runtime restores the last checkpoint and replays from the recorded
    offset (lineage re-execution, bounded by checkpoint frequency).
  * ElasticRunner — elastic scaling: re-place params (and jitted steps) on a
    new mesh when nodes join/leave; numerics are mesh-invariant (tested).
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor, wait
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax


# ----------------------------------------------------------------------
@dataclasses.dataclass
class SpecStats:
    launched: int = 0
    speculated: int = 0
    retried_failures: int = 0
    wasted_completions: int = 0


def speculative_map(fn: Callable[[Any], Any], partitions: Sequence[Any],
                    n_workers: int, *, straggler_factor: float = 3.0,
                    min_median_s: float = 1e-4, max_retries: int = 2,
                    poll_s: float = 0.005) -> tuple[List[Any], SpecStats]:
    """Run fn over partitions on a worker pool with straggler re-dispatch
    and failure retry.  Returns (results in order, stats)."""
    stats = SpecStats()
    results: List[Any] = [None] * len(partitions)
    done = [False] * len(partitions)
    attempts: Dict[int, int] = {i: 0 for i in range(len(partitions))}
    durations: List[float] = []
    lock = threading.Lock()

    def run_one(i):
        t0 = time.perf_counter()
        out = fn(partitions[i])
        return i, out, time.perf_counter() - t0

    with ThreadPoolExecutor(max_workers=n_workers) as ex:
        futures: Dict[Future, tuple[int, float]] = {}

        def launch(i):
            attempts[i] += 1
            stats.launched += 1
            futures[ex.submit(run_one, i)] = (i, time.perf_counter())

        for i in range(len(partitions)):
            launch(i)

        while futures:
            finished, _ = wait(list(futures), timeout=poll_s,
                               return_when=FIRST_COMPLETED)
            for f in finished:
                i, t_start = futures.pop(f)
                try:
                    idx, out, dur = f.result()
                except Exception:
                    stats.retried_failures += 1
                    if attempts[i] <= max_retries:
                        launch(i)
                    else:
                        raise
                    continue
                with lock:
                    durations.append(dur)
                    if done[idx]:
                        stats.wasted_completions += 1
                    else:
                        results[idx] = out
                        done[idx] = True
            # speculate on stragglers
            if durations:
                med = sorted(durations)[len(durations) // 2]
                cutoff = max(med * straggler_factor, min_median_s)
                now = time.perf_counter()
                inflight = {i for (i, _) in futures.values()}
                for f, (i, t_start) in list(futures.items()):
                    if not done[i] and now - t_start > cutoff and \
                            list(inflight).count(i) < 2 and attempts[i] <= max_retries:
                        stats.speculated += 1
                        launch(i)
    return results, stats


# ----------------------------------------------------------------------
class ReplayLog:
    """Append-only jsonl of processed micro-batches for crash replay."""

    def __init__(self, path: str):
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)

    def record(self, mb_id: int, offset: int, seed: int = 0, **extra):
        entry = {"mb_id": mb_id, "offset": offset, "seed": seed,
                 "t": time.time(), **extra}
        with open(self.path, "a") as f:
            f.write(json.dumps(entry) + "\n")
            f.flush()
            os.fsync(f.fileno())

    def entries(self) -> List[dict]:
        if not os.path.exists(self.path):
            return []
        with open(self.path) as f:
            return [json.loads(l) for l in f if l.strip()]

    def resume_point(self, checkpoint_mb: int) -> Optional[dict]:
        """First entry after the last checkpoint — where replay starts."""
        for e in self.entries():
            if e["mb_id"] > checkpoint_mb:
                return e
        return None


# ----------------------------------------------------------------------
class ElasticRunner:
    """Holds (params, mesh, policy); re-places weights when the mesh is
    rescaled (node loss / scale-up) and invalidates jitted steps."""

    def __init__(self, params, axes_tree, mesh, policy: str = "broadcast"):
        from repro.core.broadcast import place_params
        self.axes_tree = axes_tree
        self.policy = policy
        self.mesh = mesh
        self.params, self.shardings = place_params(params, axes_tree, mesh, policy)
        self.generation = 0

    def rescale(self, new_mesh):
        """Elastic re-mesh: pull weights to host view and re-shard."""
        from repro.core.broadcast import place_params
        host = jax.device_get(self.params)
        self.mesh = new_mesh
        self.params, self.shardings = place_params(host, self.axes_tree,
                                                   new_mesh, self.policy)
        self.generation += 1
        return self.params
