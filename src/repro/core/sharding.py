"""Logical-axis sharding: the TPU translation of the paper's "broadcast
variable" (§3.1) and of its Conclusion's "give each node a portion of the
trained model".

Every parameter is initialized together with a tuple of *logical* axis names
(``"embed"``, ``"ff"``, ``"heads"``, ``"experts"``, ...).  A
:class:`ShardingPolicy` maps logical names to physical mesh axes:

  * ``broadcast`` — the paper-faithful placement: weights fully replicated on
    every chip (Spark broadcast variable), data sharded over all data axes.
  * ``tp``        — tensor-parallel serving: ff/heads/vocab/experts split over
    the ``model`` axis, replicated over ``data`` (beyond-paper).
  * ``fsdp_tp``   — training placement: tp + parameter/optimizer state sharded
    over the ``data`` (and ``pod``) axes, ZeRO-3 style (beyond-paper).

Models call :func:`shard` on activations at strategic points; between those
constraints GSPMD propagates shardings and inserts collectives.
"""
from __future__ import annotations

import contextlib
import threading
from typing import NamedTuple, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class Param:
    """A parameter leaf paired with its logical axes (init-time only).

    Registered as a pytree node with ``axes`` as static aux data, so Param
    trees flow through eval_shape / tree_map / jit with only the array value
    as a traced leaf.
    """
    __slots__ = ("value", "axes")

    def __init__(self, value, axes: Tuple[Optional[str], ...]):
        self.value = value
        self.axes = tuple(axes)

    def __repr__(self):
        return f"Param({getattr(self.value, 'shape', self.value)}, {self.axes})"


jax.tree_util.register_pytree_node(
    Param,
    lambda p: ((p.value,), p.axes),
    lambda axes, ch: Param(ch[0], axes),
)


def param_leaf(x) -> bool:
    return isinstance(x, Param)


def shard_map_compat(f, *, mesh, in_specs, out_specs):
    """``shard_map`` across jax versions: import location moved and the
    replication-check kwarg was renamed (``check_rep`` -> ``check_vma``)."""
    try:
        from jax import shard_map as _sm
    except ImportError:                                # older jax
        from jax.experimental.shard_map import shard_map as _sm
    try:
        return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_vma=False)
    except TypeError:                                  # pre-rename jax
        return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=False)


def split_params(tree):
    """Split a tree of :class:`Param` into (values, logical_axes) trees."""
    values = jax.tree_util.tree_map(lambda p: p.value, tree, is_leaf=param_leaf)
    axes = jax.tree_util.tree_map(lambda p: p.axes, tree, is_leaf=param_leaf)
    return values, axes


# ----------------------------------------------------------------------
# Policies: logical axis -> mesh axis (or tuple of mesh axes).

_BATCH_AXES_1POD = ("data",)
_BATCH_AXES_2POD = ("pod", "data")


def _rules(policy: str, mesh_axes: Tuple[str, ...]):
    data_axes = tuple(a for a in ("pod", "data") if a in mesh_axes)
    model = "model" if "model" in mesh_axes else None
    if policy == "broadcast":          # paper-faithful: full replication,
        # instances data-parallel over EVERY chip (the Spark worker pool)
        return {"batch": data_axes + ((model,) if model else ())}
    if policy == "tp":                 # serving: shard the model, replicate over data
        return {
            "batch": data_axes,
            "ff": model, "heads": model, "vocab": model,
            "experts": model, "inner": model, "lru": model,
            # kv heads replicated: they rarely divide the model axis and the
            # K/V activations are small; q heads carry the TP split
        }
    if policy == "fsdp_tp":            # training: tp + ZeRO-3 over data axes
        return {
            "batch": data_axes,
            "ff": model, "heads": model, "vocab": model,
            "experts": model, "inner": model, "lru": model,
            "embed": data_axes,        # fully-sharded params/opt state
        }
    if policy == "seqtp":              # context-parallel serving: weights
        # replicated (paper's broadcast), the SEQUENCE dim takes the model
        # axis — per-layer activation all-reduces disappear; only attention
        # exchanges K/V (beyond-paper; see EXPERIMENTS.md §Perf)
        return {"batch": data_axes, "seq": model}
    raise ValueError(f"unknown policy {policy!r}")


class ShardingCtx(NamedTuple):
    mesh: Mesh
    policy: str
    rules: dict

    def spec_for(self, logical_axes: Tuple[Optional[str], ...]) -> P:
        parts, used = [], set()
        for ax in logical_axes:
            m = self.rules.get(ax)
            if m is None:
                parts.append(None)
                continue
            ms = (m,) if isinstance(m, str) else tuple(m)
            ms = tuple(a for a in ms if a not in used)
            used.update(ms)
            parts.append(ms[0] if len(ms) == 1 else (ms if ms else None))
            if not ms:
                parts[-1] = None
        return P(*parts)

    def sharding_for(self, logical_axes) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_for(logical_axes))


_local = threading.local()


def current_ctx() -> Optional[ShardingCtx]:
    return getattr(_local, "ctx", None)


@contextlib.contextmanager
def use_sharding(mesh: Optional[Mesh], policy: str = "broadcast", rules=None):
    prev = current_ctx()
    if mesh is None:
        _local.ctx = None
    else:
        _local.ctx = ShardingCtx(
            mesh, policy,
            rules if rules is not None else _rules(policy, mesh.axis_names))
    try:
        yield _local.ctx
    finally:
        _local.ctx = prev


def shard(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Constrain an activation's sharding; no-op outside a sharding context."""
    ctx = current_ctx()
    if ctx is None:
        return x
    if len(logical_axes) != x.ndim:
        raise ValueError(f"axes {logical_axes} vs rank {x.ndim}")
    return jax.lax.with_sharding_constraint(x, ctx.sharding_for(logical_axes))


def param_shardings(axes_tree, ctx: Optional[ShardingCtx] = None):
    """Tree of NamedShardings for a logical-axes tree (init/checkpoint use)."""
    ctx = ctx or current_ctx()
    if ctx is None:
        return None
    is_axes = lambda t: isinstance(t, tuple) and all(a is None or isinstance(a, str) for a in t)
    return jax.tree_util.tree_map(lambda ax: ctx.sharding_for(ax), axes_tree, is_leaf=is_axes)


def batch_spec(ctx: Optional[ShardingCtx], extra_dims: int = 1) -> P:
    """PartitionSpec for (batch, ...) activations/inputs."""
    if ctx is None:
        return P()
    m = ctx.rules.get("batch") or ()
    first = m if len(m) > 1 else (m[0] if m else None)
    return P(first, *([None] * extra_dims))
