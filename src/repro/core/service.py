"""MLaaS service front (the paper's "service offered to a wide public"):
a thread-safe request queue with deadline-aware batching in front of any
step function — the piece between end-users and the two-phase pipeline /
serving engine.

Batching policy = the mapPartitions trade-off, live: requests are grouped
until either the batch capacity is reached or the oldest request's slack
(deadline - now - estimated_step_time) runs out, using the partitioner's
fitted cost model to estimate step time per batch size.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable, List, Optional

from repro.core.partitioner import CostModel


@dataclasses.dataclass
class ServiceRequest:
    payload: Any
    deadline_s: float                  # absolute time.monotonic deadline
    submitted_s: float = 0.0
    done = None                        # threading.Event
    result: Any = None
    missed_deadline: bool = False


class MLaaSService:
    """Front a batched `step_fn(list_of_payloads) -> list_of_results`."""

    def __init__(self, step_fn: Callable[[List[Any]], List[Any]],
                 capacity: int, cost_model: Optional[CostModel] = None,
                 poll_s: float = 0.002):
        self.step_fn = step_fn
        self.capacity = capacity
        self.cost_model = cost_model
        self.poll_s = poll_s
        self.q: "queue.Queue[ServiceRequest]" = queue.Queue()
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self.stats = {"batches": 0, "requests": 0, "missed": 0,
                      "sum_batch": 0}

    def start(self):
        self._thread.start()
        return self

    def stop(self):
        self._stop.set()
        self._thread.join(timeout=10)

    # ------------------------------------------------------------------
    def submit(self, payload, timeout_s: float = 10.0) -> ServiceRequest:
        req = ServiceRequest(payload, deadline_s=time.monotonic() + timeout_s,
                             submitted_s=time.monotonic())
        req.done = threading.Event()
        self.q.put(req)
        return req

    def _estimate(self, m: int) -> float:
        return self.cost_model.time(m) if self.cost_model else 0.0

    def _loop(self):
        pending: List[ServiceRequest] = []
        while not self._stop.is_set():
            # drain the queue
            try:
                while len(pending) < self.capacity:
                    pending.append(self.q.get(timeout=self.poll_s))
            except queue.Empty:
                pass
            if not pending:
                continue
            now = time.monotonic()
            full = len(pending) >= self.capacity
            oldest_slack = min(r.deadline_s for r in pending) - now \
                - self._estimate(len(pending))
            if full or oldest_slack <= self.poll_s * 2:
                batch, pending = pending[:self.capacity], pending[self.capacity:]
                results = self.step_fn([r.payload for r in batch])
                t_done = time.monotonic()
                self.stats["batches"] += 1
                self.stats["requests"] += len(batch)
                self.stats["sum_batch"] += len(batch)
                for r, res in zip(batch, results):
                    r.result = res
                    r.missed_deadline = t_done > r.deadline_s
                    self.stats["missed"] += int(r.missed_deadline)
                    r.done.set()

    # ------------------------------------------------------------------
    def mean_batch(self) -> float:
        b = self.stats["batches"]
        return self.stats["sum_batch"] / b if b else 0.0
