"""MLaaS service front (the paper's "service offered to a wide public"):
a thread-safe request queue with deadline-aware batching in front of either

  * a local batched ``step_fn(list_of_payloads) -> list_of_results``
    (single-replica: the two-phase pipeline or one serving engine), or
  * a :class:`repro.cluster.Router`, which fans the batch out over a pool of
    replica workers (multi-replica cluster).

Batching policy = the mapPartitions trade-off, live: requests are grouped
until either the batch capacity is reached or the oldest request's slack
(deadline - now - estimated_step_time) runs out, using the partitioner's
fitted cost model to estimate step time per batch size.  The slack test
itself lives in ``repro.cluster.admission.deadline_slack`` and is shared
with the cluster's admission controller.

Shutdown contract: ``stop()`` never abandons requests.  By default it
*flushes* — everything already queued is processed before the loop exits;
with ``drain=False`` waiting requests complete immediately with an explicit
``Rejected("shutdown")`` result.  Either way, no caller blocks forever on
``req.done.wait()``.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable, List, Optional

from repro.cluster.admission import Rejected, deadline_slack
from repro.cluster.metrics import MetricsRegistry
from repro.core.partitioner import CostModel


@dataclasses.dataclass
class ServiceRequest:
    payload: Any
    deadline_s: float                  # absolute time.monotonic deadline
    submitted_s: float = 0.0
    done: threading.Event = dataclasses.field(default_factory=threading.Event)
    result: Any = None
    missed_deadline: bool = False

    @property
    def rejected(self) -> bool:
        return isinstance(self.result, Rejected)


class MLaaSService:
    """Deadline-batching front over a local step_fn or a cluster Router."""

    #: longest single block on the inbox queue: bounds both how stale the
    #: deadline-slack estimate can get while waiting and how long stop()
    #: can trail behind its wakeup sentinel
    IDLE_WAIT_CAP_S = 0.25

    def __init__(self, step_fn: Optional[Callable[[List[Any]], List[Any]]] = None,
                 capacity: int = 8, cost_model: Optional[CostModel] = None,
                 poll_s: float = 0.002, router=None,
                 metrics: Optional[MetricsRegistry] = None):
        if (step_fn is None) == (router is None):
            raise ValueError("provide exactly one of step_fn / router")
        self.router = router
        self.step_fn = step_fn if step_fn is not None else router.as_step_fn()
        self.capacity = capacity
        self.cost_model = cost_model
        self.poll_s = poll_s
        self.q: "queue.Queue[ServiceRequest]" = queue.Queue()
        self._stop = threading.Event()
        self._accept_lock = threading.Lock()   # submit vs shutdown-drain
        self._closed = False                   # loop has begun final drain
        self._drain_on_stop = True
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._c_batches = self.metrics.counter("service.batches")
        self._c_requests = self.metrics.counter("service.requests")
        self._c_missed = self.metrics.counter("service.missed")
        self._c_sum_batch = self.metrics.counter("service.sum_batch")
        self._h_latency = self.metrics.histogram("service.latency_s")

    def start(self):
        self._thread.start()
        return self

    def stop(self, drain: bool = True, timeout_s: float = 10.0):
        """Shut down without stranding requests: flush the backlog
        (``drain=True``) or fail it fast with ``Rejected("shutdown")``."""
        self._drain_on_stop = drain
        self._stop.set()
        self.q.put(None)                   # sentinel: wake a blocked q.get
        self._thread.join(timeout=timeout_s)

    # ------------------------------------------------------------------
    def submit(self, payload, timeout_s: float = 10.0) -> ServiceRequest:
        req = ServiceRequest(payload, deadline_s=time.monotonic() + timeout_s,
                             submitted_s=time.monotonic())
        # The lock makes check+enqueue atomic w.r.t. the loop's final drain:
        # once `_closed` is observed, no request can slip in behind the
        # drain and block its caller forever.
        with self._accept_lock:
            if self._closed or self._stop.is_set():   # fail-fast after stop()
                req.result = Rejected("shutdown", "service stopped")
                req.done.set()
                return req
            self.q.put(req)
        self.metrics.gauge("service.queue_depth").set(self.q.qsize())
        return req

    def _estimate(self, m: int) -> float:
        return self.cost_model.time(m) if self.cost_model else 0.0

    # ------------------------------------------------------------------
    def _run_batch(self, batch: List[ServiceRequest]):
        try:
            results = self.step_fn([r.payload for r in batch])
        except Exception as e:
            # a backend failure must not kill the loop (stranding every
            # later request) nor strand this batch: fail it explicitly
            self.metrics.counter("service.step_errors").inc()
            err = Rejected("step_error", repr(e))
            for r in batch:
                r.result = err
                r.done.set()
            return
        t_done = time.monotonic()
        self._c_batches.inc()
        self._c_requests.inc(len(batch))
        self._c_sum_batch.inc(len(batch))
        for r, res in zip(batch, results):
            r.result = res
            r.missed_deadline = t_done > r.deadline_s
            self._c_missed.inc(int(r.missed_deadline))
            self._h_latency.observe(t_done - r.submitted_s)
            r.done.set()

    def _wait_timeout(self, pending: List[ServiceRequest]) -> float:
        """How long the loop may block on the inbox before it must act.

        Idle (nothing pending): nothing can become urgent except via the
        queue itself, so block up to the cap instead of spinning at
        ``poll_s`` — idle CPU burn drops from ~1/poll_s wakeups/s to
        ~1/IDLE_WAIT_CAP_S.  With pending requests: sleep exactly the
        oldest request's deadline slack (minus the estimated step time),
        clamped to [poll_s, cap] — a new arrival interrupts the wait via
        ``q.get`` either way."""
        if not pending:
            return self.IDLE_WAIT_CAP_S
        slack = deadline_slack(min(r.deadline_s for r in pending),
                               time.monotonic(),
                               self._estimate(len(pending)))
        # wake 2*poll_s ahead of the slack expiry (the dispatch threshold
        # below): sleeping the full slack would dispatch *at* the deadline
        # minus the step estimate, turning any get() overshoot into a miss
        return min(max(slack - 2 * self.poll_s, self.poll_s),
                   self.IDLE_WAIT_CAP_S)

    def _loop(self):
        pending: List[ServiceRequest] = []
        while not self._stop.is_set():
            # drain the queue: one deadline-aware blocking get, then a
            # non-blocking sweep (None = the stop() wakeup sentinel)
            self.metrics.counter("service.loop_wakeups").inc()
            try:
                got = self.q.get(timeout=self._wait_timeout(pending))
                if got is not None:
                    pending.append(got)
                while len(pending) < self.capacity:
                    got = self.q.get_nowait()
                    if got is not None:
                        pending.append(got)
            except queue.Empty:
                pass
            if not pending:
                continue
            now = time.monotonic()
            full = len(pending) >= self.capacity
            oldest_slack = deadline_slack(min(r.deadline_s for r in pending),
                                          now, self._estimate(len(pending)))
            if full or oldest_slack <= self.poll_s * 2:
                batch, pending = pending[:self.capacity], pending[self.capacity:]
                self._run_batch(batch)
        # ---- shutdown: nothing may be left behind -----------------------
        with self._accept_lock:
            self._closed = True            # later submits fail fast
            try:
                while True:
                    got = self.q.get_nowait()
                    if got is not None:    # drop wakeup sentinels
                        pending.append(got)
            except queue.Empty:
                pass
        if self._drain_on_stop:
            while pending:
                batch, pending = pending[:self.capacity], pending[self.capacity:]
                self._run_batch(batch)
        else:
            shutdown = Rejected("shutdown", "service stopped before dispatch")
            for r in pending:
                r.result = shutdown
                r.done.set()

    # ------------------------------------------------------------------
    @property
    def stats(self) -> dict:
        """Legacy counter view (kept for existing callers/tests)."""
        return {"batches": self._c_batches.value,
                "requests": self._c_requests.value,
                "missed": self._c_missed.value,
                "sum_batch": self._c_sum_batch.value}

    def mean_batch(self) -> float:
        b = self._c_batches.value
        return self._c_sum_batch.value / b if b else 0.0
