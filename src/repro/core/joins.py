"""Phase-2 join semantics (paper §5.1 batch join, §5.2 stream scopes).

All joins produce a *pair grid*: claims (C, d) × evidence (E, d) with a
validity mask — the static-shape form of the paper's per-key Cartesian
product.  Three scopes:

  scope-batch   pairs valid iff same document key        (Listing 2 `join`)
  scope-window  pairs valid iff timestamps within a window   (Listing 3 `window`)
  scope-file    stateful: a growing claim collection per key joined against
                newly arrived evidence               (Listing 3 `updateStateByKey`)
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.filtering import Compacted


def pair_mask_batch(claims: Compacted, evidence: Compacted) -> jax.Array:
    """(C, E) bool — same-key valid pairs."""
    same = claims.keys[:, None] == evidence.keys[None, :]
    return same & claims.valid[:, None] & evidence.valid[None, :]


def pair_mask_window(claim_ts, evid_ts, claims_valid, evid_valid,
                     window: float) -> jax.Array:
    """(C, E) bool — pairs whose arrival timestamps lie within `window`."""
    dt = jnp.abs(claim_ts[:, None] - evid_ts[None, :])
    return (dt <= window) & claims_valid[:, None] & evid_valid[None, :]


# ----------------------------------------------------------------------
class FileScopeState(NamedTuple):
    """Stateful claim collection (paper's updateStateByKey), fixed capacity.

    A ring of the most recent `cap` claims with doc keys; new evidence joins
    against every retained claim with a matching key.
    """
    feats: jax.Array    # (cap, d)
    scores: jax.Array   # (cap,)
    keys: jax.Array     # (cap,)
    valid: jax.Array    # (cap,)
    cursor: jax.Array   # () next write slot


def init_file_scope(cap: int, d: int) -> FileScopeState:
    return FileScopeState(
        feats=jnp.zeros((cap, d), jnp.float32),
        scores=jnp.zeros((cap,), jnp.float32),
        keys=jnp.full((cap,), -1, jnp.int32),
        valid=jnp.zeros((cap,), bool),
        cursor=jnp.zeros((), jnp.int32),
    )


def update_file_scope(state: FileScopeState, new: Compacted) -> FileScopeState:
    """Append newly detected claims into the ring (oldest evicted)."""
    cap = state.feats.shape[0]
    n = new.valid.shape[0]
    slots = (state.cursor + jnp.cumsum(new.valid.astype(jnp.int32)) - 1) % cap
    slots = jnp.where(new.valid, slots, cap)          # invalid -> scatter-drop
    feats = state.feats.at[slots].set(new.feats, mode="drop")
    scores = state.scores.at[slots].set(new.scores, mode="drop")
    keys = state.keys.at[slots].set(new.keys.astype(jnp.int32), mode="drop")
    valid = state.valid.at[slots].set(new.valid, mode="drop")
    cursor = (state.cursor + jnp.sum(new.valid.astype(jnp.int32))) % cap
    return FileScopeState(feats, scores, keys, valid, cursor)


def file_scope_mask(state: FileScopeState, evidence: Compacted) -> jax.Array:
    same = state.keys[:, None] == evidence.keys[None, :].astype(jnp.int32)
    return same & state.valid[:, None] & evidence.valid[None, :]
