"""Micro-batch stream runtime (paper §5.2 / §6.2).

Spark Streaming's micro-batches map directly onto TPU serving: the host
slices the input flow into fixed-capacity micro-batches every `period`
seconds, pads to static shape, and runs one jitted step.  Phase-2 join scope
is either a sliding time window over device ring buffers (Listing 3, lines
17-23) or the stateful per-file claim collection (line 11).

The sustainable-rate finder reproduces the paper's evaluation methodology:
ramp the input rate and report the largest rate for which the micro-batch
processing time stays under the micro-batch period (Fig. 6b).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster.metrics import MetricsRegistry
from repro.core.filtering import Compacted, compact_by_score
from repro.core import joins
from repro.core.pipeline import PipelineConfig, PipelineOut
from repro.models import svm as svm_mod


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    period: float = 1.0             # micro-batch period, seconds
    capacity: int = 256             # max instances per micro-batch
    scope: str = "window"           # "window" | "file"
    window: float = 10.0            # seconds (scope-window)
    ring_capacity: int = 512        # claims/evidence retained on device


class RingState(NamedTuple):
    feats: jax.Array    # (cap, d)
    ts: jax.Array       # (cap,) float32 arrival time
    keys: jax.Array     # (cap,) int32 doc key
    valid: jax.Array    # (cap,) bool
    cursor: jax.Array   # ()


def init_ring(cap: int, d: int) -> RingState:
    return RingState(jnp.zeros((cap, d), jnp.float32),
                     jnp.full((cap,), -jnp.inf, jnp.float32),
                     jnp.full((cap,), -1, jnp.int32),
                     jnp.zeros((cap,), bool),
                     jnp.zeros((), jnp.int32))


def ring_append(state: RingState, feats, ts, keys, valid) -> RingState:
    cap = state.feats.shape[0]
    slots = (state.cursor + jnp.cumsum(valid.astype(jnp.int32)) - 1) % cap
    slots = jnp.where(valid, slots, cap)                 # drop invalid
    return RingState(
        state.feats.at[slots].set(feats, mode="drop"),
        state.ts.at[slots].set(ts, mode="drop"),
        state.keys.at[slots].set(keys.astype(jnp.int32), mode="drop"),
        state.valid.at[slots].set(valid, mode="drop"),
        (state.cursor + jnp.sum(valid.astype(jnp.int32))) % cap,
    )


class StreamState(NamedTuple):
    claims: RingState
    evidence: RingState
    microbatch_id: jax.Array   # () int32 — replay cursor


def init_stream_state(scfg: StreamConfig, pcfg: PipelineConfig) -> StreamState:
    return StreamState(init_ring(scfg.ring_capacity, pcfg.feat_dim),
                       init_ring(scfg.ring_capacity, pcfg.feat_dim),
                       jnp.zeros((), jnp.int32))


# ----------------------------------------------------------------------
def make_stream_step(pcfg: PipelineConfig, scfg: StreamConfig):
    """jitted ``step(models, state, X, keys, ts, valid) -> (state, out)``.

    X: (capacity, d) padded micro-batch; `valid` marks real rows.
    """
    kw = dict(gamma=pcfg.svm_gamma, coef0=pcfg.svm_coef0, degree=pcfg.svm_degree)

    def step(models, state: StreamState, X, keys, ts, valid):
        c_sc = jnp.where(valid, svm_mod.svm_score(models["claim"], X, **kw), -jnp.inf)
        e_sc = jnp.where(valid, svm_mod.svm_score(models["evidence"], X, **kw), -jnp.inf)
        claims = compact_by_score(X, c_sc, keys, pcfg.claim_capacity, pcfg.threshold)
        evid = compact_by_score(X, e_sc, keys, pcfg.evid_capacity, pcfg.threshold)
        c_ts = jnp.where(claims.valid, ts[jnp.clip(claims.index, 0, None)], -jnp.inf)
        e_ts = jnp.where(evid.valid, ts[jnp.clip(evid.index, 0, None)], -jnp.inf)

        new_claims = ring_append(state.claims, claims.feats, c_ts,
                                 claims.keys, claims.valid)
        new_evid = ring_append(state.evidence, evid.feats, e_ts,
                               evid.keys, evid.valid)

        if scfg.scope == "window":
            now = jnp.max(jnp.where(valid, ts, -jnp.inf))
            in_win_c = new_claims.valid & (new_claims.ts > now - scfg.window)
            in_win_e = new_evid.valid & (new_evid.ts > now - scfg.window)
            scores = svm_mod.link_score_matrix(models["link"], new_claims.feats,
                                               new_evid.feats)
            mask = joins.pair_mask_window(new_claims.ts, new_evid.ts,
                                          in_win_c, in_win_e, scfg.window)
        else:  # scope-file: retained claims x NEW evidence only
            scores = svm_mod.link_score_matrix(models["link"], new_claims.feats,
                                               evid.feats)
            mask = ((new_claims.keys[:, None] == evid.keys[None, :].astype(jnp.int32))
                    & new_claims.valid[:, None] & evid.valid[None, :])

        state = StreamState(new_claims, new_evid, state.microbatch_id + 1)
        n_drop = claims.n_dropped + evid.n_dropped
        return state, (scores, mask, n_drop)

    return jax.jit(step)


# ----------------------------------------------------------------------
@dataclasses.dataclass
class MicrobatchStats:
    mb_id: int
    n_in: int
    busy_s: float
    n_links: int


class StreamRuntime:
    """Host driver: slices an instance flow into micro-batches and runs the
    jitted step; tracks per-micro-batch busy time (fall-behind detection)."""

    def __init__(self, models, pcfg: PipelineConfig, scfg: StreamConfig,
                 checkpointer=None, checkpoint_every: int = 0,
                 step_fn=None, metrics: Optional[MetricsRegistry] = None):
        self.models = models
        self.pcfg, self.scfg = pcfg, scfg
        # step_fn lets N cluster replicas share one jitted step (identical
        # pcfg/scfg) instead of paying one XLA compile per replica
        self.step = step_fn if step_fn is not None else \
            make_stream_step(pcfg, scfg)
        self.state = init_stream_state(scfg, pcfg)
        self.stats: List[MicrobatchStats] = []
        self.checkpointer = checkpointer
        self.checkpoint_every = checkpoint_every
        self.metrics = metrics if metrics is not None else MetricsRegistry()

    def process_microbatch(self, X: np.ndarray, keys: np.ndarray,
                           ts: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Process one micro-batch period's worth of input.  Input beyond the
        device capacity is processed in successive chunks within the same
        period (busy time accumulates — this is what makes the runtime
        *fall behind* at excessive rates instead of silently dropping)."""
        cap = self.scfg.capacity
        total = len(X)
        busy = 0.0
        sc = ok = None
        n_links = 0
        for start in range(0, max(total, 1), cap):
            n = min(cap, total - start) if total else 0
            Xp = np.zeros((cap, self.pcfg.feat_dim), np.float32)
            kp = np.full((cap,), -1, np.int32)
            tp = np.full((cap,), -np.inf, np.float32)
            vp = np.zeros((cap,), bool)
            if n:
                sl = slice(start, start + n)
                Xp[:n], kp[:n], tp[:n], vp[:n] = X[sl], keys[sl], ts[sl], True
            t0 = time.perf_counter()
            self.state, (scores, mask, n_drop) = self.step(
                self.models, self.state, jnp.asarray(Xp), jnp.asarray(kp),
                jnp.asarray(tp), jnp.asarray(vp))
            scores.block_until_ready()
            busy += time.perf_counter() - t0
            sc = np.asarray(scores)
            ok = np.asarray(mask) & (sc > 0)
            n_links += int(ok.sum())

        mb_id = int(self.state.microbatch_id)
        self.stats.append(MicrobatchStats(mb_id, total, busy, n_links))
        self.metrics.counter("stream.microbatches").inc()
        self.metrics.counter("stream.instances").inc(total)
        self.metrics.counter("stream.links").inc(n_links)
        self.metrics.histogram("stream.busy_s").observe(busy)
        self.metrics.gauge("stream.falling_behind").set(
            float(self.falling_behind()))
        if self.checkpointer and self.checkpoint_every and \
                mb_id % self.checkpoint_every == 0:
            self.checkpointer.save(mb_id, {"state": self.state})
        return sc, ok

    def falling_behind(self, last_k: int = 3) -> bool:
        recent = self.stats[-last_k:]
        return bool(recent) and all(s.busy_s > self.scfg.period for s in recent)


def find_sustainable_rate(make_runtime: Callable[[], "StreamRuntime"],
                          gen_microbatch: Callable[[int, float], tuple],
                          rates: List[float], mb_per_rate: int = 5) -> float:
    """Paper Fig. 6b methodology: ramp the input rate (instances/sec of
    stream content), return the highest rate that does not fall behind."""
    best = 0.0
    for rate in rates:
        rt = make_runtime()
        n_per_mb = max(1, int(rate * rt.scfg.period))
        for i in range(mb_per_rate):
            X, keys, ts = gen_microbatch(n_per_mb, i * rt.scfg.period)
            rt.process_microbatch(X, keys, ts)
        if rt.falling_behind(last_k=max(1, mb_per_rate - 2)):
            break
        best = rate
    return best
