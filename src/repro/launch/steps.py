"""Step builders + input/cache sharding specs for every (arch x shape) cell.

Used by the dry-run (abstract lowering) and by the real train/serve drivers.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeCase
from repro.core.sharding import ShardingCtx, _rules, use_sharding
from repro.models import api, encdec, transformer as tfm
from repro.optim import adamw_init, adamw_update, clip_by_global_norm, cosine_schedule


# ----------------------------------------------------------------------
# cache logical axes (mirrors models/api.init_caches structures)
def _kv_axes(ring: bool):
    ax = {"k": ("layers", "batch", "seq", "kv_heads", None),
          "v": ("layers", "batch", "seq", "kv_heads", None)}
    if ring:
        ax["pos"] = ("layers", "batch", "seq")
    return ax


def cache_logical_axes(cfg: ArchConfig, max_len: int):
    if cfg.family == "encdec":
        a = ("layers", "batch", "seq", "kv_heads", None)
        return {"self_k": a, "self_v": a, "cross_k": a, "cross_v": a}
    groups = []
    for g in cfg.groups:
        pos_axes = []
        for kind in g.pattern:
            if kind == "S":
                pos_axes.append({"conv": ("layers", "batch", None, "inner"),
                                 "h": ("layers", "batch", "inner", None)})
            elif kind == "R":
                pos_axes.append({"conv": ("layers", "batch", None, "lru"),
                                 "h": ("layers", "batch", "lru")})
            elif kind == "M" and cfg.kv_lora_rank:
                pos_axes.append({"ckv": ("layers", "batch", "seq", None),
                                 "krope": ("layers", "batch", "seq", None)})
            else:
                ring = kind == "L" and cfg.window and cfg.window < max_len
                pos_axes.append(_kv_axes(bool(ring)))
        groups.append(pos_axes)
    return groups


def cache_specs(cfg: ArchConfig, mesh: Mesh, max_len: int, batch: int,
                policy: str, shard_seq: bool = False):
    """NamedShardings for cache trees.

    Batch goes to the data axes (or, when batch < n_data, the seq dim takes
    them — long-context decode).  With ``shard_seq`` the cache SEQ dim is
    additionally split over the ``model`` axis: decode attention then
    contracts over a sharded length and GSPMD exchanges score-sized partials
    instead of all-gathering the multi-GB cache (flash-decode layout)."""
    rules = dict(_rules(policy, mesh.axis_names))
    data_axes = rules.get("batch") or ()
    n_data = int(np.prod([mesh.shape[a] for a in data_axes])) if data_axes else 1
    seq_axes = []
    if batch < n_data:
        rules["batch"] = None
        seq_axes += list(data_axes)
    if shard_seq and "model" in mesh.axis_names:
        seq_axes.append("model")
    rules["seq"] = tuple(seq_axes) or None
    # kv_heads never sharded for caches (seq carries the model axis instead)
    rules["kv_heads"] = None
    ctx = ShardingCtx(mesh, policy, rules)
    axes_tree = cache_logical_axes(cfg, max_len)
    return jax.tree_util.tree_map(
        lambda ax: ctx.sharding_for(ax), axes_tree,
        is_leaf=lambda t: isinstance(t, tuple) and all(
            a is None or isinstance(a, str) for a in t))


def batch_shardings(cfg: ArchConfig, mesh: Mesh, policy: str,
                    specs: Dict[str, Any]):
    rules = _rules(policy, mesh.axis_names)
    data_axes = rules.get("batch") or None
    out = {}
    for k, v in specs.items():
        nd = v.ndim if hasattr(v, "ndim") else len(v.shape)
        out[k] = NamedSharding(mesh, P(data_axes, *([None] * (nd - 1))))
    return out


# ----------------------------------------------------------------------
def make_train_step(cfg: ArchConfig, *, lr: float = 3e-4, warmup: int = 100,
                    total: int = 10_000, clip: float = 1.0,
                    accum_steps: int = 1):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    accum_steps > 1 splits the global batch into micro-batches scanned
    sequentially with fp32 gradient accumulation — the standard memory/
    throughput trade (activation footprint / accum_steps).
    """

    def grads_of(params, batch):
        return jax.value_and_grad(api.loss_fn, has_aux=True)(params, cfg, batch)

    def step(params, opt_state, batch):
        if accum_steps == 1:
            (loss, (ce, aux)), grads = grads_of(params, batch)
        else:
            micro = jax.tree_util.tree_map(
                lambda a: a.reshape((accum_steps, a.shape[0] // accum_steps)
                                    + a.shape[1:]), batch)
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def acc_step(carry, mb):
                g_acc, l_acc, c_acc, a_acc = carry
                (l, (c, a)), g = grads_of(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda x, y: x + y.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + l, c_acc + c, a_acc + a), None

            (grads, loss, ce, aux), _ = jax.lax.scan(
                acc_step, (zeros, 0.0, 0.0, 0.0), micro)
            inv = 1.0 / accum_steps
            grads = jax.tree_util.tree_map(lambda g: g * inv, grads)
            loss, ce, aux = loss * inv, ce * inv, aux * inv
        grads, gnorm = clip_by_global_norm(grads, clip)
        lr_t = cosine_schedule(opt_state.step, peak_lr=lr, warmup=warmup,
                               total=total)
        params, opt_state = adamw_update(params, grads, opt_state, lr=lr_t)
        return params, opt_state, {"loss": loss, "ce": ce, "aux": aux,
                                   "grad_norm": gnorm, "lr": lr_t}

    return step


def make_prefill_step(cfg: ArchConfig, max_len: int):
    def step(params, batch, caches):
        return api.prefill_fn(params, cfg, batch, caches)
    return step


def make_decode_step(cfg: ArchConfig):
    def step(params, batch, caches):
        return api.decode_fn(params, cfg, batch, caches)
    return step


# ----------------------------------------------------------------------
def abstract_opt_state(params_abs):
    return jax.eval_shape(adamw_init, params_abs)


def shardings_like(axes_tree, ctx: ShardingCtx):
    is_axes = lambda t: isinstance(t, tuple) and all(
        a is None or isinstance(a, str) for a in t)
    return jax.tree_util.tree_map(lambda ax: ctx.sharding_for(ax), axes_tree,
                                  is_leaf=is_axes)


def opt_shardings(param_shardings):
    """Adam m/v inherit parameter shardings; step scalar replicated."""
    from repro.optim import AdamWState
    mesh = jax.tree_util.tree_leaves(param_shardings)[0].mesh
    return AdamWState(NamedSharding(mesh, P()), param_shardings,
                      param_shardings)
