"""Production meshes.  A FUNCTION (not a module-level constant) so importing
never touches jax device state."""
from __future__ import annotations

import jax


def compat_make_mesh(shape, axes):
    """``jax.make_mesh`` across jax versions: ``axis_types`` (and
    ``jax.sharding.AxisType``) only exist on newer releases."""
    try:
        kinds = (jax.sharding.AxisType.Auto,) * len(axes)
        return jax.make_mesh(shape, axes, axis_types=kinds)
    except (AttributeError, TypeError):
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_local_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh for CPU tests (forced host devices)."""
    return compat_make_mesh((n_data, n_model), ("data", "model"))
