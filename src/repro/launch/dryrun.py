import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import sys
import time

import jax  # noqa: E402  (device count is locked by the two lines above)

from repro.configs import ARCH_IDS  # noqa: E402
from repro.configs.base import SHAPES  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch import dryrun_lib  # noqa: E402


def main(argv=None):
    ap = argparse.ArgumentParser(description="Multi-pod dry-run: lower + "
                                 "compile every (arch x shape) on the "
                                 "production mesh; emit roofline terms.")
    ap.add_argument("--arch", default="all",
                    help=f"one of {list(ARCH_IDS)} or 'all'")
    ap.add_argument("--shape", default="all",
                    help=f"one of {[s.name for s in SHAPES]} or 'all'")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--policy", default=None,
                    help="override placement policy (broadcast|tp|fsdp_tp); "
                         "default: fsdp_tp for train, tp for serve")
    ap.add_argument("--remat", default=None, help="override remat policy")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-cost", action="store_true",
                    help="compile+memory only (multi-pod sharding proof; "
                         "the roofline table is single-pod)")
    ap.add_argument("--verbose-hlo", action="store_true",
                    help="print memory_analysis() and cost_analysis()")
    args = ap.parse_args(argv)

    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    shapes = [s.name for s in SHAPES] if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    n_fail = 0
    for multi_pod in meshes:
        mesh = make_production_mesh(multi_pod=multi_pod)
        for arch in archs:
            for shape in shapes:
                t0 = time.perf_counter()
                override = {"remat": args.remat} if args.remat else None
                res = dryrun_lib.run_cell(arch, shape, mesh,
                                          policy=args.policy,
                                          cfg_override=override,
                                          skip_cost_pass=args.skip_cost)
                dryrun_lib.save_result(res, args.out)
                wall = time.perf_counter() - t0
                if res.skipped:
                    print(f"SKIP {arch:>22} {shape:<12} {res.mesh:<9} "
                          f"{res.reason[:60]}", flush=True)
                elif res.ok:
                    print(f"OK   {arch:>22} {shape:<12} {res.mesh:<9} "
                          f"pol={res.policy:<8} "
                          f"flops/dev={res.flops_dev:.3e} "
                          f"coll={res.coll_wire_bytes_dev:.3e}B "
                          f"dom={res.dominant:<10} "
                          f"useful={res.useful_ratio:.2f} "
                          f"compile={res.compile_s:.1f}s wall={wall:.1f}s",
                          flush=True)
                else:
                    n_fail += 1
                    print(f"FAIL {arch:>22} {shape:<12} {res.mesh:<9} "
                          f"{res.error[:200]}", flush=True)
    print(f"\ndone; failures: {n_fail}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
