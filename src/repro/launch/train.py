"""Production training driver.

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --reduced --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/run1

On a real pod this runs under one process per host with the production mesh
(`make_production_mesh`) and the fsdp_tp policy; on CPU (default) it uses a
single-device mesh and the reduced config.  Checkpoint/restart: the driver
resumes from the latest checkpoint and replays the data cursor via the
ReplayLog (crash-consistent with at-least-once micro-batch semantics).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import ARCH_IDS, get_config
from repro.configs.base import reduced as reduce_cfg
from repro.core.fault import ReplayLog
from repro.core.sharding import use_sharding
from repro.data.text import synthetic_tokens
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.launch.steps import make_train_step
from repro.models import api
from repro.optim import adamw_init


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b", choices=list(ARCH_IDS))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--policy", default="broadcast")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=25)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    mesh = (make_production_mesh() if args.production_mesh
            else make_local_mesh(1, max(1, len(jax.devices()))))

    with use_sharding(mesh, args.policy):
        params, axes = api.init(jax.random.PRNGKey(0), cfg)
        opt = adamw_init(params)
        step_fn = jax.jit(make_train_step(cfg, lr=args.lr, total=args.steps,
                                          accum_steps=args.accum))
        ck = Checkpointer(args.ckpt_dir, async_save=True)
        log = ReplayLog(f"{args.ckpt_dir}/replay.jsonl")

        start = 0
        if ck.latest_step() is not None:
            state = ck.restore({"params": params, "opt": opt})
            params, opt = state["params"], state["opt"]
            start = ck.latest_step()
            print(f"[train] resumed from checkpoint step {start}")

        data = synthetic_tokens(start, args.batch, args.seq, cfg.vocab,
                                n_batches=args.steps - start)
        t0 = time.perf_counter()
        for i, tokens in enumerate(data):
            step = start + i
            params, opt, m = step_fn(params, opt, {"tokens": jnp.asarray(tokens)})
            log.record(step, offset=step * args.batch)
            if step % 10 == 0 or step == args.steps - 1:
                print(f"[train] step {step:4d} loss={float(m['loss']):.4f} "
                      f"gnorm={float(m['grad_norm']):.3f} "
                      f"({time.perf_counter() - t0:.1f}s)")
            if args.ckpt_every and step and step % args.ckpt_every == 0:
                ck.save(step, {"params": params, "opt": opt})
        ck.save(args.steps, {"params": params, "opt": opt})
        ck.wait()
        print(f"[train] done; checkpoints at {ck.steps()}")


if __name__ == "__main__":
    main()
