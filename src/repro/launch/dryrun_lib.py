"""Dry-run core: lower + compile every (arch x shape) cell on a production
mesh, extract memory/cost analysis and the collective schedule, and emit the
roofline terms.  No device buffers are ever allocated (ShapeDtypeStruct in,
AOT-compiled artifact out).

Import order note: this module must be imported AFTER the process has set
XLA_FLAGS (dryrun.py does that in its first two lines).
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import ArchConfig, SHAPE_BY_NAME, ScanGroup, ShapeCase
from repro.core import flags
from repro.core.sharding import ShardingCtx, _rules, use_sharding
from repro.launch import steps as steps_mod
from repro.models import api
from repro.optim import adamw_init

# TPU v5e constants (per chip)
HW = dict(peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9)

LONG_CONTEXT_ARCHS = ("falcon-mamba-7b", "recurrentgemma-2b", "gemma3-4b")

# gradient-accumulation steps for train_4k so activations fit 16 GB HBM
# (memory_analysis-driven; see EXPERIMENTS.md §Dry-run)
TRAIN_ACCUM = {
    "starcoder2-3b": 4, "gemma3-4b": 4, "internlm2-1.8b": 2, "gemma-7b": 4,
    "whisper-base": 1, "internvl2-1b": 2, "recurrentgemma-2b": 4,
    "deepseek-v2-lite-16b": 8, "qwen3-moe-30b-a3b": 16, "falcon-mamba-7b": 8,
}


def cell_applicable(arch: str, shape_name: str) -> Tuple[bool, str]:
    if shape_name == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
        return False, ("pure full-attention KV at 500k tokens is quadratic-"
                       "prefill / unbounded-cache; run only for SSM/hybrid/"
                       "mostly-local archs (DESIGN.md §5)")
    return True, ""


# ----------------------------------------------------------------------
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[([0-9,]+)\]<=\[")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8}


def _buf_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> List[dict]:
    """Per-device wire-byte estimates for every collective in the compiled
    module.  Result shapes in partitioned HLO are per-shard."""
    out = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        type_str, op = m.group(1), m.group(2)
        buf = _buf_bytes(type_str)
        g = 1
        mi = _GROUPS_IOTA_RE.search(line)
        if mi:
            g = int(mi.group(1).split(",")[-1])
        else:
            ml = _GROUPS_LIST_RE.search(line)
            if ml:
                g = len(ml.group(1).split(","))
        if op == "all-reduce":
            wire = 2 * buf * (g - 1) / max(g, 1)
        elif op == "reduce-scatter":
            wire = buf * (g - 1)                  # result is the shard
        elif op == "all-gather":
            wire = buf * (g - 1) / max(g, 1)      # result is gathered buf
        elif op == "all-to-all":
            wire = buf * (g - 1) / max(g, 1)
        else:                                      # collective-permute
            wire = buf
        out.append(dict(op=op, buf_bytes=buf, group=g, wire_bytes=wire))
    return out


# ----------------------------------------------------------------------
def model_param_counts(cfg: ArchConfig) -> Dict[str, float]:
    params_abs, axes = api.abstract_params(cfg)
    leaves = jax.tree_util.tree_leaves(params_abs)
    ax_leaves = jax.tree_util.tree_leaves(
        axes, is_leaf=lambda t: isinstance(t, tuple) and all(
            a is None or isinstance(a, str) for a in t))
    total = sum(int(np.prod(l.shape)) for l in leaves)
    expert = sum(int(np.prod(l.shape)) for l, a in zip(leaves, ax_leaves)
                 if "experts" in a)
    embed = 0
    for l in leaves:
        if l.shape and cfg.vocab in l.shape:
            embed += int(np.prod(l.shape))
    active = total - expert
    if cfg.n_experts:
        active += expert * cfg.top_k / cfg.n_experts
    return dict(total=total, active=active, experts=expert, embed=embed)


@dataclasses.dataclass
class CellResult:
    arch: str
    shape: str
    mesh: str
    policy: str
    ok: bool
    skipped: bool = False
    reason: str = ""
    lower_s: float = 0.0
    compile_s: float = 0.0
    flops_dev: float = 0.0
    bytes_dev: float = 0.0
    coll_wire_bytes_dev: float = 0.0
    n_collectives: int = 0
    coll_by_op: Dict[str, float] = dataclasses.field(default_factory=dict)
    arg_bytes_dev: int = 0
    out_bytes_dev: int = 0
    temp_bytes_dev: int = 0
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    dominant: str = ""
    model_flops_dev: float = 0.0
    useful_ratio: float = 0.0
    params_total: float = 0.0
    params_active: float = 0.0
    error: str = ""

    def to_json(self):
        return dataclasses.asdict(self)


# ----------------------------------------------------------------------
def build_cell(cfg: ArchConfig, sc: ShapeCase, mesh: Mesh, policy: str,
               accum_steps: int = 1):
    """Returns (fn, args, in_shardings, out_shardings, donate, act_rules)."""
    n_data = int(np.prod([mesh.shape[a] for a in mesh.axis_names
                          if a in ("pod", "data")]))
    rules = dict(_rules(policy, mesh.axis_names))
    if sc.global_batch < n_data:
        rules["batch"] = None                      # don't shard tiny batch
    ctx = ShardingCtx(mesh, policy, rules)

    params_abs, axes = api.abstract_params(cfg)
    param_sh = steps_mod.shardings_like(axes, ctx)
    repl = NamedSharding(mesh, P())

    def bsh(nd):
        data_axes = rules.get("batch")
        return NamedSharding(mesh, P(data_axes, *([None] * (nd - 1))))

    batch_abs = api.input_specs(cfg, "train" if sc.kind != "decode" else "decode",
                                sc.global_batch, sc.seq_len)
    batch_sh = {k: bsh(len(v.shape)) for k, v in batch_abs.items()}

    if sc.kind == "train":
        opt_abs = jax.eval_shape(adamw_init, params_abs)
        opt_sh = steps_mod.opt_shardings(param_sh)
        step = steps_mod.make_train_step(cfg, accum_steps=accum_steps)
        metric_sh = {k: repl for k in ("loss", "ce", "aux", "grad_norm", "lr")}
        return (step, (params_abs, opt_abs, batch_abs),
                (param_sh, opt_sh, batch_sh),
                (param_sh, opt_sh, metric_sh), (0, 1), rules)

    max_len = sc.seq_len
    caches_abs = jax.eval_shape(
        lambda: api.init_caches(cfg, sc.global_batch, max_len,
                                enc_len=sc.seq_len))
    # caches are seq-sharded over `model` for BOTH prefill (written) and
    # decode (read): one layout end-to-end, no reshard between phases
    cache_sh = steps_mod.cache_specs(cfg, mesh, max_len, sc.global_batch,
                                     policy, shard_seq=True)
    logits_sh = NamedSharding(mesh, P(rules.get("batch"), None,
                                      rules.get("vocab")))
    if sc.kind == "prefill":
        step = steps_mod.make_prefill_step(cfg, max_len)
    else:
        step = steps_mod.make_decode_step(cfg)
    return (step, (params_abs, batch_abs, caches_abs),
            (param_sh, batch_sh, cache_sh),
            (logits_sh, cache_sh), (2,), rules)


def depth_samples(cfg: ArchConfig):
    """Depth-reduced configs for the cost pass.

    XLA's cost_analysis counts scan bodies once, so costs are extracted from
    UNROLLED depth-1/depth-2 variants (full shapes) and extrapolated:
      cost(full) = cost(base) + sum_g (R_g - 1) * (cost(sample_g) - cost(base)).
    Exact because per-layer cost within a group is shape-identical.
    """
    if cfg.family == "encdec":
        base = cfg.replace(enc_layers=1, dec_layers=1, n_layers=2,
                           scan_layers=False, groups=())
        samples = []
        if cfg.enc_layers > 1:
            samples.append((cfg.replace(enc_layers=2, dec_layers=1, n_layers=3,
                                        scan_layers=False, groups=()),
                            cfg.enc_layers - 1))
        if cfg.dec_layers > 1:
            samples.append((cfg.replace(enc_layers=1, dec_layers=2, n_layers=3,
                                        scan_layers=False, groups=()),
                            cfg.dec_layers - 1))
        return base, samples

    def with_repeats(reps):
        gs = tuple(ScanGroup(g.pattern, r) for g, r in zip(cfg.groups, reps))
        return cfg.replace(groups=gs, n_layers=sum(g.n_layers for g in gs),
                           scan_layers=False)

    ones = [1] * len(cfg.groups)
    base = with_repeats(ones)
    samples = []
    for gi, g in enumerate(cfg.groups):
        if g.repeats > 1:
            reps = list(ones)
            reps[gi] = 2
            samples.append((with_repeats(reps), g.repeats - 1))
    return base, samples


def _compile_cell(cfg, sc, mesh, policy, accum_steps: int = 1):
    fn, args, in_sh, out_sh, donate, rules = build_cell(
        cfg, sc, mesh, policy, accum_steps=accum_steps)
    jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=donate)
    with use_sharding(mesh, policy, rules=rules):
        lowered = jitted.lower(*args)
    return lowered.compile()


def _extract_cost(compiled) -> Dict[str, Any]:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):          # older jax: one dict per program
        ca = ca[0] if ca else {}
    colls = parse_collectives(compiled.as_text())
    by_op: Dict[str, float] = {}
    for c in colls:
        by_op[c["op"]] = by_op.get(c["op"], 0.0) + c["wire_bytes"]
    return dict(flops=float(ca.get("flops", 0.0)),
                bytes=float(ca.get("bytes accessed", 0.0)),
                wire=float(sum(c["wire_bytes"] for c in colls)),
                ncoll=float(len(colls)), by_op=by_op)


def cost_pass(cfg: ArchConfig, sc: ShapeCase, mesh: Mesh, policy: str):
    """Corrected per-device cost via unrolled depth minis + extrapolation."""
    base_cfg, samples = depth_samples(cfg)
    flags.COST_MODE = True
    try:
        base = _extract_cost(_compile_cell(base_cfg, sc, mesh, policy))
        total = dict(base)
        total["by_op"] = dict(base["by_op"])
        for cfg_s, extra in samples:
            s = _extract_cost(_compile_cell(cfg_s, sc, mesh, policy))
            for k in ("flops", "bytes", "wire", "ncoll"):
                total[k] += extra * max(s[k] - base[k], 0.0)
            for op in set(s["by_op"]) | set(base["by_op"]):
                delta = s["by_op"].get(op, 0.0) - base["by_op"].get(op, 0.0)
                total["by_op"][op] = (total["by_op"].get(op, 0.0)
                                      + extra * max(delta, 0.0))
    finally:
        flags.COST_MODE = False
    return total


def run_cell(arch: str, shape_name: str, mesh: Mesh, policy: Optional[str] = None,
             cfg_override=None, skip_memory_pass: bool = False,
             skip_cost_pass: bool = False) -> CellResult:
    sc = SHAPE_BY_NAME[shape_name]
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    ok, reason = cell_applicable(arch, shape_name)
    policy = policy or ("fsdp_tp" if sc.kind == "train" else "tp")
    res = CellResult(arch=arch, shape=shape_name, mesh=mesh_name,
                     policy=policy, ok=False)
    if not ok:
        res.skipped = True
        res.reason = reason
        res.ok = True
        return res

    cfg = get_config(arch)
    cfg = cfg.replace(remat="full" if sc.kind == "train" else "none")
    if cfg_override:
        cfg = cfg.replace(**cfg_override)
    counts = model_param_counts(cfg)
    res.params_total, res.params_active = counts["total"], counts["active"]

    try:
        # ---- cost pass: unrolled depth minis, extrapolated
        if not skip_cost_pass:
            t0 = time.perf_counter()
            cost = cost_pass(cfg, sc, mesh, policy)
            res.lower_s = time.perf_counter() - t0
            res.flops_dev = cost["flops"]
            res.bytes_dev = cost["bytes"]
            res.coll_wire_bytes_dev = cost["wire"]
            res.n_collectives = int(cost["ncoll"])
            res.coll_by_op = cost["by_op"]

        # ---- memory/compile pass: production (scanned) config; train cells
        # use gradient accumulation to fit HBM (cost is accum-invariant)
        if not skip_memory_pass:
            accum = TRAIN_ACCUM.get(arch, 1) if sc.kind == "train" else 1
            t0 = time.perf_counter()
            compiled = _compile_cell(cfg, sc, mesh, policy, accum_steps=accum)
            res.compile_s = time.perf_counter() - t0
            res.policy = policy + (f"+accum{accum}" if accum > 1 else "")
            ma = compiled.memory_analysis()
            if ma is not None:
                res.arg_bytes_dev = int(ma.argument_size_in_bytes)
                res.out_bytes_dev = int(ma.output_size_in_bytes)
                res.temp_bytes_dev = int(ma.temp_size_in_bytes)

        # ---- roofline terms (per chip, seconds)
        res.t_compute = res.flops_dev / HW["peak_flops"]
        res.t_memory = res.bytes_dev / HW["hbm_bw"]
        res.t_collective = res.coll_wire_bytes_dev / HW["ici_bw"]
        res.dominant = max(
            [("compute", res.t_compute), ("memory", res.t_memory),
             ("collective", res.t_collective)], key=lambda kv: kv[1])[0]

        # ---- useful-FLOPs ratio
        n_chips = mesh.devices.size
        tokens = sc.global_batch * (sc.seq_len if sc.kind != "decode" else 1)
        mult = 6 if sc.kind == "train" else 2
        res.model_flops_dev = mult * counts["active"] * tokens / n_chips
        res.useful_ratio = (res.model_flops_dev / res.flops_dev
                            if res.flops_dev else 0.0)
        res.ok = True
    except Exception as e:  # noqa: BLE001 — report per-cell failures
        res.error = f"{type(e).__name__}: {e}"[:2000]
        res.ok = False
    return res


def save_result(res: CellResult, out_dir: str):
    os.makedirs(out_dir, exist_ok=True)
    base_policy = res.policy.split("+")[0]
    name = f"{res.arch}__{res.shape}__{res.mesh}__{base_policy}.json"
    path = os.path.join(out_dir, name)
    d = res.to_json()
    # memory-only re-runs (skip_cost) merge into existing cost numbers
    if res.ok and not res.skipped and res.flops_dev == 0 and os.path.exists(path):
        old = json.load(open(path))
        for k in ("flops_dev", "bytes_dev", "coll_wire_bytes_dev",
                  "n_collectives", "coll_by_op", "t_compute", "t_memory",
                  "t_collective", "dominant", "model_flops_dev",
                  "useful_ratio", "lower_s"):
            d[k] = old.get(k, d[k])
    with open(path, "w") as f:
        json.dump(d, f, indent=1)
