"""Production serving driver: continuous-batching engine + the MLaaS
service front (deadline-aware request queue).

    PYTHONPATH=src python -m repro.launch.serve --arch falcon-mamba-7b \
        --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import reduced as reduce_cfg
from repro.models import api
from repro.serving import Engine, ServeConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b",
                    choices=[a for a in ARCH_IDS if a != "whisper-base"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = reduce_cfg(get_config(args.arch))
    params, _ = api.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, ServeConfig(max_len=args.max_len,
                                          slots=args.slots))
    rng = np.random.RandomState(args.seed)
    reqs = [eng.submit(rng.randint(0, cfg.vocab,
                                   size=rng.randint(4, 16)).astype(np.int32),
                       max_new=args.max_new) for _ in range(args.requests)]
    t0 = time.perf_counter()
    eng.run_until_drained()
    wall = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in reqs)
    lats = [r.done_t - r.submit_t for r in reqs]
    print(f"[serve] arch={args.arch} reqs={len(reqs)} tokens={toks} "
          f"tok/s={toks / wall:.1f} p50={np.median(lats):.2f}s "
          f"p99={np.percentile(lats, 99):.2f}s")


if __name__ == "__main__":
    main()
