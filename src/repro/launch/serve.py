"""Production serving driver: continuous-batching engine(s) + the MLaaS
request path.  With ``--replicas N`` (N > 1) requests travel through the
cluster layer — a Router fanning out over N engine replicas with admission
control and unified metrics.  ``--transport`` picks replica placement:

  * ``thread``  — replicas share this process and its JAX runtime; weights
    are zero-copy but device FLOPs do not scale.
  * ``process`` — each replica is a spawned worker process with an RPC
    inbox, rebuilt from a serializable spec (arch + seed or
    ``--weights-dir``); independent JAX runtimes, so compute scales.
  * ``socket``  — the same spec-rebuilt worker behind a framed TCP
    connection with a versioned reconnect handshake: here the workers are
    spawned locally and dial back over loopback, but the identical worker
    (``python -m repro.cluster.worker_main``) can run on any host that
    reaches this process — heartbeat-timeout crash detection and
    artifact-store weight fetch included.

    PYTHONPATH=src python -m repro.launch.serve --arch falcon-mamba-7b \
        --requests 8 --max-new 16
    PYTHONPATH=src python -m repro.launch.serve --replicas 2 \
        --router-policy least_loaded --requests 8 --transport socket
"""
from __future__ import annotations

import argparse
import json
import threading
import time
import urllib.request

import jax
import numpy as np

from repro.cluster import (AdmissionConfig, AdmissionController,
                           BrownoutController, EngineBackend,
                           MetricsRegistry, POLICIES, ReplicaConfig, Router,
                           SLOEngine, SLOObjective, StatsServer, TRANSPORTS,
                           TelemetrySampler, TimeSeriesStore, Tracer,
                           current_tracer, engine_spec, prometheus_text,
                           render_watch, set_tracer, to_chrome_trace)
from repro.cluster.tracing import start_profiling, stop_profiling
from repro.configs import ARCH_IDS, get_config
from repro.configs.base import reduced as reduce_cfg
from repro.models import api
from repro.serving import Engine, ServeConfig, make_engine_fns


def _start_telemetry(args, snapshot_fn, registry, router=None):
    """Build the stats stack — ring-buffer TimeSeriesStore, SLO burn-rate
    engine, background sampler, HTTP stats endpoint, optional terminal
    watcher — and return a ``finalize()`` that takes one last sample,
    dumps the routes (``--stats-dump``), and tears everything down."""
    from repro.cluster.tracing import current_recorder

    store = TimeSeriesStore()
    slo = SLOEngine([SLOObjective(kind="any")], registry,
                    recorder=current_recorder())
    if router is not None:
        router.slo = slo            # brownout reads slo.pressure()
    sampler = TelemetrySampler(snapshot_fn, store, registry=registry,
                               tracer=current_tracer(), slo=slo,
                               period_s=args.stats_period)
    sampler.start()
    server = None
    port = args.stats_port
    if port is None and args.stats_dump:
        port = 0
    if port is not None:
        server = StatsServer(snapshot_fn, store, slo=slo,
                             host=args.stats_host, port=port).start()
        print(f"[stats] /metrics /timeseries.json /slo.json /dash "
              f"on {server.url}")
    stop_watch = threading.Event()
    wt = None
    if args.watch:
        def _watch_loop():
            while not stop_watch.wait(1.0):
                print("\x1b[2J\x1b[H" + render_watch(store, slo.status()))
        wt = threading.Thread(target=_watch_loop, daemon=True,
                              name="stats-watch")
        wt.start()

    def finalize():
        stop_watch.set()
        if wt is not None:
            wt.join(timeout=2.0)
        sampler.stop()
        sampler.tick()              # one last sample so dumps see the end
        if args.watch:
            print(render_watch(store, slo.status()))
        if args.stats_dump and server is not None:
            routes = (("metrics", "txt", "/metrics"),
                      ("timeseries", "json", "/timeseries.json"),
                      ("slo", "json", "/slo.json"),
                      ("dash", "html", "/dash"))
            for name, ext, route in routes:
                with urllib.request.urlopen(server.url + route,
                                            timeout=10.0) as resp:
                    body = resp.read()
                with open(f"{args.stats_dump}.{name}.{ext}", "wb") as f:
                    f.write(body)
            print(f"[stats] dumped {len(routes)} routes -> "
                  f"{args.stats_dump}.*")
        if server is not None:
            server.stop()

    return finalize


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b",
                    choices=[a for a in ARCH_IDS if a != "whisper-base"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--replicas", type=int, default=1,
                    help="engine replicas behind the cluster router")
    ap.add_argument("--router-policy", default="round_robin",
                    choices=list(POLICIES))
    ap.add_argument("--max-queue", type=int, default=4096,
                    help="admission control: global queued-cost bound")
    ap.add_argument("--transport", default="thread", choices=list(TRANSPORTS),
                    help="replica placement: host threads, worker processes "
                         "with RPC inboxes, or socket workers over framed "
                         "TCP (remote-host capable)")
    ap.add_argument("--no-fused", dest="fused", action="store_false",
                    help="per-token reference decode loop instead of the "
                         "fused on-device K-step loop")
    ap.add_argument("--sync-every", type=int, default=8,
                    help="K: fused decode steps per host sync")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="in-jit sampling temperature (0 = greedy argmax)")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache: per-layer block pool + block "
                         "tables + content-hashed prefix cache instead of "
                         "one dense max_len stripe per slot (families with "
                         "non-pageable state keep the dense path)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="tokens per KV block (paged)")
    ap.add_argument("--kv-blocks", type=int, default=0,
                    help="usable pool blocks (paged); 0 = dense-equivalent "
                         "slots * max_len/block_size")
    ap.add_argument("--kv-swap", action="store_true",
                    help="KV lifecycle swap (paged): under pool pressure "
                         "preempt whole lowest-priority sessions to the "
                         "swap tier and restore them block-exact at "
                         "re-admit instead of completing them early as "
                         "kv_pool_exhausted victims")
    ap.add_argument("--swap-tier", default="host",
                    choices=("host", "artifact"),
                    help="where swapped KV blocks live: host memory "
                         "(inline bytes) or the content-addressed "
                         "artifact store")
    ap.add_argument("--speculative", action="store_true",
                    help="speculative multi-token decode on the paged path: "
                         "an n-gram draft proposes spec-draft tokens per "
                         "step and one batched paged extend verifies them "
                         "(greedy only; requires --paged)")
    ap.add_argument("--spec-draft", type=int, default=3,
                    help="draft tokens proposed per speculative step")
    ap.add_argument("--request-timeout", type=float, default=600.0,
                    help="per-request deadline budget in seconds; the "
                         "budget rides the wire to workers, which drop "
                         "expired queue work and finish expired sessions "
                         "mid-decode (finish_reason='deadline')")
    ap.add_argument("--brownout", action="store_true",
                    help="graded overload controller: under queue/KV "
                         "pressure, degrade service (disable speculation, "
                         "halve max_new, tighten admission) instead of "
                         "only shedding at the front door")
    ap.add_argument("--kv-headroom", type=float, default=0.0,
                    help="admission: shed when the cluster's free KV-block "
                         "fraction drops below this (0 disables)")
    ap.add_argument("--weights-dir", default=None,
                    help="checkpoint dir for process workers to load "
                         "weights from (default: deterministic init at "
                         "seed 0 inside each worker, matching the "
                         "thread/single-replica paths)")
    ap.add_argument("--trace", action="store_true",
                    help="record per-request spans through router, "
                         "transport, replica, and engine stages")
    ap.add_argument("--trace-sample-rate", type=float, default=1.0,
                    help="fraction of requests that root a trace "
                         "(workers always follow a sampled parent)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the collected spans as Chrome trace-event "
                         "JSON (load in Perfetto / chrome://tracing); "
                         "implies --trace")
    ap.add_argument("--prom-out", default=None, metavar="PATH",
                    help="write the final metrics snapshot in Prometheus "
                         "text exposition format")
    ap.add_argument("--profile-dir", default=None, metavar="DIR",
                    help="capture a jax.profiler device trace of the run "
                         "into DIR (TensorBoard/Perfetto loadable); adds "
                         "TraceAnnotation markers around prefill/decode")
    ap.add_argument("--stats-port", type=int, default=None, metavar="PORT",
                    help="serve live stats over HTTP: /metrics (Prometheus), "
                         "/timeseries.json, /slo.json, /dash (HTML "
                         "dashboard); 0 picks an ephemeral port")
    ap.add_argument("--stats-host", default="127.0.0.1",
                    help="stats bind address (loopback unless you mean it)")
    ap.add_argument("--stats-dump", default=None, metavar="PREFIX",
                    help="at end of run, fetch every stats route over HTTP "
                         "and write PREFIX.metrics.txt / .timeseries.json / "
                         ".slo.json / .dash.html; implies --stats-port 0")
    ap.add_argument("--watch", action="store_true",
                    help="render a terminal stats screen every second "
                         "while the run is in flight")
    ap.add_argument("--stats-period", type=float, default=0.25,
                    help="telemetry sampling cadence in seconds")
    args = ap.parse_args(argv)

    if args.trace_out:
        args.trace = True
    if args.trace:
        set_tracer(Tracer(enabled=True,
                          sample_rate=args.trace_sample_rate,
                          replica="parent"))
    if args.profile_dir:
        start_profiling(args.profile_dir)

    cfg = reduce_cfg(get_config(args.arch))
    # remote workers init/load their own weights; don't pay for a parent copy
    need_params = args.replicas <= 1 or \
        args.transport not in ("process", "socket")
    params = api.init(jax.random.PRNGKey(0), cfg)[0] if need_params else None
    scfg = ServeConfig(max_len=args.max_len, slots=args.slots,
                       fused=args.fused, sync_every=args.sync_every,
                       temperature=args.temperature, paged=args.paged,
                       block_size=args.block_size, kv_blocks=args.kv_blocks,
                       speculative=args.speculative,
                       spec_draft=args.spec_draft, kv_swap=args.kv_swap,
                       swap_tier=args.swap_tier)
    rng = np.random.RandomState(args.seed)
    prompts = [rng.randint(0, cfg.vocab,
                           size=rng.randint(4, 16)).astype(np.int32)
               for _ in range(args.requests)]

    snap = None
    stats_on = (args.stats_port is not None or args.stats_dump is not None
                or args.watch)
    finalize_stats = None
    if args.replicas <= 1:
        metrics = MetricsRegistry() if (args.prom_out or stats_on) else None
        eng = Engine(params, cfg, scfg, metrics=metrics)
        if stats_on:
            finalize_stats = _start_telemetry(args, metrics.snapshot,
                                              metrics)
        reqs = [eng.submit(p, max_new=args.max_new) for p in prompts]
        t0 = time.perf_counter()
        eng.run_until_drained()
        wall = time.perf_counter() - t0
        toks = sum(len(r.out_tokens) for r in reqs)
        lats = [r.done_t - r.submit_t for r in reqs]
        if finalize_stats is not None:
            finalize_stats()
        if metrics is not None:
            snap = metrics.snapshot()
    else:
        metrics = MetricsRegistry()
        router = Router(policy=args.router_policy, metrics=metrics,
                        admission=AdmissionController(
                            AdmissionConfig(
                                max_queue_cost=args.max_queue,
                                min_kv_headroom_frac=args.kv_headroom),
                            metrics),
                        brownout=BrownoutController() if args.brownout
                        else None)
        rcfg = ReplicaConfig(max_batch=args.slots)
        if args.transport in ("process", "socket"):
            spec = engine_spec(arch=args.arch, max_len=args.max_len,
                               slots=args.slots, reduce=True, seed=0,
                               weights_path=args.weights_dir,
                               fused=args.fused, sync_every=args.sync_every,
                               temperature=args.temperature,
                               paged=args.paged, block_size=args.block_size,
                               kv_blocks=args.kv_blocks,
                               speculative=args.speculative,
                               spec_draft=args.spec_draft,
                               kv_swap=args.kv_swap,
                               swap_tier=args.swap_tier)
            for _ in range(args.replicas):
                router.add_replica(spec=spec, cfg=rcfg,
                                   transport=args.transport)
        else:
            shared_fns = make_engine_fns(cfg, scfg)
            for _ in range(args.replicas):
                router.add_replica(
                    EngineBackend(Engine(params, cfg, scfg, metrics=metrics,
                                         shared_fns=shared_fns)),
                    rcfg)
        if stats_on:
            finalize_stats = _start_telemetry(args, router.cluster_snapshot,
                                              metrics, router=router)
        t0 = time.perf_counter()
        creqs = [router.submit((p, args.max_new), cost=args.max_new,
                               session_key=str(i),
                               timeout_s=args.request_timeout)
                 for i, p in enumerate(prompts)]
        outs = [router.wait(r, timeout=args.request_timeout)
                for r in creqs]
        wall = time.perf_counter() - t0
        if finalize_stats is not None:
            finalize_stats()
        router.stop()
        toks = sum(len(o) for o in outs if isinstance(o, list))
        lats = [r.finished_s - r.submitted_s for r in creqs]
        snap = metrics.snapshot()
        print(f"[cluster] replicas={args.replicas} "
              f"transport={args.transport} "
              f"policy={args.router_policy} "
              f"completed={snap['router.completed']:.0f} "
              f"shed={snap.get('admission.shed_queue_full', 0):.0f}")

    print(f"[serve] arch={args.arch} reqs={len(prompts)} tokens={toks} "
          f"tok/s={toks / wall:.1f} p50={np.median(lats):.2f}s "
          f"p99={np.percentile(lats, 99):.2f}s")

    if args.profile_dir:
        stop_profiling()
        print(f"[profile] jax trace written under {args.profile_dir}")
    if args.trace_out:
        spans = current_tracer().spans()
        with open(args.trace_out, "w") as f:
            json.dump(to_chrome_trace(spans), f)
        print(f"[trace] {len(spans)} spans -> {args.trace_out}")
    if args.prom_out:
        with open(args.prom_out, "w") as f:
            f.write(prometheus_text(snap or {}))
        print(f"[metrics] prometheus exposition -> {args.prom_out}")


if __name__ == "__main__":
    main()
