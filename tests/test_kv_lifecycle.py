"""Unified KV-block lifecycle: preemption + host/artifact swap, and warm
session migration on drain.

Two consumers share one serialize/ship/restore mechanism
(``kvpool.pack_block_arrays`` + the engine's ``kv_export``/``kv_import``
gathers):

  * under pool pressure the engine swaps the lowest-priority session's
    blocks out (host bytes or the artifact store), requeues the request
    at the queue front, and later restores it block-exact — so
    oversubscription becomes routine instead of producing
    ``kv_pool_exhausted`` victims;
  * on drain a replica exports its prefix-cache blocks and the router
    ships them to the drained sessions' new rendezvous homes, so decode
    resumes warm instead of cold.

The invariant throughout is *token-exactness*: greedy decode from the
shared seed-0 params depends only on (prompt, max_new), so every swap /
restore / migration must be observationally invisible against an
undisturbed ample-pool oracle.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import reduced
from repro.models import api
from repro.serving import Engine, ServeConfig

pytestmark = pytest.mark.kvchaos


@pytest.fixture(scope="module")
def model():
    cfg = reduced(get_config("internlm2-1.8b"))
    params, _ = api.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _drain(params, cfg, scfg, prompts, max_new):
    eng = Engine(params, cfg, scfg)
    reqs = [eng.submit(p.copy(), max_new=max_new) for p in prompts]
    eng.run_until_drained()
    return eng, reqs


# ----------------------------------------------------------------------
# preemption + swap

def test_preempt_swap_restores_token_exact(model):
    """A deliberately tight pool forces mid-decode preemption; the swapped
    session must resume block-exact — identical tokens to an ample-pool
    run — and both swap counters must tick."""
    cfg, params = model
    rng = np.random.RandomState(3)
    prompts = [rng.randint(0, cfg.vocab, size=8).astype(np.int32)
               for _ in range(6)]
    _, oracle = _drain(params, cfg,
                       ServeConfig(max_len=32, slots=2, sync_every=4,
                                   paged=True, block_size=8, kv_blocks=64,
                                   prefix_cache=False), prompts, max_new=12)
    eng, reqs = _drain(params, cfg,
                       ServeConfig(max_len=32, slots=4, sync_every=4,
                                   paged=True, block_size=8, kv_blocks=10,
                                   prefix_cache=False, kv_swap=True),
                       prompts, max_new=12)
    for a, b in zip(oracle, reqs):
        assert b.done and b.finish_reason == "max_new", b.finish_reason
        assert a.out_tokens == b.out_tokens
    snap = eng.metrics.snapshot()
    assert snap.get("engine.kv_swap_out", 0) > 0, snap
    assert snap.get("engine.kv_swap_in", 0) == snap["engine.kv_swap_out"]
    assert snap.get("engine.kv_pool_exhausted", 0) == 0
    # no block leaked across the swap cycles
    assert eng.alloc.free_blocks + eng.alloc.cached_blocks == \
        eng.alloc.num_blocks


def test_oversubscribe_4x_completes_all(model):
    """ISSUE acceptance: 4x KV oversubscription (token demand ~4x the
    pool) sustained via swap where the seed engine produced
    kv_pool_exhausted victims — everything completes, token-exact."""
    cfg, params = model
    rng = np.random.RandomState(5)
    prompts = [rng.randint(0, cfg.vocab, size=8).astype(np.int32)
               for _ in range(8)]
    max_new = 16
    # demand: 8 sessions x 24 tokens = 192; pool: 6 blocks x 8 = 48 -> 4x
    tight = ServeConfig(max_len=32, slots=8, sync_every=4, paged=True,
                        block_size=8, kv_blocks=6, prefix_cache=False,
                        kv_swap=True)
    _, oracle = _drain(params, cfg,
                       ServeConfig(max_len=32, slots=8, sync_every=4,
                                   paged=True, block_size=8, kv_blocks=64,
                                   prefix_cache=False), prompts, max_new)
    eng, reqs = _drain(params, cfg, tight, prompts, max_new)
    for a, b in zip(oracle, reqs):
        assert b.done and b.finish_reason == "max_new", b.finish_reason
        assert a.out_tokens == b.out_tokens
    snap = eng.metrics.snapshot()
    assert snap.get("engine.kv_pool_exhausted", 0) == 0, snap
    assert snap.get("engine.kv_swap_out", 0) > 0, snap


def test_swap_artifact_tier_token_exact(model):
    """swap_tier="artifact" routes swapped bytes through the ArtifactStore
    (content-addressed, digest in the snapshot) instead of host memory;
    the restore path must stay token-exact."""
    cfg, params = model
    rng = np.random.RandomState(7)
    prompts = [rng.randint(0, cfg.vocab, size=8).astype(np.int32)
               for _ in range(6)]
    _, oracle = _drain(params, cfg,
                       ServeConfig(max_len=32, slots=2, sync_every=4,
                                   paged=True, block_size=8, kv_blocks=64,
                                   prefix_cache=False), prompts, max_new=12)
    eng, reqs = _drain(params, cfg,
                       ServeConfig(max_len=32, slots=4, sync_every=4,
                                   paged=True, block_size=8, kv_blocks=10,
                                   prefix_cache=False, kv_swap=True,
                                   swap_tier="artifact"),
                       prompts, max_new=12)
    for a, b in zip(oracle, reqs):
        assert a.out_tokens == b.out_tokens
    assert eng.metrics.snapshot().get("engine.kv_swap_out", 0) > 0


def test_kv_swap_requires_paged():
    with pytest.raises(ValueError):
        ServeConfig(kv_swap=True)
    with pytest.raises(ValueError):
        ServeConfig(paged=True, kv_swap=True, swap_tier="nvme")


def test_priority_orders_preemption_victims(model):
    """Lower Request.priority preempts first: under pressure the
    low-priority session is the one that swaps, never the high-priority
    ones (observable via which rid the recorder logs)."""
    from repro.cluster.tracing import FlightRecorder, current_recorder, \
        set_recorder

    cfg, params = model
    rng = np.random.RandomState(11)
    prompts = [rng.randint(0, cfg.vocab, size=8).astype(np.int32)
               for _ in range(4)]
    scfg = ServeConfig(max_len=32, slots=4, sync_every=4, paged=True,
                       block_size=8, kv_blocks=10, prefix_cache=False,
                       kv_swap=True)
    prev = current_recorder()
    set_recorder(FlightRecorder(replica="test"))
    try:
        eng = Engine(params, cfg, scfg)
        low = eng.submit(prompts[0].copy(), max_new=12, priority=-1)
        rest = [eng.submit(p.copy(), max_new=12) for p in prompts[1:]]
        eng.run_until_drained()
        assert low.done and all(r.done for r in rest)
        swaps = [e for e in current_recorder().events()
                 if e["kind"] == "kv_swap_out"]
        assert swaps, "pressure never forced a swap"
        assert all(e["rid"] == low.rid for e in swaps), \
            f"preempted a higher-priority session: {swaps}"
    finally:
        set_recorder(prev)


# ----------------------------------------------------------------------
# export / import (the migration payload)

def test_export_import_restores_prefix_warm(model):
    """Engine A's exported blocks adopted by engine B turn B's first
    decode of the same prefix into cache hits, with tokens identical to a
    cold run — and the import is idempotent and consumes only free
    blocks (admission headroom never shrinks)."""
    cfg, params = model
    scfg = ServeConfig(max_len=48, slots=2, sync_every=4, paged=True,
                       block_size=8, kv_blocks=24, prefix_cache=True)
    rng = np.random.RandomState(13)
    prompt = rng.randint(0, cfg.vocab, size=17).astype(np.int32)

    a = Engine(params, cfg, scfg)
    ra = a.submit(prompt.copy(), max_new=8)
    a.run_until_drained()
    state = a.export_kv_state()
    assert state is not None and state["kind"] == "kv_blocks"
    assert state["block_size"] == 8 and len(state["hashes"]) > 0

    b = Engine(params, cfg, scfg)
    free_before = b.alloc.free_blocks
    n = b.import_kv_state(state)
    assert n == len(state["hashes"])
    # adopted entries are evictable cache, not pinned residents
    assert b.alloc.cached_blocks == n
    assert b.alloc.free_blocks == free_before - n
    assert b.alloc.free_blocks + b.alloc.cached_blocks == b.alloc.num_blocks
    # idempotent: a re-delivered frame adopts nothing new
    assert b.import_kv_state(state) == 0

    cont = np.concatenate([prompt, np.asarray(ra.out_tokens, np.int32)])
    rb = b.submit(cont.copy(), max_new=6)
    b.run_until_drained()
    assert b.metrics.snapshot().get("engine.prefix_hit_blocks", 0) > 0

    c = Engine(params, cfg, scfg)      # cold oracle
    rc = c.submit(cont.copy(), max_new=6)
    c.run_until_drained()
    assert rb.out_tokens == rc.out_tokens


def test_import_rejects_mismatched_state(model):
    cfg, params = model
    scfg = ServeConfig(max_len=48, slots=2, sync_every=4, paged=True,
                       block_size=8, kv_blocks=24, prefix_cache=True)
    eng = Engine(params, cfg, scfg)
    assert eng.import_kv_state(None) == 0
    assert eng.import_kv_state({"kind": "other"}) == 0
    assert eng.import_kv_state({"kind": "kv_blocks", "block_size": 16,
                                "hashes": [], "data": b""}) == 0


# ----------------------------------------------------------------------
# drain-time warm migration through the router (the PR 7 regression:
# Router drain used to only *log* sessions_remapped and drop the state)

def test_drained_session_resumes_warm_on_new_home(model):
    """Satellite regression: after ``remove_replica(home, drain=True,
    migrate=True)`` the drained session's continuation decodes warm
    (prefix hits > 0) on its new rendezvous home and resumes at its exact
    position — token streams match an uninterrupted oracle."""
    from repro.cluster import MetricsRegistry, ReplicaConfig, Router
    from repro.cluster.backends import shared_engine_fns
    from repro.cluster.replica import EngineBackend

    cfg, params = model
    scfg = ServeConfig(max_len=48, slots=2, sync_every=4, paged=True,
                       block_size=8, kv_blocks=24, prefix_cache=True)
    fns = shared_engine_fns(cfg, scfg)

    def backend():
        return EngineBackend(Engine(params, cfg, scfg, shared_fns=fns))

    r = Router(policy="session_affinity", metrics=MetricsRegistry())
    workers = [r.add_replica(backend(), ReplicaConfig(max_batch=2),
                             kind="lm") for _ in range(3)]
    rng = np.random.RandomState(17)
    prompt = rng.randint(0, cfg.vocab, size=17).astype(np.int32)
    q = r.submit((prompt.copy(), 8), session_key="sess-1", kind="lm",
                 timeout_s=300.0)
    toks = r.wait(q, 300.0)
    home = q.replica_rid
    cont = np.concatenate([prompt, np.asarray(toks, np.int32)])

    # uninterrupted oracle for the continuation, off to the side
    oeng = Engine(params, cfg, scfg, shared_fns=fns)
    ro = oeng.submit(cont.copy(), max_new=6)
    oeng.run_until_drained()

    r.remove_replica(home, drain=True, migrate=True)
    snap = r.metrics.snapshot()
    assert snap.get("router.sessions_migrated", 0) >= 1, snap
    assert snap.get("router.kv_migrations", 0) >= 1, snap
    assert r.last_remapped_sessions[home] == ["sess-1"]

    q2 = r.submit((cont.copy(), 6), session_key="sess-1", kind="lm",
                  timeout_s=300.0)
    toks2 = r.wait(q2, 300.0)
    assert q2.replica_rid != home, "session not remapped off the drain"
    new_home = next(w for w in workers if w.rid == q2.replica_rid)
    hits = new_home.backend.engine.metrics.snapshot() \
        .get("engine.prefix_hit_blocks", 0)
    assert hits > 0, "migration did not warm the new home"
    assert toks2 == list(ro.out_tokens), (toks2, list(ro.out_tokens))
    r.stop()


def test_drain_without_migrate_stays_cold(model):
    """migrate=False keeps PR 7 semantics: sessions remap but no KV
    ships, so the new home decodes the continuation cold (and still
    token-exact — cold is correct, just slower)."""
    from repro.cluster import MetricsRegistry, ReplicaConfig, Router
    from repro.cluster.backends import shared_engine_fns
    from repro.cluster.replica import EngineBackend

    cfg, params = model
    scfg = ServeConfig(max_len=48, slots=2, sync_every=4, paged=True,
                       block_size=8, kv_blocks=24, prefix_cache=True)
    fns = shared_engine_fns(cfg, scfg)
    r = Router(policy="session_affinity", metrics=MetricsRegistry())
    workers = [r.add_replica(
        EngineBackend(Engine(params, cfg, scfg, shared_fns=fns)),
        ReplicaConfig(max_batch=2), kind="lm") for _ in range(3)]
    rng = np.random.RandomState(19)
    prompt = rng.randint(0, cfg.vocab, size=17).astype(np.int32)
    q = r.submit((prompt.copy(), 8), session_key="sess-2", kind="lm",
                 timeout_s=300.0)
    toks = r.wait(q, 300.0)
    home = q.replica_rid
    r.remove_replica(home, drain=True, migrate=False)
    assert r.metrics.snapshot().get("router.sessions_migrated", 0) == 0
    cont = np.concatenate([prompt, np.asarray(toks, np.int32)])
    q2 = r.submit((cont.copy(), 6), session_key="sess-2", kind="lm",
                  timeout_s=300.0)
    toks2 = r.wait(q2, 300.0)
    assert isinstance(toks2, list) and q2.replica_rid != home
    new_home = next(w for w in workers if w.rid == q2.replica_rid)
    assert new_home.backend.engine.metrics.snapshot() \
        .get("engine.prefix_hit_blocks", 0) == 0, "cold path hit the cache?"
    r.stop()


# ----------------------------------------------------------------------
# the wire hand-off over a real process boundary (slow: spawns two jax
# worker interpreters; runs in the kv-lifecycle-chaos CI job)

@pytest.mark.slow
def test_process_drain_publishes_kv_state_and_migrates():
    """Over the process transport the drain-time ("kv_state", state)
    frame must arrive before ("drained",) — FIFO channel order — and the
    router must ship it to the new home, which acks the import."""
    from repro.cluster import (MetricsRegistry, ReplicaConfig, Router,
                               engine_spec)

    r = Router(policy="session_affinity", metrics=MetricsRegistry())
    cfg = ReplicaConfig(max_batch=2, spawn_timeout_s=300.0)
    spec = engine_spec(arch="internlm2-1.8b", max_len=48, slots=2,
                       sync_every=4, paged=True, block_size=8,
                       kv_blocks=24, prefix_cache=True)
    workers = [r.add_replica(spec=spec, cfg=cfg, transport="process",
                             kind="lm") for _ in range(2)]
    rng = np.random.RandomState(23)
    prompt = rng.randint(0, 256, size=17).astype(np.int32)
    q = r.submit((prompt.copy(), 8), session_key="sess-3", kind="lm",
                 timeout_s=600.0)
    toks = r.wait(q, 600.0)
    assert isinstance(toks, list)
    home = q.replica_rid
    r.remove_replica(home, drain=True, migrate=True)
    snap = r.metrics.snapshot()
    assert snap.get("router.sessions_migrated", 0) >= 1, snap
    cont = np.concatenate([prompt, np.asarray(toks, np.int32)])
    q2 = r.submit((cont.copy(), 6), session_key="sess-3", kind="lm",
                  timeout_s=600.0)
    toks2 = r.wait(q2, 600.0)
    assert isinstance(toks2, list) and q2.replica_rid != home
    r.stop()
