"""Speculative multi-token decode: token-exact parity with the
non-speculative paged engine (itself parity-tested against the dense
fused oracle), draft acceptance semantics, and fallback gating.

The invariant under test is the acceptance rule: every emitted token is
the greedy argmax of a context consisting entirely of previously-emitted
tokens, so the output stream is bit-identical to non-speculative decode
no matter what the draft proposes — a perfect draft only changes *speed*
(all d tokens accepted per verify), a hostile draft only costs compute
(nothing accepted, one corrected token per verify).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import reduced
from repro.models import api, transformer as tfm
from repro.serving import Engine, ServeConfig
from repro.serving.kvpool import padded_table

# row-decoupled pageable families: speculation's verify windows are
# per-row independent (MoE expert capacity couples rows, so it falls back)
SPEC_FAMILIES = ["internlm2-1.8b",      # GQA 2:1 (reduced)
                 "gemma-7b"]            # MHA, tied embeddings


def _model(arch, seed=0):
    cfg = reduced(get_config(arch))
    params, _ = api.init(jax.random.PRNGKey(seed), cfg)
    return cfg, params


def _drain(params, cfg, scfg, prompts, max_new):
    eng = Engine(params, cfg, scfg)
    reqs = [eng.submit(p, max_new=max_new) for p in prompts]
    eng.run_until_drained()
    return eng, reqs


# ----------------------------------------------------------------------
# engine-level parity with the non-speculative paged oracle
@pytest.mark.parametrize("arch", SPEC_FAMILIES)
def test_spec_matches_paged_with_refill(arch):
    """5 requests through 2 slots: slots complete mid-K-loop and refill
    from the queue while other slots are mid-speculation; the emitted
    streams must match the non-speculative paged engine request-for-
    request."""
    cfg, params = _model(arch)
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab, size=n).astype(np.int32)
               for n in (5, 9, 7, 12, 6)]
    base = dict(max_len=64, slots=2, sync_every=4, paged=True, block_size=8)
    _, plain = _drain(params, cfg, ServeConfig(**base), prompts, max_new=6)
    seng, spec = _drain(params, cfg,
                        ServeConfig(speculative=True, **base),
                        prompts, max_new=6)
    assert seng.speculative
    for i, (a, b) in enumerate(zip(plain, spec)):
        assert a.out_tokens == b.out_tokens, (arch, i)
        assert a.finish_reason == b.finish_reason == "max_new"


def test_spec_truncation_parity():
    """max_len truncation fires at the same token even when it lands in
    the middle of a verify window (the emission cap clamps the accepted
    prefix; overshoot K/V past the cap is junk above pos, never read)."""
    cfg, params = _model("internlm2-1.8b")
    rng = np.random.RandomState(2)
    prompts = [rng.randint(0, cfg.vocab, size=n).astype(np.int32)
               for n in (4, 9)]
    base = dict(max_len=32, slots=2, sync_every=8, paged=True, block_size=8)
    _, plain = _drain(params, cfg, ServeConfig(**base), prompts,
                      max_new=100)
    _, spec = _drain(params, cfg, ServeConfig(speculative=True, **base),
                     prompts, max_new=100)
    for a, b in zip(plain, spec):
        assert a.out_tokens == b.out_tokens
        assert a.finish_reason == b.finish_reason == "max_len"


def test_spec_prefix_cache_parity():
    """A speculative engine admitting through prefix-cache hits backfills
    the draft history from the cached prompt tokens; streams stay exact."""
    cfg, params = _model("internlm2-1.8b")
    rng = np.random.RandomState(3)
    common = rng.randint(0, cfg.vocab, size=16).astype(np.int32)
    prompts = [np.concatenate([common,
                               rng.randint(0, cfg.vocab,
                                           n).astype(np.int32)])
               for n in (4, 3, 5)]
    base = dict(max_len=64, slots=2, sync_every=4, paged=True, block_size=8)
    _, plain = _drain(params, cfg, ServeConfig(**base),
                      [p.copy() for p in prompts], max_new=6)
    seng, spec = _drain(params, cfg,
                        ServeConfig(speculative=True, **base),
                        [p.copy() for p in prompts], max_new=6)
    assert seng.metrics.counter("engine.prefix_hit_blocks").value > 0
    for a, b in zip(plain, spec):
        assert a.out_tokens == b.out_tokens


def test_spec_moe_family_falls_back():
    """MoE couples batch rows through expert capacity, so speculation
    falls back to non-speculative paged decode — observably, with
    identical tokens."""
    cfg, params = _model("qwen3-moe-30b-a3b")
    rng = np.random.RandomState(4)
    prompts = [rng.randint(0, cfg.vocab, size=6).astype(np.int32)
               for _ in range(2)]
    base = dict(max_len=32, slots=2, sync_every=4, paged=True, block_size=8)
    _, plain = _drain(params, cfg, ServeConfig(**base), prompts, max_new=5)
    seng, spec = _drain(params, cfg,
                        ServeConfig(speculative=True, **base),
                        prompts, max_new=5)
    assert seng.paged and not seng.speculative
    assert seng.metrics.counter("engine.spec_fallback").value == 1
    for a, b in zip(plain, spec):
        assert a.out_tokens == b.out_tokens


def test_spec_config_validation():
    with pytest.raises(ValueError, match="paged"):
        ServeConfig(speculative=True)
    with pytest.raises(ValueError, match="greedy"):
        ServeConfig(speculative=True, paged=True, max_len=64, block_size=8,
                    temperature=0.7)
    with pytest.raises(ValueError, match="spec_draft"):
        ServeConfig(speculative=True, paged=True, max_len=64, block_size=8,
                    spec_draft=0)


# ----------------------------------------------------------------------
# draft-acceptance semantics (loop-level, injected draft oracles)
def _spec_loop_state(cfg, params, scfg, prompt, max_new):
    """A speculative engine advanced one sync, its slot-0 table fully
    pre-allocated so a direct spec_decode_loop call never writes through
    null-block padding."""
    eng = Engine(params, cfg, scfg)
    req = eng.submit(prompt, max_new=max_new)
    eng.step()
    assert not req.done
    # the engine's writeback is lazy — make the pool authoritative before
    # handing eng.caches to a direct loop call
    eng.flush_kv()
    sid = eng._seq_of_slot[0]
    eng.alloc.extend_to(sid, scfg.max_len)
    eng._bt[0] = padded_table(eng.alloc.table(sid), eng.nb_max)
    bt = jnp.asarray(eng._bt)
    return eng, req, bt


def _greedy_stream(cfg, params, scfg_base, prompt, max_new):
    """Ground truth: prompt ++ the non-speculative greedy continuation,
    as one position-indexed token array."""
    _, (ref,) = _drain(params, cfg, ServeConfig(**scfg_base),
                       [prompt.copy()], max_new=max_new)
    stream = np.concatenate([prompt,
                             np.asarray(ref.out_tokens, np.int32)])
    pad = np.zeros(scfg_base["max_len"], np.int32)
    pad[:len(stream)] = stream
    return pad, len(stream)


def test_spec_oracle_draft_accepts_all():
    """A draft that always proposes the true greedy continuation is fully
    accepted: every verify emits d+1 tokens and the stream is exact."""
    cfg, params = _model("internlm2-1.8b")
    base = dict(max_len=64, slots=1, sync_every=4, paged=True, block_size=8)
    prompt = np.random.RandomState(1).randint(
        0, cfg.vocab, size=6).astype(np.int32)
    stream, n_stream = _greedy_stream(cfg, params, base, prompt, max_new=40)
    scfg = ServeConfig(speculative=True, **base)
    eng, req, bt = _spec_loop_state(cfg, params, scfg, prompt.copy(),
                                    max_new=40)
    pos0 = int(np.asarray(eng._pos)[0])
    assert req.out_tokens == list(stream[len(prompt):pos0 + 1])
    sarr = jnp.asarray(stream[None])
    k, d = 3, scfg.spec_draft

    def oracle(hist, pos, last, dd):
        idx = jnp.clip(pos[:, None] + 1 + jnp.arange(dd)[None, :], 0,
                       scfg.max_len - 1)
        return jnp.take_along_axis(
            jnp.broadcast_to(sarr, (pos.shape[0], scfg.max_len)), idx,
            axis=1)

    (out, emitted, stats, *_rest) = tfm.spec_decode_loop(
        params, cfg, eng.caches, eng._hist, eng._pos, eng._last,
        eng._active, eng._remaining, eng._rng, k=k, d=d,
        max_len=scfg.max_len, bt=bt, draft_fn=oracle)
    acc, prop = (int(x) for x in np.asarray(stats))
    assert prop == k * d and acc == k * d          # everything accepted
    em = int(np.asarray(emitted)[0])
    assert em == k * (d + 1)
    want = stream[pos0 + 1:pos0 + 1 + em]
    assert pos0 + 1 + em <= n_stream
    np.testing.assert_array_equal(np.asarray(out)[0, :em], want)


def test_spec_adversarial_draft_accepts_none():
    """A draft that proposes impossible tokens is fully rejected: every
    verify still emits exactly one correct token (the non-speculative
    stream), nothing is accepted, and the cache stays coherent."""
    cfg, params = _model("internlm2-1.8b")
    base = dict(max_len=64, slots=1, sync_every=4, paged=True, block_size=8)
    prompt = np.random.RandomState(5).randint(
        0, cfg.vocab, size=6).astype(np.int32)
    stream, n_stream = _greedy_stream(cfg, params, base, prompt, max_new=40)
    scfg = ServeConfig(speculative=True, **base)
    eng, req, bt = _spec_loop_state(cfg, params, scfg, prompt.copy(),
                                    max_new=40)
    pos0 = int(np.asarray(eng._pos)[0])
    k, d = 3, scfg.spec_draft

    def hostile(hist, pos, last, dd):
        return jnp.full((pos.shape[0], dd), -1, jnp.int32)

    (out, emitted, stats, *_rest) = tfm.spec_decode_loop(
        params, cfg, eng.caches, eng._hist, eng._pos, eng._last,
        eng._active, eng._remaining, eng._rng, k=k, d=d,
        max_len=scfg.max_len, bt=bt, draft_fn=hostile)
    acc, prop = (int(x) for x in np.asarray(stats))
    assert prop == k * d and acc == 0              # nothing accepted
    em = int(np.asarray(emitted)[0])
    assert em == k                                 # 1 corrected token each
    want = stream[pos0 + 1:pos0 + 1 + em]
    assert pos0 + 1 + em <= n_stream
    np.testing.assert_array_equal(np.asarray(out)[0, :em], want)
