"""Transport layer: process workers with RPC inboxes, wire framing,
crash-spill over real process death (SIGKILL), worker-side metrics
aggregation, per-backend admission cost models, and the router property
that dead transports are never dispatch candidates.

Process tests use the echo BackendSpec (no jax in the worker) so spawn
cost is interpreter + numpy import only."""
import threading
import time

import numpy as np
import pytest

from tests._hyp_compat import given, settings, st

from repro.cluster import (AdmissionConfig, AdmissionController, Autoscaler,
                           AutoscalerConfig, BackendSpec, FnBackend,
                           LocalTransport, MetricsRegistry, ProcessTransport,
                           Rejected, ReplicaConfig, Router, Status,
                           echo_spec, make_transport, merge_snapshots)
from repro.cluster.replica import ClusterRequest
from repro.cluster.transport import decode_frame, encode_frame
from repro.core.partitioner import CostModel
from repro.core.service import MLaaSService

PROC_CFG = ReplicaConfig(inbox_capacity=256, max_batch=4)


# ----------------------------------------------------------------------
def test_frame_codec_roundtrips_plain_and_numpy():
    plain = ["req", 7, 3, {"a": [1, 2, 3], "b": "x"}]
    buf = encode_frame(plain)
    assert decode_frame(buf) == plain
    arr = np.arange(12, dtype=np.float32).reshape(3, 4)
    tagged = decode_frame(encode_frame(("req", 1, 1, arr)))
    assert tagged[0] == "req"
    np.testing.assert_array_equal(tagged[3], arr)
    # payload-carrying frames must round-trip type-exact: a tuple payload
    # stays a tuple (msgpack would flatten it to a list)
    exact = decode_frame(encode_frame(("req", 1, 1, (1, 2)), pickle_only=True))
    assert exact == ("req", 1, 1, (1, 2)) and isinstance(exact[3], tuple)


def test_backend_spec_builds_and_validates():
    b = echo_spec(delay_s=0.0, scale=3).build()
    assert b.process([1, 2]) == [3, 6]
    with pytest.raises(ValueError):
        BackendSpec("no.colon.in.target").build()
    with pytest.raises(ValueError):
        make_transport("process", backend=FnBackend(lambda ps: ps))
    with pytest.raises(ValueError):
        make_transport("carrier-pigeon", spec=echo_spec())


# ----------------------------------------------------------------------
def test_process_transport_round_trip_and_worker_metrics():
    m = MetricsRegistry()
    r = Router(policy="round_robin", metrics=m)
    for _ in range(2):
        r.add_replica(spec=echo_spec(delay_s=0.001), cfg=PROC_CFG,
                      transport="process")
    reqs = [r.submit(i) for i in range(24)]
    assert [r.wait(q, 30.0) for q in reqs] == [2 * i for i in range(24)]
    assert all(q.status is Status.OK for q in reqs)
    # composite payloads/results keep their exact types across the pipe
    tup = r.submit((1, 2))
    out = r.wait(tup, 30.0)
    assert out == (1, 2, 1, 2) and isinstance(out, tuple)
    # worker-side counters arrive via heartbeat snapshots and aggregate
    deadline = time.monotonic() + 5.0
    while time.monotonic() < deadline:
        snap = r.cluster_snapshot()
        if snap.get("replica.batch_s.count", 0) > 0:
            break
        time.sleep(0.05)
    assert snap["replica.batch_s.count"] > 0
    assert snap["router.completed"] == 25      # 24 ints + the tuple probe
    r.stop()
    assert r.n_alive() == 0


def test_process_crash_mid_batch_loses_zero_requests():
    """Kill the worker *process* (SIGKILL) mid-batch: every unacknowledged
    request must spill and complete on survivors — at-least-once, zero
    lost, across a real process boundary."""
    m = MetricsRegistry()
    r = Router(policy="round_robin", metrics=m, max_retries=3)
    workers = [r.add_replica(spec=echo_spec(delay_s=0.01), cfg=PROC_CFG,
                             transport="process")
               for _ in range(3)]
    reqs = [r.submit(i) for i in range(60)]
    time.sleep(0.02)                      # mid-load…
    workers[0].inject_crash()             # …SIGKILL one worker process
    results = [r.wait(q, 30.0) for q in reqs]
    assert all(q.status is Status.OK for q in reqs), \
        {q.status for q in reqs}
    assert results == [2 * i for i in range(60)]
    assert r.n_alive() == 2
    assert not workers[0].alive
    assert not workers[0]._proc.is_alive()
    snap = m.snapshot()
    assert snap["replica.crashes"] == 1
    assert snap["router.failed"] == 0
    r.stop()


def test_unpicklable_payload_sheds_without_killing_the_worker():
    """A payload that cannot cross the process boundary is refused at
    offer() (explicit shed), never sent, and never leaks outstanding cost."""
    r = Router()
    w = r.add_replica(spec=echo_spec(), cfg=PROC_CFG, transport="process")
    q = r.submit(threading.Lock(), timeout_s=5.0)
    assert q.status is Status.REJECTED and q.result.reason == "queue_full"
    assert w.outstanding_cost() == 0
    ok = r.submit(3)                      # replica still alive and serving
    assert r.wait(ok, 15.0) == 6
    r.stop()


def test_process_soft_crash_spills_before_ack():
    """The ("crash",) control frame: the worker raises at its next loop
    checkpoint instead of being SIGKILLed, exercising the in-worker
    crash-before-ack path across the pipe."""
    m = MetricsRegistry()
    r = Router(policy="round_robin", metrics=m, max_retries=3)
    workers = [r.add_replica(spec=echo_spec(delay_s=0.01), cfg=PROC_CFG,
                             transport="process")
               for _ in range(2)]
    reqs = [r.submit(i) for i in range(30)]
    time.sleep(0.02)
    workers[0].inject_crash(soft=True)
    results = [r.wait(q, 30.0) for q in reqs]
    assert all(q.status is Status.OK for q in reqs)
    assert results == [2 * i for i in range(30)]
    assert not workers[0].alive and r.n_alive() == 1
    assert m.snapshot()["replica.crashes"] == 1
    r.stop()


def test_kind_with_no_live_replica_sheds_explicitly():
    """Strict kind routing: a request whose backend kind has no live
    replica must shed, not fall back onto wrong-kind backends."""
    r = Router()
    r.add_replica(FnBackend(lambda ps: [p * 2 for p in ps]),
                  ReplicaConfig(), kind="svm")
    q = r.submit(1, kind="lm", timeout_s=5.0)
    assert q.status is Status.REJECTED and q.result.reason == "queue_full"
    ok = r.submit(2, kind="svm", timeout_s=5.0)
    assert r.wait(ok, 5.0) == 4
    r.stop()


def test_process_crash_with_no_survivors_fails_explicitly():
    r = Router()
    w = r.add_replica(spec=echo_spec(delay_s=0.2), cfg=PROC_CFG,
                      transport="process")
    reqs = [r.submit(i) for i in range(6)]
    w.inject_crash()
    for q in reqs:
        assert q.done.wait(15.0), "must fail explicitly, not hang"
    assert all(q.status is Status.FAILED for q in reqs)
    r.stop()


def test_process_drain_finishes_outstanding():
    r = Router()
    w = r.add_replica(spec=echo_spec(delay_s=0.002), cfg=PROC_CFG,
                      transport="process")
    reqs = [r.submit(i) for i in range(16)]
    r.remove_replica(w.rid, drain=True)
    for q in reqs:
        assert q.done.wait(15.0)
    assert all(q.status is Status.OK for q in reqs)
    assert [q.result for q in reqs] == [2 * i for i in range(16)]


def test_process_backend_exception_spills_to_survivors():
    """A worker whose backend raises dies like a thread replica: the batch
    spills and survivors absorb it."""
    r = Router(max_retries=3)
    bomb = BackendSpec("tests.test_transport:build_bomb", {"trip": 3})
    r.add_replica(spec=bomb, cfg=PROC_CFG, transport="process")
    r.add_replica(spec=echo_spec(delay_s=0.001), cfg=PROC_CFG,
                  transport="process")
    reqs = [r.submit(i) for i in range(20)]
    for q in reqs:
        assert q.done.wait(30.0)
    assert all(q.status is Status.OK for q in reqs)
    assert r.n_alive() == 1
    r.stop()


def build_bomb(trip: int = 3):
    """Module-level builder (spawn-importable): explodes on any payload
    >= ``trip``, echoing otherwise."""
    def step(payloads):
        if any(p >= trip for p in payloads):
            raise RuntimeError(f"bomb tripped at {trip}")
        return [p * 2 for p in payloads]
    return FnBackend(step)


def test_service_front_targets_process_cluster():
    r = Router(policy="least_loaded")
    for _ in range(2):
        r.add_replica(spec=echo_spec(), cfg=PROC_CFG, transport="process")
    svc = MLaaSService(router=r, capacity=4).start()
    reqs = [svc.submit(i, timeout_s=15.0) for i in range(12)]
    for q in reqs:
        assert q.done.wait(15.0)
    svc.stop()
    r.stop()
    assert [q.result for q in reqs] == [2 * i for i in range(12)]


def test_autoscaler_scales_up_with_process_transport():
    gate_delay = 0.05
    r = Router(policy="least_loaded")
    r.add_replica(spec=echo_spec(delay_s=gate_delay), cfg=PROC_CFG,
                  transport="process")
    sc = Autoscaler(r, lambda: echo_spec(delay_s=gate_delay),
                    AutoscalerConfig(max_replicas=2, cooldown_s=0.0,
                                     scale_up_depth=4.0,
                                     replica_cfg=PROC_CFG),
                    transport="process")
    reqs = [r.submit(i) for i in range(30)]
    ev = sc.tick()
    assert ev and ev.action == "up" and r.n_alive() == 2
    assert isinstance(r.alive_replicas()[0], ProcessTransport)
    for q in reqs:
        assert q.done.wait(30.0)
    r.stop()


# ----------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(st.integers(0, 6), st.sampled_from(["round_robin", "least_loaded",
                                           "session_affinity"]),
       st.integers(0, 99))
def test_router_never_ranks_a_dead_transport(dead_mask, policy, key):
    """Property: whatever the policy and whichever replicas have died,
    the dispatch preference order contains only alive transports."""
    r = Router(policy=policy)
    transports = []
    for i in range(3):
        t = LocalTransport(FnBackend(lambda ps: ps), ReplicaConfig())
        t.alive = not (dead_mask >> i) & 1    # died without starting a thread
        r._replicas[t.rid] = t
        transports.append(t)
    req = ClusterRequest(payload=0, session_key=f"user-{key}", rid=key)
    ranked = r._ranked(req)
    assert all(t.alive for t in ranked)
    alive = [t for t in transports if t.alive]
    assert sorted(t.rid for t in ranked) == sorted(t.rid for t in alive)
    if not alive:
        # dispatch must shed explicitly, never hang or pick a corpse
        q = r.submit(1, timeout_s=5.0)
        assert q.status is Status.REJECTED and q.result.reason == "queue_full"


# ----------------------------------------------------------------------
def test_admission_uses_per_backend_cost_models():
    lm_cm = CostModel(overhead_s=0.0, per_item_s=0.5, r2=1.0)    # 0.5 s/token
    svm_cm = CostModel(overhead_s=0.0, per_item_s=1e-4, r2=1.0)  # 0.1 ms/row
    ctrl = AdmissionController(AdmissionConfig(
        max_queue_cost=10_000,
        cost_models={"lm": lm_cm, "svm": svm_cm}))
    now = 0.0
    # 100 cost units in 1s: infeasible for LM tokens, trivial for SVM rows
    shed = ctrl.decide(0, 100, deadline_s=1.0, now=now, kind="lm")
    assert shed is not None and shed.reason == "deadline"
    assert ctrl.decide(0, 100, deadline_s=1.0, now=now, kind="svm") is None
    # unknown kind falls back to the global model (none here -> admit)
    assert ctrl.decide(0, 100, deadline_s=1.0, now=now, kind="vlm") is None


def test_router_routes_by_kind_and_sheds_per_kind_queue():
    """Per-kind admission sees only that backend's queue: a deep LM queue
    must not shed SVM traffic."""
    lm_cm = CostModel(overhead_s=0.0, per_item_s=1.0, r2=1.0)
    ctrl = AdmissionController(AdmissionConfig(
        max_queue_cost=10_000, cost_models={"lm": lm_cm}))
    gate = threading.Event()

    def gated(payloads):
        assert gate.wait(10.0)
        return [p * 2 for p in payloads]

    r = Router(policy="least_loaded", admission=ctrl)
    r.add_replica(FnBackend(gated), ReplicaConfig(inbox_capacity=256),
                  kind="lm")
    r.add_replica(FnBackend(gated), ReplicaConfig(inbox_capacity=256),
                  kind="svm")
    # pile cost onto the LM replica
    lm_reqs = [r.submit(i, cost=5, kind="lm", timeout_s=60.0)
               for i in range(4)]
    assert r.queue_depth("lm") >= 15 and r.queue_depth("svm") == 0
    # an LM request with a tight deadline sheds (queued lm cost is huge)...
    shed = r.submit(99, cost=1, kind="lm", timeout_s=2.0)
    assert shed.status is Status.REJECTED and shed.result.reason == "deadline"
    # ...but SVM traffic with the same deadline is admitted: its queue is
    # empty and it has no slow cost model
    ok = r.submit(7, cost=1, kind="svm", timeout_s=2.0)
    assert ok.status is Status.PENDING
    gate.set()
    for q in lm_reqs + [ok]:
        assert q.done.wait(15.0)
    assert ok.status is Status.OK and ok.replica_rid is not None
    r.stop()


def test_merge_snapshots_counters_sum_means_weight_percentiles_max():
    """Legacy (bucketless) snapshots keep the old conservative behavior:
    percentiles merge as a max upper bound."""
    base = {"replica.batch_s.count": 10.0, "replica.batch_s.mean": 2.0,
            "replica.batch_s.p95": 5.0, "replica.crashes": 1.0}
    w1 = {"replica.batch_s.count": 30.0, "replica.batch_s.mean": 4.0,
          "replica.batch_s.p95": 9.0, "replica.crashes": 2.0}
    w2 = {"only.in.worker": 3.0}
    out = merge_snapshots(base, [w1, w2])
    assert out["replica.batch_s.count"] == 40.0
    assert out["replica.batch_s.mean"] == pytest.approx(
        (10 * 2.0 + 30 * 4.0) / 40)
    assert out["replica.batch_s.p95"] == 9.0
    assert out["replica.crashes"] == 3.0
    assert out["only.in.worker"] == 3.0


def test_merge_snapshots_bucketed_percentiles_match_ground_truth():
    """Snapshots that ship histogram bucket counts merge to true
    cluster-wide percentiles (up to the 10^(1/4)x bucket resolution) —
    not the max-across-workers upper bound.  Two workers with disjoint
    latency regimes make the difference stark: the max-merge answer would
    be the slow worker's percentile regardless of traffic mix."""
    from repro.cluster.metrics import HIST_BUCKET_BOUNDS  # noqa: F401
    rng = np.random.RandomState(0)
    fast, slow = MetricsRegistry(), MetricsRegistry()
    x_fast = rng.lognormal(-4.0, 0.6, 6000)    # ~18ms median worker
    x_slow = rng.lognormal(-1.0, 0.4, 1500)    # ~370ms median worker
    for v in x_fast:
        fast.histogram("replica.batch_s").observe(v)
    for v in x_slow:
        slow.histogram("replica.batch_s").observe(v)
    merged = merge_snapshots(fast.snapshot(), [slow.snapshot()])
    combined = np.concatenate([x_fast, x_slow])
    resolution = 10 ** 0.25
    for p in (50, 95, 99):
        truth = float(np.percentile(combined, p))
        est = merged[f"replica.batch_s.p{p}"]
        assert truth / resolution <= est <= truth * resolution, \
            f"p{p}: merged {est:.4f} vs truth {truth:.4f}"
    # the old behavior would have reported the slow worker's p50 (~0.37s)
    # as the cluster p50; the merged estimate must reflect the fast bulk
    assert merged["replica.batch_s.p50"] < 0.1
    assert merged["replica.batch_s.count"] == 7500.0
    # a percentile landing beyond the last bucket bound (e.g. first-batch
    # compiles) must not clamp down to the bound: the conservative
    # max-merge of the workers' exact percentiles stands instead
    base2, over = MetricsRegistry(), MetricsRegistry()
    for _ in range(100):
        base2.histogram("x").observe(0.01)
    for _ in range(200):
        over.histogram("x").observe(2000.0)     # past the last bound
    m2 = merge_snapshots(base2.snapshot(), [over.snapshot()])
    assert m2["x.p99"] == pytest.approx(2000.0)
    assert m2["x.p50"] == pytest.approx(2000.0)  # true combined median


def test_merge_snapshots_mixed_bucketed_and_legacy_is_conservative():
    """One worker ships bucket counts, another (older build) ships only
    count/mean/percentiles for the *same* stem: recomputing percentiles
    from the buckets alone would silently drop the legacy worker's
    observations from the estimate.  The merge must detect the mix and
    fall back to the conservative max-merge for that stem — while a stem
    that is bucketed everywhere still recomputes — and stay deterministic
    across input order."""
    bucketed = MetricsRegistry()
    for _ in range(100):
        bucketed.histogram("replica.batch_s").observe(0.01)
    for _ in range(50):
        bucketed.histogram("clean.stem").observe(0.02)
    legacy = {"replica.batch_s.count": 900.0,
              "replica.batch_s.mean": 5.0,
              "replica.batch_s.p50": 5.0, "replica.batch_s.p95": 8.0,
              "replica.batch_s.p99": 9.0}
    out = merge_snapshots(bucketed.snapshot(), [legacy])
    # counts/means always merge exactly
    assert out["replica.batch_s.count"] == 1000.0
    assert out["replica.batch_s.mean"] == pytest.approx(
        (100 * 0.01 + 900 * 5.0) / 1000.0)
    # the legacy worker dominates the distribution (900 of 1000 samples at
    # ~5s); a bucket-only recompute would report ~0.01s.  Conservative
    # max-merge keeps its percentiles on the board.
    assert out["replica.batch_s.p50"] == pytest.approx(5.0)
    assert out["replica.batch_s.p95"] == pytest.approx(8.0)
    # the all-bucketed stem still gets the true recompute
    assert out["clean.stem.count"] == 50.0
    assert 0.02 / (10 ** 0.25) <= out["clean.stem.p50"] <= 0.02 * 10 ** 0.25
    # deterministic under worker order (dict/set iteration must not leak)
    out2 = merge_snapshots(bucketed.snapshot(), [dict(legacy)])
    assert out == out2
    # an *empty* bucketed snapshot for the stem (count 0, no observations
    # yet) must not demote an otherwise-bucketed merge to legacy mode
    empty = MetricsRegistry()
    empty.histogram("clean.stem")               # registered, never observed
    out3 = merge_snapshots(bucketed.snapshot(), [empty.snapshot()])
    assert 0.02 / (10 ** 0.25) <= out3["clean.stem.p50"] <= 0.02 * 10 ** 0.25


def test_histogram_stats_are_torn_read_free():
    """count/sum/mean and snapshot() must come from one consistent view:
    under concurrent observers, mean*count == sum exactly and the bucket
    counts total the count — a torn read (count bumped, sum not yet) shows
    up as a violated identity."""
    reg = MetricsRegistry()
    h = reg.histogram("t.x")
    stop = threading.Event()

    def writer():
        while not stop.is_set():
            h.observe(0.125)                   # exact in binary: sum is
                                               # count * 0.125 precisely

    threads = [threading.Thread(target=writer) for _ in range(4)]
    for t in threads:
        t.start()
    try:
        for _ in range(500):
            st = h.stats()
            assert st["sum"] == st["count"] * 0.125, \
                f"torn read: count={st['count']} sum={st['sum']}"
            if st["count"]:
                assert st["mean"] == 0.125
            assert sum(st["buckets"]) == st["count"]
            snap = reg.snapshot()
            total = sum(v for k, v in snap.items()
                        if k.startswith("t.x.le"))
            assert total == snap["t.x.count"]
            assert snap["t.x.mean"] * snap["t.x.count"] == \
                snap["t.x.count"] * 0.125
    finally:
        stop.set()
        for t in threads:
            t.join()
    assert h.sum == h.count * 0.125 and h.mean() == 0.125


def test_cluster_snapshot_merges_worker_buckets_over_heartbeat():
    """End to end over a real remote worker: the worker's bucket counts
    arrive via the heartbeat channel and the router's cluster_snapshot
    recomputes percentiles from them instead of taking a max."""
    m = MetricsRegistry()
    r = Router(metrics=m)
    r.add_replica(spec=echo_spec(delay_s=0.002), cfg=PROC_CFG,
                  transport="process")
    reqs = [r.submit(i) for i in range(12)]
    assert all(r.wait(q, 30.0) is not None for q in reqs)
    deadline = time.monotonic() + 5.0
    snap = {}
    while time.monotonic() < deadline:
        snap = r.cluster_snapshot()
        if snap.get("replica.batch_s.count", 0) > 0:
            break
        time.sleep(0.05)
    assert snap["replica.batch_s.count"] > 0
    bucket_keys = [k for k in snap if k.startswith("replica.batch_s.le")]
    assert bucket_keys, "worker heartbeat must ship bucket counts"
    assert sum(snap[k] for k in bucket_keys) == snap["replica.batch_s.count"]
    assert snap["replica.batch_s.p95"] > 0
    r.stop()


def test_service_request_done_is_a_real_event_field():
    from repro.core.service import ServiceRequest
    import dataclasses as dc
    names = [f.name for f in dc.fields(ServiceRequest)]
    assert "done" in names, "done must be a dataclass field, not a class attr"
    a, b = ServiceRequest(1, deadline_s=0.0), ServiceRequest(2, deadline_s=0.0)
    assert isinstance(a.done, threading.Event)
    assert a.done is not b.done, "each request needs its own Event"
