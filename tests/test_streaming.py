"""Per-K-step token streaming: Engine.on_tokens fires at every host sync,
and run_replica_loop forwards partial-token frames through the transports
instead of quantizing to whole-request acks.
"""
import queue
import time

import jax
import numpy as np
import pytest

from repro.cluster import (EngineBackend, ReplicaConfig, Router)
from repro.cluster.replica import FnBackend
from repro.configs import get_config
from repro.configs.base import reduced
from repro.models import api
from repro.serving import Engine, ServeConfig


def _model(arch="internlm2-1.8b", seed=0):
    cfg = reduced(get_config(arch))
    params, _ = api.init(jax.random.PRNGKey(seed), cfg)
    return cfg, params


# ----------------------------------------------------------------------
# engine-level callback
@pytest.mark.parametrize("paged", [False, True])
def test_on_tokens_streams_at_sync_cadence(paged):
    """Every token arrives through on_tokens exactly once, in order, with
    at most sync_every tokens per callback and done=True on the last."""
    cfg, params = _model()
    scfg = ServeConfig(max_len=64, slots=2, fused=True, sync_every=4,
                       paged=paged, block_size=8)
    eng = Engine(params, cfg, scfg)
    rng = np.random.RandomState(0)
    frames = []
    req = eng.submit(rng.randint(0, cfg.vocab, 6).astype(np.int32),
                     max_new=10,
                     on_tokens=lambda r, toks, done:
                     frames.append((list(toks), done)))
    eng.run_until_drained()
    streamed = [t for toks, _ in frames for t in toks]
    assert streamed == req.out_tokens
    assert len(frames) >= 3                    # 1 admit + >=2 K-step syncs
    assert all(len(toks) <= scfg.sync_every for toks, _ in frames)
    assert [d for _, d in frames] == [False] * (len(frames) - 1) + [True]


def test_on_tokens_reference_path_per_token():
    cfg, params = _model()
    eng = Engine(params, cfg, ServeConfig(max_len=64, slots=1, fused=False))
    frames = []
    req = eng.submit(np.arange(5, dtype=np.int32), max_new=4,
                     on_tokens=lambda r, toks, done:
                     frames.append((list(toks), done)))
    eng.run_until_drained()
    assert [t for toks, _ in frames for t in toks] == req.out_tokens
    assert all(len(toks) == 1 for toks, _ in frames)


def test_on_tokens_exception_does_not_kill_engine():
    cfg, params = _model()
    eng = Engine(params, cfg, ServeConfig(max_len=64, slots=1))

    def boom(r, toks, done):
        raise RuntimeError("consumer bug")

    req = eng.submit(np.arange(5, dtype=np.int32), max_new=3,
                     on_tokens=boom)
    eng.run_until_drained()
    assert req.done and len(req.out_tokens) == 4
    assert eng.metrics.counter("engine.stream_errors").value > 0


# ----------------------------------------------------------------------
# transport forwarding
def test_thread_replica_streams_partial_frames():
    """EngineBackend behind a LocalTransport: partial frames reach the
    ClusterRequest while it is still in flight, and concatenate to the
    final result."""
    cfg, params = _model()
    scfg = ServeConfig(max_len=64, slots=2, fused=True, sync_every=4)
    router = Router(policy="round_robin")
    router.add_replica(EngineBackend(Engine(params, cfg, scfg)),
                       ReplicaConfig(max_batch=2))
    rng = np.random.RandomState(1)
    got = queue.Queue()
    req = router.submit((rng.randint(0, cfg.vocab, 6).astype(np.int32), 9),
                        on_partial=got.put, timeout_s=120.0)
    out = router.wait(req, timeout=120.0)
    router.stop()
    frames = list(req.partials)
    assert len(frames) >= 3                    # streamed, not one lump
    streamed = [t for toks, _ in frames for t in toks]
    assert streamed == list(out)
    assert frames[-1][1] is True               # final frame marks done


def test_process_replica_streams_partial_frames():
    """The same frames cross the process transport's pipe as ("partial",
    rid, frame) messages and fire the parent-side callback before the
    ack completes the request."""
    cfg, _ = _model()
    from repro.cluster import engine_spec
    router = Router(policy="round_robin")
    router.add_replica(
        spec=engine_spec(arch="internlm2-1.8b", max_len=64, slots=2,
                         reduce=True, sync_every=4),
        cfg=ReplicaConfig(max_batch=2, spawn_timeout_s=300.0),
        transport="process")
    rng = np.random.RandomState(2)
    seen_at = []
    req = router.submit((rng.randint(0, cfg.vocab, 6).astype(np.int32), 9),
                        on_partial=lambda f: seen_at.append(
                            (time.monotonic(), f)),
                        timeout_s=300.0)
    out = router.wait(req, timeout=300.0)
    router.stop()
    assert isinstance(out, list) and len(out) == 10
    assert len(seen_at) >= 3
    streamed = [t for _, (toks, _) in seen_at for t in toks]
    assert streamed == out
    # partials landed strictly before completion
    assert seen_at[0][0] <= req.finished_s


def test_spilled_request_resets_partial_frames():
    """At-least-once streaming: a replica crash mid-stream re-runs the
    request elsewhere from token 0.  The router clears the frame buffer
    and signals consumers with RETRY_FRAME so they discard the first
    attempt's prefix instead of rendering it twice."""
    from repro.cluster.replica import ClusterRequest

    frames = []
    req = ClusterRequest(payload=("p", 4), on_partial=frames.append)
    req.emit_partial(([1, 2], False))
    req.emit_partial(([3], False))
    assert len(req.partials) == 2
    req.reset_partials()                       # what _on_spill does
    assert req.partials == []
    assert frames[-1] == ClusterRequest.RETRY_FRAME
    # the retry re-streams; the buffer now reflects only attempt 2
    req.emit_partial(([1, 2], False))
    assert req.partials == [([1, 2], False)]
    # a request that never streamed gets no retry signal
    quiet = ClusterRequest(payload=("q", 1), on_partial=frames.append)
    n = len(frames)
    quiet.reset_partials()
    assert len(frames) == n


def test_fn_backend_without_emitter_still_acks():
    """Backends that never bind an emitter are unaffected by the
    streaming surface."""
    router = Router()
    router.add_replica(FnBackend(lambda ps: [p * 2 for p in ps]))
    req = router.submit(21, timeout_s=30.0)
    assert router.wait(req, timeout=30.0) == 42
    assert req.partials == []
    router.stop()
