"""Property tests on the model substrate (hypothesis where useful)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import given, settings, st

from repro.models.attention import (apply_rope, causal_mask,
                                    flash_attention_jnp, mha)
from repro.models.layers import rms_norm, layer_norm
from repro.models.ssm import selective_scan
from repro.models.rglru import diag_scan


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 16), st.sampled_from([32, 64, 128]))
def test_rope_preserves_norm(pos, hd):
    """RoPE is a rotation: vector norms are invariant."""
    x = jax.random.normal(jax.random.PRNGKey(pos % 97), (1, 1, 1, hd))
    y = apply_rope(x, jnp.array([[pos]]), 10_000.0)
    np.testing.assert_allclose(jnp.linalg.norm(y), jnp.linalg.norm(x),
                               rtol=1e-5)


def test_rope_relative_position_property():
    """<rope(q,m), rope(k,n)> depends only on m - n."""
    hd = 64
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, hd))

    def score(m, n):
        qm = apply_rope(q, jnp.array([[m]]), 10_000.0)
        kn = apply_rope(k, jnp.array([[n]]), 10_000.0)
        return float(jnp.sum(qm * kn))

    np.testing.assert_allclose(score(5, 3), score(105, 103), rtol=1e-4)
    np.testing.assert_allclose(score(17, 0), score(1017, 1000), rtol=1e-4)


@settings(max_examples=20, deadline=None)
@given(st.floats(0.1, 10.0), st.integers(0, 1000))
def test_rms_norm_scale_invariance(scale, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, 8))
    w = jnp.ones(8)
    a = rms_norm(x, w)
    b = rms_norm(x * scale, w)
    # exact invariance is broken only by eps; bound is eps/(scale^2 * ms)
    np.testing.assert_allclose(a, b, atol=2e-3)


def test_layer_norm_shift_scale_invariance():
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 16))
    a = layer_norm(x, jnp.ones(16), jnp.zeros(16))
    b = layer_norm(x * 3.0 + 7.0, jnp.ones(16), jnp.zeros(16))
    np.testing.assert_allclose(a, b, atol=1e-4)


# ----------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(st.sampled_from([(64, 16, 16), (128, 32, 64), (96, 32, 32)]),
       st.integers(0, 100))
def test_flash_equals_masked_attention(shapes, seed):
    S, qc, kc = shapes
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(jax.random.fold_in(key, 0), (1, S, 4, 32))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, S, 2, 32))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, S, 2, 32))
    ref = mha(q, k, v, causal_mask(S, S)[None, None])
    out = flash_attention_jnp(q, k, v, causal=True, q_chunk=qc, kv_chunk=kc)
    np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-5)


def test_local_attention_window_property():
    """Changing tokens OUTSIDE the window must not affect a query's output."""
    S, W = 128, 16
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(jax.random.fold_in(key, 0), (1, S, 2, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, S, 2, 16))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, S, 2, 16))
    out1 = flash_attention_jnp(q, k, v, causal=True, window=W,
                               q_chunk=32, kv_chunk=16)
    # perturb k/v at positions far before the last query's window
    k2 = k.at[:, :S - W - 32].set(jax.random.normal(jax.random.fold_in(key, 9),
                                                    (1, S - W - 32, 2, 16)))
    v2 = v.at[:, :S - W - 32].set(0.0)
    out2 = flash_attention_jnp(q, k2, v2, causal=True, window=W,
                               q_chunk=32, kv_chunk=16)
    np.testing.assert_allclose(out1[:, -1], out2[:, -1], atol=1e-5)


# ----------------------------------------------------------------------
def test_selective_scan_matches_naive():
    B, S, D, N = 1, 40, 8, 4
    key = jax.random.PRNGKey(4)
    xc = jax.random.normal(jax.random.fold_in(key, 0), (B, S, D))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1), (B, S, D)))
    Bc = jax.random.normal(jax.random.fold_in(key, 2), (B, S, N))
    Cc = jax.random.normal(jax.random.fold_in(key, 3), (B, S, N))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 4), (D, N)))
    Dd = jnp.ones(D)
    y, hT = selective_scan(xc, dt, Bc, Cc, A, Dd, chunk=16)

    # naive per-step recurrence
    h = np.zeros((B, D, N), np.float32)
    ys = []
    a_bar = np.asarray(jnp.exp(dt[..., None] * A[None, None]))
    b_bar = np.asarray((dt * xc)[..., None] * Bc[:, :, None, :])
    for t in range(S):
        h = a_bar[:, t] * h + b_bar[:, t]
        ys.append(np.einsum("bdn,bn->bd", h, np.asarray(Cc[:, t]))
                  + np.asarray(xc[:, t]) * np.asarray(Dd))
    np.testing.assert_allclose(y, np.stack(ys, 1), atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(hT, h, atol=1e-3, rtol=1e-3)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 60), st.integers(4, 64), st.integers(0, 50))
def test_diag_scan_matches_naive(S, chunk, seed):
    key = jax.random.PRNGKey(seed)
    a = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(key, 0), (1, S, 4)))
    b = jax.random.normal(jax.random.fold_in(key, 1), (1, S, 4)) * 0.3
    hs, hT = diag_scan(a, b, chunk=chunk)
    h = np.zeros((1, 4), np.float32)
    outs = []
    for t in range(S):
        h = np.asarray(a[:, t]) * h + np.asarray(b[:, t])
        outs.append(h.copy())
    np.testing.assert_allclose(hs, np.stack(outs, 1), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(hT, h, atol=1e-4, rtol=1e-4)


def test_moe_capacity_monotone_drops():
    """Higher capacity factor => no more drops; outputs converge."""
    from repro.configs import get_config
    from repro.configs.base import reduced
    from repro.models import moe as moe_mod
    from repro.core.sharding import split_params
    cfg = reduced(get_config("qwen3-moe-30b-a3b"))
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 16, cfg.d_model))
    outs = []
    for cf in (1.0, 4.0, 64.0):
        c = cfg.replace(capacity_factor=cf)
        params, _ = split_params(
            {"m": moe_mod.init_moe(jax.random.PRNGKey(1), c)})
        out, _ = moe_mod.apply_moe(params["m"], x, c)
        outs.append(out)
    # at cf=4 and cf=64 routing is drop-free for 16 tokens -> identical
    np.testing.assert_allclose(outs[1], outs[2], atol=1e-5)
