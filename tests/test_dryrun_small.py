"""CI-scale dry-run: the full lower+compile+roofline path on a small forced-
device mesh, one cell per family (subprocess owns its XLA_FLAGS)."""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import sys, json
import jax
from repro.launch import dryrun_lib

from repro.launch.mesh import compat_make_mesh
mesh = compat_make_mesh((4, 4), ("data", "model"))
arch, shape = sys.argv[1], sys.argv[2]
res = dryrun_lib.run_cell(arch, shape, mesh)
print("RESULT " + json.dumps(res.to_json()))
"""


def run_cell(arch, shape):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run([sys.executable, "-c", SCRIPT, arch, shape],
                       env=env, capture_output=True, text=True, timeout=3000)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT "):])


@pytest.mark.slow
@pytest.mark.parametrize("arch,shape", [
    ("internlm2-1.8b", "decode_32k"),      # dense decode path
    ("whisper-base", "train_4k"),          # enc-dec + padded vocab
    ("internvl2-1b", "decode_32k"),        # vlm + padded vocab
])
def test_dryrun_cell_small_mesh(arch, shape):
    d = run_cell(arch, shape)
    assert d["ok"], d["error"]
    if not d["skipped"]:
        assert d["flops_dev"] > 0
        assert d["dominant"] in ("compute", "memory", "collective")
        assert 0 < d["useful_ratio"] <= 2.0
