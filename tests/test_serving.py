"""Continuous-batching engine: equivalence with sequential generation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import reduced
from repro.models import api, transformer as tfm
from repro.serving import Engine, ServeConfig


def greedy_reference(params, cfg, prompt, max_new):
    """Sequential prefill+decode, one request at a time."""
    caches = api.init_caches(cfg, 1, 128)
    logits, caches = tfm.prefill(params, cfg, jnp.asarray(prompt[None]), caches)
    toks = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    while len(toks) < max_new:
        lg, caches = tfm.decode_step(params, cfg, jnp.asarray([[toks[-1]]]),
                                     caches, jnp.asarray([pos], jnp.int32))
        toks.append(int(jnp.argmax(lg[0, 0])))
        pos += 1
    return toks


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "falcon-mamba-7b"])
def test_engine_matches_sequential(arch):
    cfg = reduced(get_config(arch))
    params, _ = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab, size=n).astype(np.int32)
               for n in (5, 9, 7)]

    eng = Engine(params, cfg, ServeConfig(max_len=128, slots=2))
    reqs = [eng.submit(p, max_new=6) for p in prompts]
    eng.run_until_drained()
    assert all(r.done for r in reqs)

    for p, r in zip(prompts, reqs):
        want = greedy_reference(params, cfg, p, 6)
        assert r.out_tokens[:6] == want, (arch, r.out_tokens, want)


def test_engine_more_requests_than_slots():
    cfg = reduced(get_config("internlm2-1.8b"))
    params, _ = api.init(jax.random.PRNGKey(1), cfg)
    rng = np.random.RandomState(1)
    eng = Engine(params, cfg, ServeConfig(max_len=64, slots=2))
    reqs = [eng.submit(rng.randint(0, cfg.vocab, size=4).astype(np.int32),
                       max_new=3) for _ in range(5)]
    done = eng.run_until_drained()
    assert len(done) == 5
    assert all(len(r.out_tokens) >= 3 for r in reqs)
    # latency accounting present
    assert all(r.done_t >= r.first_token_t >= r.submit_t > 0 for r in reqs)
