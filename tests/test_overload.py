"""End-to-end resilience control plane: deadline propagation and expiry,
cancellation, retry budgets (backoff + poison detection), replica circuit
breaking, graded brownout, bounded artifact-fetch retry, and the
finish-reason taxonomy every terminal path must land in.

Engine-level taxonomy tests use a reduced LM engine (jax); everything else
runs over plain-function backends so the concurrency machinery is what's
under test.  Randomized overload-chaos episodes carry the ``slow`` marker
(CI runs them in a dedicated job).
"""
import random
import threading
import time
import types

import jax
import numpy as np
import pytest

from repro.cluster import (ArtifactStore, BackendSpec, BreakerConfig,
                           BrownoutConfig, BrownoutController,
                           CircuitBreaker, FnBackend, MetricsRegistry,
                           ReplicaConfig, Router, Status, WaitTimeout,
                           artifact_ref, echo_spec, fetch_with_retry,
                           prometheus_text, resolve_spec)
from repro.cluster.artifacts import sha256_bytes
from repro.cluster.replica import ClusterRequest, EngineBackend
from repro.cluster.tracing import FlightRecorder, set_recorder
from repro.configs import get_config
from repro.configs.base import reduced
from repro.models import api
from repro.serving import Engine, ServeConfig

#: every reason a request can terminate with — the engine's decode-side
#: taxonomy plus the cluster-side resilience reasons
FINISH_REASONS = {"max_new", "max_len", "rejected_prompt_too_long",
                  "kv_pool_exhausted", "deadline", "cancelled", "poison"}


class _Clock:
    """Injectable monotonic clock: tests never sleep through cooldowns."""

    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t


def echo(delay: float = 0.0):
    def step(payloads):
        if delay:
            time.sleep(delay)
        return [p * 2 for p in payloads]
    return FnBackend(step)


def gated(event: threading.Event):
    def step(payloads):
        assert event.wait(10.0), "gate never opened"
        return [p * 2 for p in payloads]
    return FnBackend(step)


# ----------------------------------------------------------------------
# circuit breaker

def test_breaker_trips_after_crash_window_and_probes_half_open():
    clk = _Clock()
    cb = CircuitBreaker(BreakerConfig(crash_threshold=3, window_s=10.0,
                                      cooldown_s=5.0), clock=clk)
    assert cb.allow(1)
    assert not cb.record_crash(1)
    assert not cb.record_crash(1)
    assert cb.record_crash(1), "third crash in the window must trip"
    assert cb.state(1) == "open"
    assert not cb.allow(1), "quarantined during cooldown"
    clk.t = 5.0
    assert cb.allow(1), "cooldown over: eligible for the probe"
    # ranking alone must not consume the probe (allow is side-effect free)
    assert cb.allow(1) and cb.state(1) == "open"
    cb.note_dispatch(1)
    assert cb.state(1) == "half_open"
    assert not cb.allow(1), "only the one probe flies while half-open"
    cb.record_ack(1)
    assert cb.state(1) == "closed" and cb.allow(1)


def test_breaker_probe_failure_reopens_with_fresh_cooldown():
    clk = _Clock()
    cb = CircuitBreaker(BreakerConfig(crash_threshold=2, window_s=10.0,
                                      cooldown_s=5.0), clock=clk)
    cb.record_crash(1)
    assert cb.record_crash(1)
    clk.t = 5.0
    cb.note_dispatch(1)
    assert cb.state(1) == "half_open"
    assert cb.record_crash(1), "a dying probe re-trips"
    assert cb.state(1) == "open"
    assert not cb.allow(1)
    clk.t = 9.9
    assert not cb.allow(1), "cooldown restarted at the probe failure"
    clk.t = 10.0
    assert cb.allow(1)


def test_breaker_ignores_crashes_outside_window():
    clk = _Clock()
    cb = CircuitBreaker(BreakerConfig(crash_threshold=3, window_s=10.0),
                        clock=clk)
    for t in (0.0, 20.0, 40.0):      # spread wider than the window
        clk.t = t
        assert not cb.record_crash(1)
    assert cb.state(1) == "closed" and cb.allow(1)


def test_breaker_forget_clears_state():
    cb = CircuitBreaker(BreakerConfig(crash_threshold=1), clock=_Clock())
    assert cb.record_crash(1)
    cb.forget(1)
    assert cb.state(1) == "closed" and cb.allow(1)


# ----------------------------------------------------------------------
# brownout ladder

def test_brownout_one_rung_per_tick_with_hysteresis():
    bo = BrownoutController()
    assert bo.tick(0.95) == 1 and bo.changed
    assert bo.tick(0.95) == 2 and bo.changed
    assert bo.tick(0.95) == 3 and bo.changed
    assert bo.tick(0.95) == 3 and not bo.changed, "ladder tops out"
    # inside the hysteresis band (below enter[2]=0.90, above exit[2]=0.75):
    # the level holds instead of flapping
    assert bo.tick(0.80) == 3 and not bo.changed
    assert bo.tick(0.70) == 2 and bo.changed
    assert bo.tick(0.65) == 2 and not bo.changed   # band for level 2
    assert bo.tick(0.50) == 1 and bo.changed
    assert bo.tick(0.40) == 0 and bo.changed
    assert bo.tick(0.40) == 0 and not bo.changed


def test_brownout_pressure_is_max_of_queue_and_kv():
    bo = BrownoutController()
    assert bo.tick(0.0, kv_used_frac=0.95) == 1, \
        "KV occupancy alone must raise the level"
    assert bo.admission_scale() == 1.0
    bo.tick(0.95, 0.95)
    bo.tick(0.95, 0.95)
    assert bo.level == 3 and bo.admission_scale() == 0.5


def test_brownout_config_validates_hysteresis_band():
    with pytest.raises(ValueError):
        BrownoutConfig(enter=(0.6, 0.75, 0.9), exit=(0.6, 0.6, 0.75))
    with pytest.raises(ValueError):
        BrownoutConfig(enter=(0.6, 0.75), exit=(0.45, 0.6))


def test_engine_backend_brownout_toggles_speculation():
    eng = types.SimpleNamespace(speculative=True)
    be = EngineBackend(eng)
    be.set_brownout(1)
    assert eng.speculative is False
    be.set_brownout(0)
    assert eng.speculative is True, "level 0 restores the engine's setting"
    be.set_brownout(2)
    assert eng.speculative is False


def test_router_brownout_ladder_under_queue_pressure():
    """Queue occupancy against the admission bound drives the ladder up
    one rung per submit; level 3 tightens the front door (scaled bound →
    explicit queue_full shed); draining drops it back down — and every
    transition broadcasts the level to the replicas."""
    from repro.cluster import AdmissionConfig, AdmissionController
    gate = threading.Event()
    m = MetricsRegistry()
    r = Router(metrics=m,
               admission=AdmissionController(
                   AdmissionConfig(max_queue_cost=10), m),
               brownout=BrownoutController())
    w = r.add_replica(gated(gate), ReplicaConfig(max_batch=1))
    held = [r.submit(0, cost=8, timeout_s=30.0)]       # qfrac -> 0.8
    held.append(r.submit(1, cost=1, timeout_s=30.0))   # tick: L1
    held.append(r.submit(2, cost=1, timeout_s=30.0))   # tick: L2
    assert m.gauge("router.brownout_level").value == 2
    shed = r.submit(3, cost=1, timeout_s=30.0)         # tick: L3 -> bound 5
    assert shed.status is Status.REJECTED
    assert "brownout" in shed.result.detail
    assert w.brownout() == 3, "transition was broadcast to the replica"
    assert m.counter("router.brownout_transitions").value == 3
    gate.set()
    for q in held:
        assert r.wait(q, timeout=10.0) == 2 * q.payload
    for i in range(4):                                 # drained: descend
        r.wait(r.submit(10 + i, cost=1, timeout_s=10.0), timeout=10.0)
    assert m.gauge("router.brownout_level").value == 0
    r.stop()


# ----------------------------------------------------------------------
# wait timeout + cancellation

def test_wait_timeout_is_typed_and_cancel_reaches_queued_work():
    gate = threading.Event()
    m = MetricsRegistry()
    r = Router(metrics=m)
    r.add_replica(gated(gate), ReplicaConfig(max_batch=1))
    blocker = r.submit(1, timeout_s=30.0)
    target = r.submit(2, timeout_s=30.0)     # queued behind the blocker
    out = r.wait(target, timeout=0.05)
    assert isinstance(out, WaitTimeout)
    assert out.rid == target.rid and out.waited_s == 0.05
    assert m.counter("router.wait_timeout").value == 1
    r.cancel(target)
    gate.set()
    assert r.wait(blocker, timeout=10.0) == 2
    assert target.done.wait(10.0)
    assert target.status is Status.CANCELLED
    assert target.finish_reason == "cancelled"
    assert m.counter("router.cancelled").value == 1
    r.stop()


def test_cancel_losing_race_to_completion_is_noop():
    r = Router()
    r.add_replica(echo())
    q = r.submit(5, timeout_s=30.0)
    assert r.wait(q, timeout=10.0) == 10
    r.cancel(q)                      # already terminal: OK wins
    assert q.status is Status.OK and q.finish_reason == ""
    r.stop()


def test_deadline_expires_in_replica_queue():
    gate = threading.Event()
    r = Router()
    r.add_replica(gated(gate), ReplicaConfig(max_batch=1))
    blocker = r.submit(1, timeout_s=30.0)
    victim = r.submit(2, timeout_s=0.05)
    time.sleep(0.15)                 # victim expires while queued
    gate.set()
    assert r.wait(blocker, timeout=10.0) == 2
    assert victim.done.wait(10.0)
    assert victim.status is Status.EXPIRED
    assert victim.finish_reason == "deadline"
    assert victim.result == [], "queue drop acks empty partial output"
    r.stop()


def test_late_ack_downgrades_to_expired():
    """A full result arriving after the deadline must not land as OK —
    the single-completion-point downgrade covers workers that ignored the
    wire budget (old builds) and acks already in flight."""
    gate = threading.Event()
    r = Router()
    r.add_replica(gated(gate), ReplicaConfig(max_batch=1))
    victim = r.submit(3, timeout_s=0.05)    # pulled before expiry, stuck
    time.sleep(0.15)
    gate.set()
    assert victim.done.wait(10.0)
    assert victim.status is Status.EXPIRED
    assert victim.finish_reason == "deadline"
    r.stop()


# ----------------------------------------------------------------------
# retry budgets: backoff + poison

def test_poison_request_blast_radius_is_bounded():
    m = MetricsRegistry()
    r = Router(metrics=m, max_retries=8, poison_threshold=2,
               retry_backoff_base_s=0.001, retry_backoff_max_s=0.01)
    for _ in range(3):
        r.add_replica(spec=echo_spec(delay_s=0.001, poison=7),
                      cfg=ReplicaConfig(max_batch=2))
    bad = r.submit(7, timeout_s=30.0)
    assert bad.done.wait(10.0)
    assert bad.status is Status.FAILED
    assert bad.finish_reason == "poison"
    assert len(bad.killed_replicas) == 2, \
        "poison terminates at the threshold, not the whole fleet"
    assert m.counter("router.poisoned").value == 1
    assert m.counter("router.retry_backoff").value >= 1
    assert r.n_alive() == 1, "the third replica survived"
    ok = r.submit(5, timeout_s=10.0)
    assert r.wait(ok, timeout=10.0) == 10
    r.stop()


def test_quarantine_routes_around_crash_looping_replica():
    """Spills from a transport that stays in the pool (socket-flap
    semantics) are breaker strikes; a tripped replica stops winning
    ranking rounds and traffic lands on the healthy one."""
    clk = _Clock()
    m = MetricsRegistry()
    cb = CircuitBreaker(BreakerConfig(crash_threshold=2, window_s=30.0,
                                      cooldown_s=5.0), clock=clk)
    r = Router(metrics=m, breaker=cb)
    flaky = r.add_replica(echo())
    healthy = r.add_replica(echo())
    fake = types.SimpleNamespace(rid=flaky.rid, alive=True)
    r._on_spill([], fake)
    assert cb.state(flaky.rid) == "closed"
    r._on_spill([], fake)
    assert cb.state(flaky.rid) == "open"
    assert m.counter("router.quarantined").value == 1
    reqs = [r.submit(i, timeout_s=10.0) for i in range(6)]
    for q in reqs:
        assert r.wait(q, timeout=10.0) == 2 * q.payload
    assert all(q.replica_rid == healthy.rid for q in reqs), \
        "no request may land on the quarantined replica"
    r.stop()


def test_half_open_probe_readmits_recovered_replica():
    clk = _Clock()
    cb = CircuitBreaker(BreakerConfig(crash_threshold=1, cooldown_s=5.0),
                        clock=clk)
    r = Router(breaker=cb)
    w = r.add_replica(echo())
    fake = types.SimpleNamespace(rid=w.rid, alive=True)
    r._on_spill([], fake)
    assert cb.state(w.rid) == "open"
    # during cooldown the only replica is unrankable: explicit shed
    q = r.submit(1, timeout_s=5.0)
    assert q.status is Status.REJECTED
    clk.t = 5.0
    probe = r.submit(99, timeout_s=10.0)
    assert r.wait(probe, timeout=10.0) == 198
    assert cb.state(w.rid) == "closed", "a clean probe ack closes it"
    r.stop()


# ----------------------------------------------------------------------
# artifact fetch retry

def test_fetch_with_retry_bounds_attempts_and_jitters_backoff():
    calls, sleeps = [], []
    out = fetch_with_retry(lambda d: calls.append(d), "ab", attempts=4,
                           base_s=0.1, max_s=0.15, jitter=0.5,
                           sleep=sleeps.append, rng=random.Random(0))
    assert out is None
    assert len(calls) == 4
    assert len(sleeps) == 3, "no backoff after the final attempt"
    for i, s in enumerate(sleeps):
        base = min(0.1 * 2 ** i, 0.15)
        assert base <= s <= base * 1.5, "jitter is bounded and additive"
    seq = iter([None, None, b"blob"])
    assert fetch_with_retry(lambda d: next(seq), "ab", attempts=4,
                            sleep=lambda s: None) == b"blob"


def test_fetch_with_retry_propagates_exceptions_immediately():
    calls = []

    def broken(d):
        calls.append(d)
        raise OSError("channel closed")

    with pytest.raises(OSError):
        fetch_with_retry(broken, "ab", attempts=4, sleep=lambda s: None)
    assert len(calls) == 1, "a closed channel is not a transient miss"


def test_resolve_spec_survives_transient_fetch_miss(tmp_path):
    store = ArtifactStore(str(tmp_path))
    payload = b"weights-bytes"
    digest = sha256_bytes(payload)
    spec = BackendSpec("mod:fn", {"weights_path": artifact_ref(digest)})
    attempts = []

    def flaky_fetch(d):
        attempts.append(d)
        return payload if len(attempts) >= 3 else None

    resolved = resolve_spec(spec, store, fetch=flaky_fetch)
    assert resolved.kwargs["weights_path"] == store.get_path(digest)
    assert len(attempts) == 3, "two transient misses then success"


def test_resolve_spec_total_failure_is_still_explicit(tmp_path):
    store = ArtifactStore(str(tmp_path))
    digest = sha256_bytes(b"never-arrives")
    spec = BackendSpec("mod:fn", {"weights_path": artifact_ref(digest)})
    attempts = []

    def always_miss(d):
        attempts.append(d)
        return None

    t0 = time.monotonic()
    with pytest.raises(KeyError):
        resolve_spec(spec, store, fetch=always_miss)
    assert len(attempts) == 4, "bounded attempts, then the explicit error"
    assert time.monotonic() - t0 < 10.0, "capped backoff keeps it prompt"


# ----------------------------------------------------------------------
# observability: every resilience event reaches the flight recorder and
# every counter/gauge renders through the Prometheus exporter

def test_resilience_events_recorded_and_exported():
    from repro.cluster import AdmissionConfig, AdmissionController
    from repro.cluster.tracing import current_recorder

    prev = current_recorder()
    rec = FlightRecorder(capacity=4096, replica="parent")
    set_recorder(rec)
    try:
        gate = threading.Event()
        m = MetricsRegistry()
        clk = _Clock()
        cb = CircuitBreaker(BreakerConfig(crash_threshold=1), clock=clk)
        r = Router(metrics=m, breaker=cb,
                   admission=AdmissionController(
                       AdmissionConfig(max_queue_cost=20), m),
                   brownout=BrownoutController(),
                   max_retries=8, poison_threshold=2,
                   retry_backoff_base_s=0.001, retry_backoff_max_s=0.01)
        w = r.add_replica(gated(gate), ReplicaConfig(max_batch=1))
        blocker = r.submit(1, cost=16, timeout_s=30.0)
        cancelled = r.submit(2, timeout_s=30.0)   # qfrac 0.8 -> brownout L1
        expired = r.submit(3, timeout_s=0.02)
        r.cancel(cancelled)
        assert isinstance(r.wait(blocker, timeout=0.01), WaitTimeout)
        time.sleep(0.1)
        gate.set()
        assert r.wait(blocker, timeout=10.0) == 2
        assert cancelled.done.wait(10.0) and expired.done.wait(10.0)
        # quarantine strike from a still-alive transport
        r._on_spill([], types.SimpleNamespace(rid=w.rid, alive=True))
        r.stop()

        # poison episode (its own pool: the gated one is quarantined)
        r2 = Router(metrics=m, max_retries=8, poison_threshold=2,
                    retry_backoff_base_s=0.001, retry_backoff_max_s=0.01)
        for _ in range(3):
            r2.add_replica(spec=echo_spec(poison=7),
                           cfg=ReplicaConfig(max_batch=2))
        bad = r2.submit(7, timeout_s=30.0)
        assert bad.done.wait(10.0) and bad.finish_reason == "poison"
        r2.stop()

        kinds = {e["kind"] for e in rec.events()}
        for kind in ("cancelled", "deadline_expired", "retry_backoff",
                     "quarantine", "brownout_level", "poison"):
            assert kind in kinds, f"flight recorder missed {kind!r}"

        text = prometheus_text(m.snapshot())
        for metric in ("router_cancelled", "router_wait_timeout",
                       "router_retry_backoff", "router_poisoned",
                       "router_quarantined", "router_brownout_level",
                       "router_brownout_transitions"):
            assert metric in text, f"exporter missed {metric}"
    finally:
        set_recorder(prev)


# ----------------------------------------------------------------------
# finish-reason taxonomy: one engine, seven ways to stop

@pytest.fixture(scope="module")
def lm():
    cfg = reduced(get_config("internlm2-1.8b"))
    params, _ = api.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _frames(sink):
    """on_tokens collector: (tokens, done) pairs in arrival order."""
    def cb(req, toks, done):
        sink.append((list(toks), done))
    return cb


@pytest.mark.parametrize("scenario", sorted(FINISH_REASONS - {"poison"}))
def test_finish_reason_taxonomy(lm, scenario):
    """Every terminal path lands in exactly one taxonomy reason, with a
    consistent stream view: exactly one ``done=True`` frame, and partial
    output only where the contract allows it.  ("poison" is cluster-side;
    see test_poison_request_blast_radius_is_bounded.)"""
    cfg, params = lm
    rng = np.random.RandomState(0)
    prompt = rng.randint(0, cfg.vocab, size=6).astype(np.int32)
    dense = ServeConfig(max_len=32, slots=2, fused=True, sync_every=4)
    frames = []

    if scenario == "max_new":
        eng = Engine(params, cfg, dense)
        r = eng.submit(prompt, max_new=4, on_tokens=_frames(frames))
        eng.run_until_drained()
        assert r.decoded == 4, "prefill token rides free of the budget"
        assert len(r.out_tokens) == 5
    elif scenario == "max_len":
        eng = Engine(params, cfg, dense)
        r = eng.submit(prompt, max_new=100, on_tokens=_frames(frames))
        eng.run_until_drained()
        assert r.decoded == dense.max_len - 1 - len(prompt)
    elif scenario == "rejected_prompt_too_long":
        scfg = ServeConfig(max_len=32, slots=2, fused=True, sync_every=4,
                           paged=True, block_size=8, kv_blocks=4,
                           prefix_cache=False)
        eng = Engine(params, cfg, scfg)
        big = rng.randint(0, cfg.vocab, size=30).astype(np.int32)
        r = eng.submit(big, max_new=3, on_tokens=_frames(frames))
        eng.run_until_drained()
        assert r.out_tokens == []
    elif scenario == "kv_pool_exhausted":
        scfg = ServeConfig(max_len=32, slots=2, fused=True, sync_every=4,
                           paged=True, block_size=8, kv_blocks=5,
                           prefix_cache=False)
        eng = Engine(params, cfg, scfg)
        a = eng.submit(prompt, max_new=24)
        r = eng.submit(rng.randint(0, cfg.vocab, size=8).astype(np.int32),
                       max_new=24, on_tokens=_frames(frames))
        eng.run_until_drained()
        assert a.done and r.done
        reasons = {a.finish_reason, r.finish_reason}
        assert "kv_pool_exhausted" in reasons
        if r.finish_reason != "kv_pool_exhausted":
            r = a        # the victim is what the scenario asserts on
            frames = None
    elif scenario == "deadline":
        eng = Engine(params, cfg, dense)
        r = eng.submit(prompt, max_new=8, on_tokens=_frames(frames),
                       deadline_s=time.monotonic() - 1.0)
        eng.run_until_drained()
        assert r.out_tokens == [], "expired in queue: no decode spent"
        assert eng.metrics.counter("engine.deadline_expired").value == 1
    elif scenario == "cancelled":
        eng = Engine(params, cfg, dense)
        r = eng.submit(prompt, max_new=8, on_tokens=_frames(frames),
                       cancel_cb=lambda: True)
        eng.run_until_drained()
        assert r.out_tokens == []
        assert eng.metrics.counter("engine.cancelled").value == 1

    assert r.done
    assert r.finish_reason == scenario
    assert r.finish_reason in FINISH_REASONS
    if frames is not None:
        assert sum(1 for _, done in frames if done) == 1, \
            "exactly one terminal frame per request"
        assert frames[-1][1], "the terminal frame is last"


def test_engine_cancels_mid_decode_and_frees_kv():
    """A cancel landing after decode starts ends the session at the next
    sync with its partial tokens intact — and on the paged path its KV
    blocks return to the pool immediately, not at drain."""
    cfg = reduced(get_config("internlm2-1.8b"))
    params, _ = api.init(jax.random.PRNGKey(0), cfg)
    scfg = ServeConfig(max_len=64, slots=2, fused=True, sync_every=2,
                       paged=True, block_size=8, prefix_cache=False)
    eng = Engine(params, cfg, scfg)
    rng = np.random.RandomState(1)
    prompt = rng.randint(0, cfg.vocab, size=6).astype(np.int32)
    flag = {"cancel": False}
    seen = []

    def on_tokens(req, toks, done):
        seen.extend(toks)
        if seen:
            flag["cancel"] = True    # cancel after the first sync's tokens

    victim = eng.submit(prompt, max_new=40, on_tokens=on_tokens,
                        cancel_cb=lambda: flag["cancel"])
    survivor = eng.submit(rng.randint(0, cfg.vocab, size=7).astype(np.int32),
                          max_new=6)
    eng.run_until_drained()
    assert victim.done and victim.finish_reason == "cancelled"
    assert 0 < len(victim.out_tokens) < 40, "partial output survives"
    assert survivor.done and survivor.finish_reason == "max_new"
    assert survivor.decoded == 6, "batch-mates are untouched"
    assert eng.alloc.free_blocks + eng.alloc.cached_blocks == \
        eng.alloc.num_blocks, "cancelled session's blocks were freed"


def test_engine_deadline_mid_decode(lm):
    """A deadline that passes mid-decode finishes the session with its
    partial tokens (finish_reason="deadline") while batch-mates decode to
    completion."""
    cfg, params = lm
    scfg = ServeConfig(max_len=64, slots=2, fused=True, sync_every=2)
    eng = Engine(params, cfg, scfg)
    rng = np.random.RandomState(2)
    prompt = rng.randint(0, cfg.vocab, size=6).astype(np.int32)

    def on_tokens(req, toks, done):
        # once the first tokens land, yank the deadline into the past —
        # the sweep re-reads deadline_s every step
        if toks and not done:
            req.deadline_s = time.monotonic() - 1.0

    victim = eng.submit(prompt, max_new=40, on_tokens=on_tokens,
                        deadline_s=time.monotonic() + 100.0)
    survivor = eng.submit(rng.randint(0, cfg.vocab, size=7).astype(np.int32),
                          max_new=6)
    eng.run_until_drained()
    assert victim.done and victim.finish_reason == "deadline"
    assert 0 < len(victim.out_tokens) < 40, "partial output survives"
    assert eng.metrics.counter("engine.deadline_expired").value == 1
    assert survivor.done and survivor.finish_reason == "max_new"
    assert survivor.decoded == 6, "batch-mates are untouched"


# ----------------------------------------------------------------------
# end-to-end deadline propagation over the wire (worker pins the budget)

@pytest.mark.parametrize("transport", ["thread", "process"])
def test_deadline_propagates_to_worker_queue(transport):
    """The budget rides the request frame; the worker drops expired queue
    work without touching the backend, acking Terminal("deadline")."""
    m = MetricsRegistry()
    r = Router(metrics=m)
    r.add_replica(spec=echo_spec(delay_s=0.2), cfg=ReplicaConfig(max_batch=1),
                  transport=transport)
    blocker = r.submit(1, timeout_s=30.0)       # holds the backend 200ms
    victim = r.submit(2, timeout_s=0.05)        # expires while queued
    assert r.wait(blocker, timeout=20.0) == 2
    assert victim.done.wait(20.0)
    assert victim.status is Status.EXPIRED
    assert victim.finish_reason == "deadline"
    r.stop()


# ----------------------------------------------------------------------
# randomized overload chaos (CI job: overload-chaos)

@pytest.mark.slow
@pytest.mark.parametrize("transport", ["thread", "process"])
def test_overload_chaos_invariants(transport):
    from tests.chaos import (assert_overload_invariants, overload_schedule,
                             run_overload_chaos)
    faults = overload_schedule(seed=5, n_faults=12, horizon_s=0.8,
                               n_replicas=3)
    report, snap, info = run_overload_chaos(transport, faults,
                                            n_replicas=3, n_requests=80)
    assert_overload_invariants(report, info)
    if any(f.action == "cancel" for f in faults):
        assert info["cancel_targets"], "schedule had cancels but none fired"
    if any(f.action == "expire" for f in faults):
        assert info["expire_reqs"], "schedule had expiries but none fired"


@pytest.mark.slow
def test_overload_chaos_thread_seeds():
    from tests.chaos import (assert_overload_invariants, overload_schedule,
                             run_overload_chaos)
    for seed in (0, 1, 2, 3):
        faults = overload_schedule(seed, n_faults=10, horizon_s=0.6,
                                   n_replicas=3)
        report, _, info = run_overload_chaos("thread", faults,
                                             n_replicas=3, n_requests=60)
        assert_overload_invariants(report, info)
