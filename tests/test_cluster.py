"""Cluster subsystem: router policies, admission/backpressure, crash
recovery (zero lost requests), autoscaler, metrics, and the service bridge.

Backends here are plain functions (no jax) so the tests exercise the
concurrency machinery, not device compute."""
import threading
import time

import numpy as np
import pytest

from repro.cluster import (AdmissionConfig, AdmissionController, Autoscaler,
                           AutoscalerConfig, FnBackend, MetricsRegistry,
                           Rejected, ReplicaConfig, Router, Status)
from repro.cluster.router import _rendezvous_weight
from repro.core.partitioner import CostModel
from repro.core.service import MLaaSService


def echo(delay: float = 0.0):
    def step(payloads):
        if delay:
            time.sleep(delay)
        return [p * 2 for p in payloads]
    return FnBackend(step)


def gated(event: threading.Event):
    def step(payloads):
        assert event.wait(10.0), "gate never opened"
        return [p * 2 for p in payloads]
    return FnBackend(step)


# ----------------------------------------------------------------------
def test_round_robin_distributes_evenly():
    r = Router(policy="round_robin")
    workers = [r.add_replica(echo(0.001)) for _ in range(3)]
    reqs = [r.submit(i) for i in range(30)]
    assert [r.wait(q, 5.0) for q in reqs] == [2 * i for i in range(30)]
    counts = [w.processed for w in workers]
    assert counts == [10, 10, 10], counts
    r.stop()


def test_session_affinity_is_sticky():
    r = Router(policy="session_affinity")
    for _ in range(3):
        r.add_replica(echo(0.001))
    reqs = [r.submit(i, session_key="user-42") for i in range(20)]
    for q in reqs:
        r.wait(q, 5.0)
    homes = {q.replica_rid for q in reqs}
    assert len(homes) == 1, f"session bounced across {homes}"
    # many distinct sessions spread over the pool
    reqs = [r.submit(i, session_key=f"user-{i}") for i in range(40)]
    for q in reqs:
        r.wait(q, 5.0)
    assert len({q.replica_rid for q in reqs}) >= 2
    r.stop()


def test_rendezvous_only_remaps_removed_replicas_keys():
    rids = [1, 2, 3]
    keys = [f"k{i}" for i in range(200)]

    def winner(key, pool):
        return max(pool, key=lambda rid: _rendezvous_weight(key, rid))

    before = {k: winner(k, rids) for k in keys}
    after = {k: winner(k, [1, 3]) for k in keys}          # rid 2 removed
    for k in keys:
        if before[k] != 2:
            assert after[k] == before[k], "stable key got remapped"
    moved = [k for k in keys if before[k] == 2]
    assert moved, "hash never picked the removed replica (degenerate test)"


def test_drain_remaps_only_drained_sessions_and_exports_them():
    """Draining a replica must (a) leave every session homed on a
    survivor exactly where it was — the rendezvous property, live through
    the real Router — and (b) log/export exactly the drained replica's
    session keys, since those restart cold elsewhere (no cache handoff
    yet: ROADMAP note made observable instead of silent)."""
    m = MetricsRegistry()
    r = Router(policy="session_affinity", metrics=m)
    for _ in range(3):
        r.add_replica(echo(0.001), ReplicaConfig(inbox_capacity=256))
    keys = [f"user-{i}" for i in range(60)]
    reqs = [r.submit(i, session_key=keys[i]) for i in range(60)]
    for q in reqs:
        r.wait(q, 10.0)
    before = {keys[i]: q.replica_rid for i, q in enumerate(reqs)}
    assert len(set(before.values())) == 3, "want sessions on all replicas"
    victim_rid = sorted(set(before.values()))[1]
    victim_keys = sorted(k for k, rid in before.items() if rid == victim_rid)

    r.remove_replica(victim_rid, drain=True)

    # (b) the remapped sessions are exported, exactly the victim's
    assert r.last_remapped_sessions[victim_rid] == victim_keys
    assert m.snapshot()["router.sessions_remapped"] == len(victim_keys)
    # (a) non-drained replicas keep every one of their sessions
    reqs2 = [r.submit(100 + i, session_key=keys[i]) for i in range(60)]
    for q in reqs2:
        r.wait(q, 10.0)
    after = {keys[i]: q.replica_rid for i, q in enumerate(reqs2)}
    for k in keys:
        if before[k] != victim_rid:
            assert after[k] == before[k], \
                f"session {k} on surviving replica {before[k]} remapped"
        else:
            assert after[k] != victim_rid
    # removing a replica with no sessions exports an empty remap
    spare = r.add_replica(echo(0.001), ReplicaConfig())
    r.remove_replica(spare.rid, drain=True)
    assert r.last_remapped_sessions[spare.rid] == []
    r.stop()


def test_least_loaded_routes_around_slow_replica():
    """Join-shortest-queue: a replica whose requests cost more (its queue
    stays deep) receives fewer new requests than a fast peer."""
    r = Router(policy="least_loaded")
    slow = r.add_replica(echo(0.05), ReplicaConfig(max_batch=1,
                                                   inbox_capacity=256))
    fast = r.add_replica(echo(0.002), ReplicaConfig(max_batch=1,
                                                    inbox_capacity=256))
    reqs = []
    for i in range(40):
        reqs.append(r.submit(i))
        time.sleep(0.002)              # let outstanding counts update
    assert [r.wait(q, 20.0) for q in reqs] == [2 * i for i in range(40)]
    assert fast.processed > 2 * slow.processed, \
        (slow.processed, fast.processed)
    # round_robin under the same skew would keep feeding the slow replica:
    # its outstanding queue at the end of submission would be ~half the load
    r.stop()


# ----------------------------------------------------------------------
def test_admission_sheds_on_queue_full_and_nothing_hangs():
    m = MetricsRegistry()
    r = Router(policy="round_robin", metrics=m,
               admission=AdmissionController(
                   AdmissionConfig(max_queue_cost=5), m))
    r.add_replica(echo(0.01), ReplicaConfig(max_batch=1, inbox_capacity=256))
    reqs = [r.submit(i) for i in range(50)]
    for q in reqs:
        assert q.done.wait(10.0), "request neither completed nor rejected"
    ok = [q for q in reqs if q.status is Status.OK]
    shed = [q for q in reqs if q.status is Status.REJECTED]
    assert len(ok) + len(shed) == 50
    assert shed, "overload never shed"
    assert all(isinstance(q.result, Rejected) and q.result.reason == "queue_full"
               for q in shed)
    snap = m.snapshot()
    assert snap["admission.shed_queue_full"] == len(shed)
    r.stop()


def test_admission_sheds_infeasible_deadline():
    cm = CostModel(overhead_s=0.0, per_item_s=1.0, r2=1.0)   # 1s per item
    r = Router(admission=AdmissionController(
        AdmissionConfig(max_queue_cost=100, cost_model=cm)))
    r.add_replica(echo())
    q = r.submit("x", timeout_s=0.05)          # deadline < estimated service
    assert q.status is Status.REJECTED
    assert q.result.reason == "deadline"
    ok = r.submit("y", timeout_s=10.0)         # feasible deadline admitted
    assert r.wait(ok, 5.0) == "yy"
    r.stop()


def test_admission_sheds_on_kv_pressure():
    """The paged-KV headroom gate: a starved pool (free fraction below the
    configured headroom) sheds with an explicit kv_pressure result; a
    healthy pool admits; an unknown pool (no paged replica reporting) is
    not penalized."""
    m = MetricsRegistry()
    ctrl = AdmissionController(
        AdmissionConfig(max_queue_cost=100, min_kv_headroom_frac=0.25), m)
    shed = ctrl.decide(0, 1, time.monotonic() + 10.0, kind="lm",
                       kv_free_frac=0.10)
    assert shed is not None and shed.reason == "kv_pressure"
    assert ctrl.decide(0, 1, time.monotonic() + 10.0, kind="lm",
                       kv_free_frac=0.50) is None
    assert ctrl.decide(0, 1, time.monotonic() + 10.0, kind="lm",
                       kv_free_frac=None) is None
    assert m.snapshot()["admission.shed_kv_pressure"] == 1


def test_router_kv_free_fraction_from_engine_gauges():
    """A thread replica's paged engine reports its pool through the shared
    registry; the router turns the gauges into the admission signal."""
    import jax
    from repro.configs import get_config
    from repro.configs.base import reduced
    from repro.models import api
    from repro.serving import Engine, ServeConfig
    from repro.cluster.replica import EngineBackend

    cfg = reduced(get_config("internlm2-1.8b"))
    params, _ = api.init(jax.random.PRNGKey(0), cfg)
    m = MetricsRegistry()
    eng = Engine(params, cfg,
                 ServeConfig(max_len=32, slots=2, paged=True, block_size=8,
                             kv_blocks=8),
                 metrics=m)
    r = Router(metrics=m,
               admission=AdmissionController(
                   AdmissionConfig(min_kv_headroom_frac=0.1), m))
    r.add_replica(EngineBackend(eng), ReplicaConfig(max_batch=2), kind="lm")
    assert r.kv_free_fraction() == 1.0
    rng = np.random.RandomState(0)
    q = r.submit((rng.randint(0, cfg.vocab, 6).astype(np.int32), 3),
                 kind="lm", timeout_s=120.0)
    assert isinstance(r.wait(q, 120.0), list)
    assert r.kv_free_fraction() is not None
    r.stop()


def test_process_worker_ships_kv_gauges_in_heartbeats():
    """A paged engine inside a spawned worker reports into the registry
    its heartbeats ship, so the parent-side merge (and the admission
    headroom gate) can see engine.kv_blocks_* from across the process
    boundary."""
    from repro.cluster import engine_spec

    m = MetricsRegistry()
    r = Router(metrics=m)
    r.add_replica(
        spec=engine_spec(arch="internlm2-1.8b", max_len=32, slots=2,
                         reduce=True, paged=True, block_size=8),
        cfg=ReplicaConfig(max_batch=2, spawn_timeout_s=300.0,
                          heartbeat_interval_s=0.05),
        transport="process")
    rng = np.random.RandomState(3)
    q = r.submit((rng.randint(0, 256, 6).astype(np.int32), 3),
                 timeout_s=300.0)
    assert isinstance(r.wait(q, 300.0), list)
    # heartbeats are periodic: wait (bounded) for one carrying the
    # post-batch registry before asserting its contents
    deadline = time.monotonic() + 10.0
    snap = {}
    while time.monotonic() < deadline:
        snap = r.cluster_snapshot()
        if snap.get("engine.requests", 0) >= 1:
            break
        time.sleep(0.05)
    assert snap.get("engine.requests", 0) >= 1
    assert snap.get("engine.kv_blocks_total", 0) == 8   # 2 slots * 32/8
    frac = r.kv_free_fraction()
    assert frac is not None and 0.0 < frac <= 1.0
    r.stop()


def test_backpressure_when_every_inbox_is_full():
    gate = threading.Event()
    r = Router()                               # no admission controller
    r.add_replica(gated(gate), ReplicaConfig(inbox_capacity=1, max_batch=1))
    reqs = [r.submit(i) for i in range(20)]
    gate.set()
    for q in reqs:
        assert q.done.wait(10.0)
    shed = [q for q in reqs if q.status is Status.REJECTED]
    assert shed, "full inboxes must shed explicitly, not block"
    assert all(q.result.reason == "queue_full" for q in shed)
    r.stop()


# ----------------------------------------------------------------------
def test_crash_injection_loses_zero_requests():
    m = MetricsRegistry()
    r = Router(policy="round_robin", metrics=m, max_retries=3)
    workers = [r.add_replica(echo(0.005),
                             ReplicaConfig(max_batch=2, inbox_capacity=256))
               for _ in range(3)]
    reqs = [r.submit(i) for i in range(60)]
    time.sleep(0.02)                           # mid-load…
    workers[0].inject_crash()                  # …kill one replica
    results = [r.wait(q, 20.0) for q in reqs]
    assert all(q.status is Status.OK for q in reqs), \
        {q.status for q in reqs}
    assert results == [2 * i for i in range(60)]
    assert r.n_alive() == 2
    # the dead replica's work was redistributed to survivors
    assert not workers[0].alive
    assert sum(w.processed for w in workers[1:]) >= 60 - workers[0].processed
    snap = m.snapshot()
    assert snap["replica.crashes"] == 1
    assert snap["router.failed"] == 0
    r.stop()


def test_crash_with_no_survivors_fails_explicitly():
    gate = threading.Event()
    r = Router()
    w = r.add_replica(gated(gate), ReplicaConfig(inbox_capacity=64))
    reqs = [r.submit(i) for i in range(4)]
    w.inject_crash()
    gate.set()
    for q in reqs:
        assert q.done.wait(10.0), "must fail explicitly, not hang"
    assert all(q.status is Status.FAILED for q in reqs)
    r.stop()


def test_replica_drain_finishes_inbox():
    r = Router()
    w = r.add_replica(echo(0.002), ReplicaConfig(inbox_capacity=64))
    reqs = [r.submit(i) for i in range(16)]
    r.remove_replica(w.rid, drain=True)
    assert all(q.done.wait(5.0) for q in reqs)
    assert all(q.status is Status.OK for q in reqs)


# ----------------------------------------------------------------------
def test_autoscaler_up_on_pressure_down_when_idle():
    t = [0.0]
    gate = threading.Event()
    r = Router(policy="least_loaded")
    cfg = AutoscalerConfig(min_replicas=1, max_replicas=3, scale_up_depth=4.0,
                           scale_down_depth=0.5, cooldown_s=1.0,
                           idle_ticks_to_drain=2,
                           replica_cfg=ReplicaConfig(inbox_capacity=256))
    r.add_replica(gated(gate), cfg.replica_cfg)
    sc = Autoscaler(r, lambda: gated(gate), cfg, clock=lambda: t[0])

    reqs = [r.submit(i) for i in range(20)]
    ev = sc.tick()
    assert ev and ev.action == "up" and r.n_alive() == 2
    assert sc.tick() is None, "cooldown must gate consecutive actions"
    t[0] += 2.0
    ev = sc.tick()
    assert ev and ev.action == "up" and r.n_alive() == 3
    t[0] += 2.0
    assert sc.tick() is None, "max_replicas must cap the pool"

    gate.set()
    for q in reqs:
        assert q.done.wait(10.0)
    for expect_n in (2, 1):
        t[0] += 2.0
        assert sc.tick() is None            # first idle tick: observe only
        t[0] += 2.0
        ev = sc.tick()                      # second idle tick: drain one
        assert ev and ev.action == "down" and r.n_alive() == expect_n
    t[0] += 2.0
    sc.tick(); t[0] += 2.0
    assert sc.tick() is None, "min_replicas must floor the pool"
    assert [e.action for e in sc.events] == ["up", "up", "down", "down"]
    r.stop()


def test_autoscaler_reacts_to_fall_behind_signal():
    r = Router()
    r.add_replica(echo())
    sc = Autoscaler(r, echo, AutoscalerConfig(max_replicas=2, cooldown_s=0.0),
                    fall_behind=lambda: True)
    ev = sc.tick()
    assert ev and ev.action == "up" and ev.reason == "fall_behind"
    r.stop()


# ----------------------------------------------------------------------
def test_service_front_targets_router():
    r = Router(policy="round_robin")
    for _ in range(2):
        r.add_replica(echo())
    svc = MLaaSService(router=r, capacity=4).start()
    reqs = [svc.submit(i, timeout_s=5.0) for i in range(12)]
    for q in reqs:
        assert q.done.wait(5.0)
    svc.stop()
    r.stop()
    assert [q.result for q in reqs] == [2 * i for i in range(12)]
    assert svc.stats["requests"] == 12


def test_service_stop_drains_pending():
    slow = lambda ps: (time.sleep(0.05), [p * 2 for p in ps])[1]
    svc = MLaaSService(slow, capacity=2).start()
    reqs = [svc.submit(i, timeout_s=30.0) for i in range(8)]
    svc.stop(drain=True)                       # flush everything queued
    for q in reqs:
        assert q.done.wait(1.0), "stop() stranded a pending request"
    assert [q.result for q in reqs] == [2 * i for i in range(8)]


def test_service_stop_failfast_rejects_pending():
    slow = lambda ps: (time.sleep(0.2), [p for p in ps])[1]
    svc = MLaaSService(slow, capacity=1).start()
    reqs = [svc.submit(i, timeout_s=30.0) for i in range(6)]
    time.sleep(0.05)
    svc.stop(drain=False)
    for q in reqs:
        assert q.done.wait(1.0), "stop(drain=False) stranded a request"
    rejected = [q for q in reqs if q.rejected]
    assert rejected, "pending requests must be failed fast on shutdown"
    assert all(q.result.reason == "shutdown" for q in rejected)
    # post-stop submissions fail immediately instead of queueing forever
    late = svc.submit(99)
    assert late.done.is_set() and late.rejected


# ----------------------------------------------------------------------
def test_service_step_error_fails_batch_but_not_the_loop():
    def flaky(ps):
        if any(p < 0 for p in ps):            # poison payloads
            raise RuntimeError("backend OOM")
        return [p * 2 for p in ps]

    svc = MLaaSService(flaky, capacity=4).start()
    bad = [svc.submit(-i - 1, timeout_s=2.0) for i in range(4)]
    for q in bad:
        assert q.done.wait(5.0), "failed batch must not strand callers"
    assert all(q.rejected and q.result.reason == "step_error" for q in bad)
    ok = svc.submit(21, timeout_s=2.0)        # loop survived the exception
    assert ok.done.wait(5.0) and ok.result == 42
    svc.stop()


def test_metrics_registry_counters_gauges_histograms():
    m = MetricsRegistry()
    m.counter("c").inc(); m.counter("c").inc(2)
    m.gauge("g").set(7.5)
    h = m.histogram("h")
    for v in range(1, 101):
        h.observe(float(v))
    with m.timer("t"):
        pass
    snap = m.snapshot()
    assert snap["c"] == 3
    assert snap["g"] == 7.5
    assert snap["h.count"] == 100
    assert abs(snap["h.p50"] - 50.5) < 1.5
    assert snap["h.p99"] >= 99.0
    assert snap["t.count"] == 1
    assert m.histogram("h").mean() == pytest.approx(50.5)


def test_engine_rids_are_monotonic_and_unique():
    jax = pytest.importorskip("jax")
    from repro.configs import get_config
    from repro.configs.base import reduced
    from repro.models import api
    from repro.serving import Engine, ServeConfig

    cfg = reduced(get_config("internlm2-1.8b"))
    params, _ = api.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, ServeConfig(max_len=32, slots=2))
    rng = np.random.RandomState(0)
    rids = []
    for _ in range(3):                  # interleave submit / drain so the
        for _ in range(3):              # old len(finished)+len(queue) formula
            rids.append(eng.submit(     # would collide
                rng.randint(0, cfg.vocab, size=4).astype(np.int32),
                max_new=2).rid)
        eng.run_until_drained()
    assert rids == sorted(rids) and len(set(rids)) == len(rids), rids
