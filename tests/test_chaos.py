"""Property tests over the chaos harness (``tests/chaos.py``): randomized
kill/crash/drop/delay schedules against replica pools on every transport,
asserting the cluster-layer contract — no request is ever lost (all reach
an explicit terminal state) and none is ever double-completed — plus
correct results for everything that completed.

Fast seeds run in tier-1; the heavier multi-episode sweeps over spawned
process/socket workers carry the ``slow`` marker (deselected by default
via ``pytest.ini``; CI runs them in a dedicated job).
"""
import pytest

from tests._hyp_compat import given, settings, st
from tests.chaos import (ACTIONS, partition_schedule, random_schedule,
                         run_chaos, run_slow_loris)


def _episode(transport: str, seed: int, n_faults: int = 3,
             n_requests: int = 90) -> None:
    faults = random_schedule(seed, n_faults=n_faults, horizon_s=0.5,
                             n_replicas=3)
    report = run_chaos(transport, faults, n_replicas=3,
                       n_requests=n_requests)
    report.assert_invariants()


# ----------------------------------------------------------------------
# Tier-1: thread pools are cheap — randomize broadly; remote transports
# get one deterministic smoke episode each.

@settings(max_examples=5, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_chaos_thread_never_loses_or_doubles(seed):
    _episode("thread", seed)


def test_chaos_process_smoke():
    _episode("process", seed=7, n_faults=2, n_requests=60)


def test_chaos_socket_smoke():
    _episode("socket", seed=11, n_faults=2, n_requests=60)


def test_slow_loris_process_is_rerouted():
    """ROADMAP scenario: a worker that heartbeats but never acks.  The ack
    timeout must declare it dead, its queued work must reroute to the
    survivors, and every request must complete exactly once."""
    report = run_slow_loris("process", n_replicas=3, n_requests=40,
                            ack_timeout_s=1.0)
    report.assert_invariants()
    assert report.ok == report.n_requests, \
        f"survivors should absorb everything: {report}"
    assert report.crashes >= 1


def test_chaos_partial_partition_process():
    """Partial network partition: drop the worker→parent heartbeat
    direction for windows shorter than the heartbeat timeout while acks
    keep flowing.  Replicas must ride it out (no spurious deaths under
    load), the zero-lost contract must hold, and the flight recorder must
    capture the partition events for post-mortem."""
    from repro.cluster import current_recorder, set_recorder
    from repro.cluster.tracing import FlightRecorder

    prev = current_recorder()
    set_recorder(FlightRecorder(replica="parent"))
    try:
        faults = partition_schedule(31, n_partitions=2, horizon_s=0.4,
                                    n_replicas=3,
                                    duration_bounds_s=(0.3, 0.6))
        report = run_chaos("process", faults, n_replicas=3, n_requests=60)
        report.assert_invariants()
        assert report.ok == report.n_requests, str(report)
        events = [e for e in current_recorder().events()
                  if e["kind"] == "partition"]
        assert len(events) == len(faults), \
            "every injected partition must leave a flight-recorder event"
        for e in events:
            assert e["direction"] == "worker->parent" and e["duration_s"] > 0
    finally:
        set_recorder(prev)


def test_schedule_is_deterministic():
    a = random_schedule(123, n_faults=5, horizon_s=1.0, n_replicas=3)
    b = random_schedule(123, n_faults=5, horizon_s=1.0, n_replicas=3)
    assert a == b
    assert all(f.action in ACTIONS for f in a)
    assert [f.at_s for f in a] == sorted(f.at_s for f in a)
    p = partition_schedule(123, n_partitions=4, horizon_s=1.0, n_replicas=3)
    assert p == partition_schedule(123, n_partitions=4, horizon_s=1.0,
                                   n_replicas=3)
    assert all(f.action == "partition" for f in p)


# ----------------------------------------------------------------------
# Slow: multi-episode randomized sweeps over spawned workers.

@pytest.mark.slow
@settings(max_examples=4, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_chaos_process_never_loses_or_doubles(seed):
    _episode("process", seed)


@pytest.mark.slow
@settings(max_examples=4, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_chaos_socket_never_loses_or_doubles(seed):
    _episode("socket", seed)


@pytest.mark.slow
@settings(max_examples=4, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_chaos_mixed_transport_cluster(seed):
    """One pool spanning thread + process + socket replicas at once."""
    _episode("mixed", seed, n_faults=4)


@pytest.mark.slow
def test_slow_loris_socket_is_rerouted():
    """Same slow-loris contract over the socket transport: the worker-side
    heartbeat thread keeps the connection audibly alive the whole time, so
    only the ack timeout can catch it."""
    report = run_slow_loris("socket", n_replicas=3, n_requests=40,
                            ack_timeout_s=1.0)
    report.assert_invariants()
    assert report.ok == report.n_requests, str(report)


@pytest.mark.slow
def test_chaos_survives_killing_every_replica():
    """Total loss: every replica killed mid-stream.  Requests may FAIL or
    be REJECTED — explicitly — but none may hang or double-complete."""
    from tests.chaos import Fault
    faults = [Fault(at_s=0.05, action="kill", target=0),
              Fault(at_s=0.10, action="kill", target=1),
              Fault(at_s=0.15, action="kill", target=2)]
    report = run_chaos("socket", faults, n_replicas=3, n_requests=80,
                       timeout_s=30.0)
    report.assert_invariants()
    assert report.failed + report.rejected > 0, \
        "killing the whole pool must surface explicit failures"
