"""Property tests over the chaos harness (``tests/chaos.py``): randomized
kill/crash/drop/delay schedules against replica pools on every transport,
asserting the cluster-layer contract — no request is ever lost (all reach
an explicit terminal state) and none is ever double-completed — plus
correct results for everything that completed.

Fast seeds run in tier-1; the heavier multi-episode sweeps over spawned
process/socket workers carry the ``slow`` marker (deselected by default
via ``pytest.ini``; CI runs them in a dedicated job).
"""
import pytest

from tests._hyp_compat import given, settings, st
from tests.chaos import (ACTIONS, partition_schedule, random_schedule,
                         run_chaos, run_slow_loris)


def _episode(transport: str, seed: int, n_faults: int = 3,
             n_requests: int = 90) -> None:
    faults = random_schedule(seed, n_faults=n_faults, horizon_s=0.5,
                             n_replicas=3)
    report = run_chaos(transport, faults, n_replicas=3,
                       n_requests=n_requests)
    report.assert_invariants()


# ----------------------------------------------------------------------
# Tier-1: thread pools are cheap — randomize broadly; remote transports
# get one deterministic smoke episode each.

@settings(max_examples=5, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_chaos_thread_never_loses_or_doubles(seed):
    _episode("thread", seed)


def test_chaos_process_smoke():
    _episode("process", seed=7, n_faults=2, n_requests=60)


def test_chaos_socket_smoke():
    _episode("socket", seed=11, n_faults=2, n_requests=60)


def test_slow_loris_process_is_rerouted():
    """ROADMAP scenario: a worker that heartbeats but never acks.  The ack
    timeout must declare it dead, its queued work must reroute to the
    survivors, and every request must complete exactly once."""
    report = run_slow_loris("process", n_replicas=3, n_requests=40,
                            ack_timeout_s=1.0)
    report.assert_invariants()
    assert report.ok == report.n_requests, \
        f"survivors should absorb everything: {report}"
    assert report.crashes >= 1


def test_chaos_partial_partition_process():
    """Partial network partition: drop the worker→parent heartbeat
    direction for windows shorter than the heartbeat timeout while acks
    keep flowing.  Replicas must ride it out (no spurious deaths under
    load), the zero-lost contract must hold, and the flight recorder must
    capture the partition events for post-mortem."""
    from repro.cluster import current_recorder, set_recorder
    from repro.cluster.tracing import FlightRecorder

    prev = current_recorder()
    set_recorder(FlightRecorder(replica="parent"))
    try:
        faults = partition_schedule(31, n_partitions=2, horizon_s=0.4,
                                    n_replicas=3,
                                    duration_bounds_s=(0.3, 0.6))
        report = run_chaos("process", faults, n_replicas=3, n_requests=60)
        report.assert_invariants()
        assert report.ok == report.n_requests, str(report)
        events = [e for e in current_recorder().events()
                  if e["kind"] == "partition"]
        assert len(events) == len(faults), \
            "every injected partition must leave a flight-recorder event"
        for e in events:
            assert e["direction"] == "worker->parent" and e["duration_s"] > 0
    finally:
        set_recorder(prev)


def test_schedule_is_deterministic():
    a = random_schedule(123, n_faults=5, horizon_s=1.0, n_replicas=3)
    b = random_schedule(123, n_faults=5, horizon_s=1.0, n_replicas=3)
    assert a == b
    assert all(f.action in ACTIONS for f in a)
    assert [f.at_s for f in a] == sorted(f.at_s for f in a)
    p = partition_schedule(123, n_partitions=4, horizon_s=1.0, n_replicas=3)
    assert p == partition_schedule(123, n_partitions=4, horizon_s=1.0,
                                   n_replicas=3)
    assert all(f.action == "partition" for f in p)


# ----------------------------------------------------------------------
# KV lifecycle chaos: preempt / drain / migrate / kill against paged LM
# engines with tight KV pools and host swap on.  The invariant is the
# tentpole's contract — every completed request's token stream is
# byte-identical to an undisturbed oracle run.  One deterministic fast
# episode runs in tier-1; the randomized sweeps carry slow+kvchaos and
# run in the dedicated ``kv-lifecycle-chaos`` CI job.

@pytest.mark.kvchaos
def test_kv_chaos_preempt_migrate_drain_token_exact():
    from tests.chaos import Fault, run_kv_chaos

    faults = [Fault(at_s=0.05, action="preempt", target=0),
              Fault(at_s=0.25, action="migrate", target=0),
              Fault(at_s=0.45, action="drain", target=1)]
    report, snap, backends = run_kv_chaos(
        faults, seed=5, n_replicas=3, n_requests=12, horizon_s=0.3,
        kv_blocks=10, max_new=16)
    report.assert_invariants()
    # no kills in this schedule: everything must complete OK, and the
    # token streams already matched the oracle (wrong_results empty)
    assert report.failed == 0 and report.rejected == 0, str(report)
    assert report.ok == report.n_requests, str(report)
    # the episode must actually exercise the machinery under test
    swaps = sum(b.engine.metrics.snapshot().get("engine.kv_swap_out", 0)
                for b in backends)
    assert swaps > 0, "pressure burst never forced a preemption swap"
    restores = sum(b.engine.metrics.snapshot().get("engine.kv_swap_in", 0)
                   for b in backends)
    assert restores == swaps, "every swap-out must be restored (no kills)"


@pytest.mark.kvchaos
def test_kv_chaos_kill_allows_explicit_failures_only():
    """With hard kills in the schedule requests may FAIL after retries —
    explicitly — but OK results must still be token-exact and nothing may
    hang or double-complete."""
    from tests.chaos import Fault, run_kv_chaos

    faults = [Fault(at_s=0.05, action="preempt", target=0),
              Fault(at_s=0.2, action="kill", target=1)]
    report, _, _ = run_kv_chaos(faults, seed=9, n_replicas=3,
                                n_requests=10, horizon_s=0.3,
                                kv_blocks=10, max_new=16)
    report.assert_invariants()
    assert report.ok > 0, "survivors must absorb the stream"


def test_partition_between_autoscaler_ticks_no_double_scale():
    """Partial partitions landing *between* autoscaler ticks: a
    partitioned-but-acking replica must not be declared dead (no spurious
    scale-up), consecutive scale actions must stay a cooldown apart (no
    double-scale), and the sessions remapped off a drained replica must
    keep completing on survivors (nothing stranded)."""
    from repro.cluster import (MetricsRegistry, ReplicaConfig, Router,
                               Status, echo_spec)
    from repro.cluster.autoscaler import Autoscaler, AutoscalerConfig

    m = MetricsRegistry()
    r = Router(policy="session_affinity", metrics=m, max_retries=8,
               requeue_timeout_s=3.0)
    rcfg = ReplicaConfig(inbox_capacity=512, max_batch=4,
                         heartbeat_timeout_s=2.0)
    workers = [r.add_replica(spec=echo_spec(delay_s=0.002), cfg=rcfg,
                             transport="process") for _ in range(3)]
    clock = [0.0]
    cooldown = 5.0
    sc = Autoscaler(r, lambda: echo_spec(delay_s=0.002),
                    AutoscalerConfig(min_replicas=1, max_replicas=4,
                                     cooldown_s=cooldown,
                                     scale_down_depth=1.0,
                                     idle_ticks_to_drain=2,
                                     replica_cfg=rcfg),
                    clock=lambda: clock[0], transport="process")
    reqs = []

    def wave(n, base):
        for i in range(n):
            reqs.append(r.submit(base + i, session_key=f"u{(base + i) % 9}",
                                 timeout_s=60.0))

    wave(12, 0)
    sc.tick()                       # busy pool: no action
    # partition one replica between ticks, shorter than the heartbeat
    # timeout, while requests keep flowing (acks refresh liveness)
    workers[1].inject_hb_partition(0.8)
    wave(12, 100)
    clock[0] += 1.0
    sc.tick()                       # within cooldown anyway: must be None
    for q in reqs:
        assert q.done.wait(60.0), "request hung during partition"
    assert r.n_alive() == 3, "partitioned-but-acking replica declared dead"
    assert all(e.action != "up" for e in sc.events), \
        f"partition triggered a spurious scale-up: {sc.events}"

    # idle pool now: the scaler drains exactly one replica across ticks,
    # with another partition window landing between them
    clock[0] += 10.0
    sc.tick()                       # idle tick 1
    workers[0].inject_hb_partition(0.5)
    clock[0] += 10.0
    sc.tick()                       # idle tick 2 -> drain
    clock[0] += 1.0
    sc.tick()                       # within cooldown: no second drain
    downs = [e for e in sc.events if e.action == "down"]
    assert len(downs) == 1, f"double-scaled: {sc.events}"
    ts = [e.t for e in sc.events]
    assert all(b - a >= cooldown for a, b in zip(ts, ts[1:])), \
        f"scale actions closer than cooldown: {sc.events}"
    assert 1 <= r.n_alive() <= 4
    assert r.n_alive() == 2

    # the drained replica's sessions must not be stranded: the same
    # session keys keep completing on the survivors
    before = len(reqs)
    wave(9, 200)
    for q in reqs[before:]:
        assert q.done.wait(60.0), "remapped session stranded after drain"
        assert q.status is Status.OK
    r.stop()


# ----------------------------------------------------------------------
# Slow: multi-episode randomized sweeps over spawned workers.

@pytest.mark.slow
@settings(max_examples=4, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_chaos_process_never_loses_or_doubles(seed):
    _episode("process", seed)


@pytest.mark.slow
@settings(max_examples=4, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_chaos_socket_never_loses_or_doubles(seed):
    _episode("socket", seed)


@pytest.mark.slow
@settings(max_examples=4, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_chaos_mixed_transport_cluster(seed):
    """One pool spanning thread + process + socket replicas at once."""
    _episode("mixed", seed, n_faults=4)


@pytest.mark.slow
def test_slow_loris_socket_is_rerouted():
    """Same slow-loris contract over the socket transport: the worker-side
    heartbeat thread keeps the connection audibly alive the whole time, so
    only the ack timeout can catch it."""
    report = run_slow_loris("socket", n_replicas=3, n_requests=40,
                            ack_timeout_s=1.0)
    report.assert_invariants()
    assert report.ok == report.n_requests, str(report)


@pytest.mark.slow
@pytest.mark.kvchaos
@settings(max_examples=3, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_kv_chaos_randomized_schedules(seed):
    """Randomized KV-lifecycle schedules: whatever mix of preempt / drain
    / migrate / kill the seed draws, nothing is lost or double-completed
    and every OK token stream matches the undisturbed oracle."""
    from tests.chaos import kv_schedule, run_kv_chaos

    faults = kv_schedule(seed, n_faults=4, horizon_s=0.4, n_replicas=3)
    report, _, _ = run_kv_chaos(faults, seed=seed % 1000, n_replicas=3,
                                n_requests=12, horizon_s=0.4,
                                kv_blocks=10, max_new=16)
    report.assert_invariants()
    if all(f.action != "kill" for f in faults):
        assert report.failed == 0, str(report)


@pytest.mark.slow
def test_chaos_survives_killing_every_replica():
    """Total loss: every replica killed mid-stream.  Requests may FAIL or
    be REJECTED — explicitly — but none may hang or double-complete."""
    from tests.chaos import Fault
    faults = [Fault(at_s=0.05, action="kill", target=0),
              Fault(at_s=0.10, action="kill", target=1),
              Fault(at_s=0.15, action="kill", target=2)]
    report = run_chaos("socket", faults, n_replicas=3, n_requests=80,
                       timeout_s=30.0)
    report.assert_invariants()
    assert report.failed + report.rejected > 0, \
        "killing the whole pool must surface explicit failures"
