"""Hypothesis compatibility shim for the property tests.

If ``hypothesis`` is installed, re-export the real ``given``/``settings``/
``st``.  Otherwise provide a tiny deterministic fallback that runs each
property test ``max_examples`` times on seeded draws (boundary values first,
then uniform samples) so the suite still collects and exercises the
properties without the optional dependency.
"""
from __future__ import annotations

import functools

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover - env
    import numpy as np

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, boundary, sample):
            self._boundary = list(boundary)   # always-tried edge cases
            self._sample = sample             # rng -> value

        def example(self, rng, i: int):
            if i < len(self._boundary):
                return self._boundary[i]
            return self._sample(rng)

    class _St:
        @staticmethod
        def integers(lo, hi):
            lo, hi = int(lo), int(hi)
            return _Strategy(
                [lo, hi],
                lambda rng: int(lo + rng.rand() * (hi - lo + 1)) if hi > lo
                else lo)

        @staticmethod
        def floats(lo, hi):
            return _Strategy([float(lo), float(hi)],
                             lambda rng: float(rng.uniform(lo, hi)))

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(seq[:1], lambda rng: seq[rng.randint(len(seq))])

    st = _St()

    def given(*strategies):
        def deco(fn):
            # NB: no functools.wraps — the wrapper must present a zero-arg
            # signature or pytest treats the strategy params as fixtures.
            def wrapper():
                n = getattr(wrapper, "_max_examples", 10)
                rng = np.random.RandomState(0)
                for i in range(n):
                    vals = [s.example(rng, i) for s in strategies]
                    fn(*vals)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco

    def settings(max_examples: int = 10, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco
