"""Time-series telemetry + SLO burn-rate engine: windowed bucket-delta
percentiles match a brute-force oracle and recover after a spike (the
lifetime reservoir provably does not), counter windowing is reset-safe,
memory stays bounded under stem/ring pressure and concurrent access, the
multi-window burn alerts fire during a deadline-miss burst and clear
with hysteresis (FlightRecorder events + ``slo.*`` gauges on every
transition), and cluster counters stay monotone when replicas are
removed or killed (departed-replica retention).

Everything runs on injected fake clocks except the two end-to-end
harness tests (live Router; the process one pays worker spawns).
"""
import threading
import time

import numpy as np
import pytest

from repro.cluster import (FnBackend, MetricsRegistry, ReplicaConfig,
                           Router, Status, echo_spec, prometheus_text)
from repro.cluster.metrics import is_gauge_key
from repro.cluster.slo import SLOEngine
from repro.cluster.slo import test_scaled_objective as scaled_objective
from repro.cluster.timeseries import (EwmaRate, TelemetrySampler,
                                      TimeSeriesStore)
from repro.cluster.tracing import FlightRecorder

#: one 10^(1/4)x histogram bucket — the documented resolution bound
BUCKET_FACTOR = 10.0 ** 0.25
PROC_CFG = ReplicaConfig(inbox_capacity=256, max_batch=4)


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t


def gated(event: threading.Event):
    def step(payloads):
        assert event.wait(10.0), "gate never opened"
        return [p * 2 for p in payloads]
    return FnBackend(step)


# ----------------------------------------------------------------------
# windowed percentiles from bucket deltas


def test_window_percentile_matches_bruteforce_oracle():
    """p50/p90/p99 over the trailing window agree with numpy over the
    exact same observations, up to one bucket of resolution."""
    rng = np.random.RandomState(7)
    reg = MetricsRegistry()
    h = reg.histogram("lat_s")
    clk = FakeClock()
    store = TimeSeriesStore(clock=clk)
    store.sample(reg.snapshot())               # baseline tick at t=0
    obs = []
    for _ in range(10):
        clk.t += 1.0
        vals = np.exp(rng.uniform(np.log(1e-3), np.log(5.0), size=60))
        for v in vals:
            h.observe(float(v))
        obs.extend(float(v) for v in vals)
        store.sample(reg.snapshot())
    for p in (50, 90, 99):
        est = store.window_percentile("lat_s", p, window_s=10.5)
        oracle = float(np.percentile(obs, p))
        assert oracle / BUCKET_FACTOR <= est <= oracle * BUCKET_FACTOR, \
            (p, est, oracle)
    # the windowed count is the exact number of in-window observations
    assert store.window_count("lat_s", 10.5) == len(obs)


def test_window_percentile_sees_only_the_window():
    """Observations older than the window do not leak into the estimate:
    a narrow window over the slow phase ignores earlier fast traffic."""
    reg = MetricsRegistry()
    h = reg.histogram("lat_s")
    clk = FakeClock()
    store = TimeSeriesStore(clock=clk)
    store.sample(reg.snapshot())
    for _ in range(5):                         # fast phase: t=1..5
        clk.t += 1.0
        for _ in range(20):
            h.observe(0.002)
        store.sample(reg.snapshot())
    for _ in range(3):                         # slow phase: t=6..8
        clk.t += 1.0
        for _ in range(20):
            h.observe(3.0)
        store.sample(reg.snapshot())
    est = store.window_percentile("lat_s", 50, window_s=3.0)
    assert est > 1.0, est                      # fast phase fully aged out


def test_spike_recovers_within_one_window_reservoir_does_not():
    """The acceptance scenario: after a latency spike passes, the
    windowed p99 returns to baseline within one window — while the
    lifetime reservoir p99 stays stuck on the spike forever (why the
    point-in-time snapshot cannot answer "what is p99 *now*")."""
    reg = MetricsRegistry()
    h = reg.histogram("lat_s")
    clk = FakeClock()
    store = TimeSeriesStore(clock=clk)
    store.sample(reg.snapshot())
    window_s = 5.0

    def drive(n_ticks, value, per_tick=20):
        for _ in range(n_ticks):
            clk.t += 1.0
            for _ in range(per_tick):
                h.observe(value)
            store.sample(reg.snapshot())

    drive(6, 0.002)                            # steady fast traffic
    assert store.window_percentile("lat_s", 99, window_s) < 0.01
    drive(2, 3.0)                              # spike
    assert store.window_percentile("lat_s", 99, window_s) > 1.0
    drive(6, 0.002)                            # one full window of fast
    recovered = store.window_percentile("lat_s", 99, window_s)
    assert recovered < 0.01, recovered
    # the lifetime reservoir still reports the spike as "the p99"
    lifetime = store.last("lat_s.p99")
    assert lifetime is not None and lifetime > 1.0, lifetime


def test_empty_window_and_unknown_keys_read_zero():
    reg = MetricsRegistry()
    h = reg.histogram("lat_s")
    clk = FakeClock()
    store = TimeSeriesStore(clock=clk)
    assert store.window_percentile("nope", 99, 10.0) == 0.0
    assert store.rate("nope", 10.0) == 0.0
    assert store.increase("nope", 10.0) == 0.0
    clk.t = 1.0
    h.observe(0.5)
    store.sample(reg.snapshot())
    clk.t = 100.0                              # stem known, window empty
    store.sample(reg.snapshot())
    assert store.window_percentile("lat_s", 99, 5.0) == 0.0
    assert store.rate("lat_s.count", 5.0) == 0.0


# ----------------------------------------------------------------------
# reset-safe counter windowing


def test_counter_reset_clamps_and_attach_is_not_credited():
    clk = FakeClock()
    store = TimeSeriesStore(clock=clk)
    clk.t = 1.0
    store.sample({"reqs": 100.0})              # attach to a running source
    clk.t = 2.0
    store.sample({"reqs": 150.0})
    clk.t = 3.0
    store.sample({"reqs": 20.0})               # worker restart: reset
    clk.t = 4.0
    store.sample({"reqs": 30.0})
    # +50, reset clamps the -130 to 0, +10; the lifetime 100 seen at
    # attach is NOT credited as fresh traffic
    assert store.increase("reqs", 10.0) == pytest.approx(60.0)
    assert store.rate("reqs", 10.0) >= 0.0
    # a key appearing after the store was already ticking gets a
    # synthetic zero baseline: its first value IS fresh traffic
    clk.t = 5.0
    store.sample({"reqs": 30.0, "late": 7.0})
    assert store.increase("late", 10.0) == pytest.approx(7.0)


def test_ewma_rate_clamps_resets():
    e = EwmaRate(halflife_s=1.0)
    e.update(100.0, 0.0)
    r1 = e.update(200.0, 1.0)
    assert r1 > 0.0
    r2 = e.update(0.0, 2.0)                    # reset: decays, never < 0
    assert 0.0 <= r2 < r1


# ----------------------------------------------------------------------
# memory bounds + concurrency


def test_memory_bound_and_stem_cap():
    clk = FakeClock()
    store = TimeSeriesStore(capacity=8, max_stems=16, clock=clk)
    for i in range(50):
        clk.t += 1.0
        store.sample({f"k{j}": float(i) for j in range(40)})
    assert store.max_points == 8 * 16
    assert store.n_points <= store.max_points
    assert len(store.keys()) == 16             # stem bound held
    assert store.dropped_keys > 0              # overflow counted, not kept
    assert len(store.points("k0")) <= 8        # per-key ring bound
    j = store.to_json()
    assert j["n_points"] <= j["max_points"]
    assert j["dropped_keys"] == store.dropped_keys


def test_concurrent_writers_and_readers():
    store = TimeSeriesStore(capacity=32, max_stems=64)
    reg = MetricsRegistry()
    h = reg.histogram("lat_s")
    errors = []
    stop = threading.Event()

    def writer(i):
        try:
            while not stop.is_set():
                h.observe(0.01 * (i + 1))
                reg.counter("reqs").inc()
                store.sample(reg.snapshot())
        except Exception as exc:               # noqa: BLE001
            errors.append(exc)

    def reader():
        try:
            while not stop.is_set():
                store.to_json()
                store.window_percentile("lat_s", 99, 1.0)
                store.rate("reqs", 1.0)
                store.ewma("lat_s.p99")
        except Exception as exc:               # noqa: BLE001
            errors.append(exc)

    threads = ([threading.Thread(target=writer, args=(i,))
                for i in range(3)]
               + [threading.Thread(target=reader) for _ in range(2)])
    for t in threads:
        t.start()
    time.sleep(0.3)
    stop.set()
    for t in threads:
        t.join(5.0)
    assert not errors, errors
    assert store.n_points <= store.max_points


# ----------------------------------------------------------------------
# SLO burn-rate engine (fake clock)


def _slo_rig():
    reg = MetricsRegistry()
    clk = FakeClock()
    store = TimeSeriesStore(clock=clk)
    rec = FlightRecorder()
    slo = SLOEngine([scaled_objective()], reg, recorder=rec,
                    clock=clk)
    return reg, clk, store, rec, slo


def _tick(clk, store, slo, reg, dt=0.1):
    clk.t += dt
    store.sample(reg.snapshot())
    slo.tick(store, now=clk.t)


def test_slo_latency_burn_fires_and_clears_with_hysteresis():
    reg, clk, store, rec, slo = _slo_rig()
    h = reg.histogram("router.latency_s")
    store.sample(reg.snapshot())
    for _ in range(4):                         # healthy: under threshold
        for _ in range(5):
            h.observe(0.01)
        _tick(clk, store, slo, reg)
    assert slo.firing() == []
    assert slo.pressure() == 0.0
    for _ in range(6):                         # burst: every request slow
        for _ in range(5):
            h.observe(5.0)
        _tick(clk, store, slo, reg)
    assert ("any", "latency") in slo.firing()
    assert slo.pressure() > 0.0                # feeds the brownout ladder
    snap = reg.snapshot()
    assert snap["slo.any.latency.firing"] == 1.0
    assert snap["slo.any.latency.burn_fast"] > 2.0
    fired = [e for e in rec.events() if e["kind"] == "slo_burn_fired"]
    assert any(e["slo"] == "latency" and e["objective"] == "any"
               for e in fired)
    # gauges survive the prometheus exporter round-trip
    assert "repro_slo_any_latency_firing 1" in prometheus_text(snap)
    for _ in range(25):                        # recovery: > slow window
        for _ in range(5):
            h.observe(0.01)
        _tick(clk, store, slo, reg)
    assert slo.firing() == []
    assert slo.pressure() == 0.0
    snap = reg.snapshot()
    assert snap["slo.any.latency.firing"] == 0.0
    assert any(e["kind"] == "slo_burn_cleared" and e["slo"] == "latency"
               for e in rec.events())
    # the burst spent lifetime error budget; recovery does not refund it
    assert snap["slo.any.latency.budget_remaining"] < 1.0


def test_slo_availability_deadline_burns_cancelled_is_neutral():
    reg, clk, store, rec, slo = _slo_rig()
    total = reg.counter("router.finish.total")
    dead = reg.counter("router.finish.deadline")
    canc = reg.counter("router.finish.cancelled")
    store.sample(reg.snapshot())
    for _ in range(6):          # cancelled-only traffic: caller's choice,
        total.inc(5)            # excluded from the denominator entirely
        canc.inc(5)
        _tick(clk, store, slo, reg)
    assert slo.firing() == []
    for _ in range(6):                         # deadline-miss burst
        total.inc(5)
        dead.inc(4)
        _tick(clk, store, slo, reg)
    assert ("any", "availability") in slo.firing()
    assert any(e["kind"] == "slo_burn_fired"
               and e["slo"] == "availability" for e in rec.events())
    for _ in range(25):                        # clean traffic drains it
        total.inc(5)
        _tick(clk, store, slo, reg)
    assert ("any", "availability") not in slo.firing()
    assert any(e["kind"] == "slo_burn_cleared"
               and e["slo"] == "availability" for e in rec.events())


# ----------------------------------------------------------------------
# end-to-end: live Router harnesses


def test_slo_fires_in_overload_deadline_burst_harness():
    """The overload-chaos scenario end-to-end: a wedged replica makes a
    burst of requests expire in its queue; the sampler feeds the real
    ``cluster_snapshot`` counters into the store and the fast-window
    availability alert fires, then clears once traffic is healthy."""
    reg = MetricsRegistry()
    rec = FlightRecorder()
    r = Router(metrics=reg)
    gate = threading.Event()
    r.add_replica(gated(gate), ReplicaConfig(max_batch=1))
    clk = FakeClock()
    store = TimeSeriesStore(clock=clk)
    slo = SLOEngine([scaled_objective()], reg, recorder=rec,
                    clock=clk)
    sampler = TelemetrySampler(r.cluster_snapshot, store, registry=reg,
                               slo=slo, clock=clk)
    try:
        sampler.tick()                         # baseline before the burst
        blocker = r.submit(1, timeout_s=30.0)
        victims = [r.submit(i, timeout_s=0.05) for i in range(8)]
        time.sleep(0.15)                       # deadlines pass while queued
        gate.set()                             # replica drains its queue:
        assert r.wait(blocker, timeout=10.0) == 2
        for q in victims:                      # ...dropping expired work
            assert q.done.wait(10.0)
        assert all(q.status is Status.EXPIRED for q in victims)
        for _ in range(4):
            clk.t += 0.1
            sampler.tick()
        assert ("any", "availability") in slo.firing()
        snap = reg.snapshot()
        assert snap["slo.any.availability.firing"] == 1.0
        assert any(e["kind"] == "slo_burn_fired" for e in rec.events())
        for i in range(8):                     # healthy traffic again
            assert r.wait(r.submit(10 + i, timeout_s=10.0),
                          timeout=10.0) == 2 * (10 + i)
        for _ in range(25):
            clk.t += 0.1
            sampler.tick()
        assert slo.firing() == []
        assert any(e["kind"] == "slo_burn_cleared"
                   for e in rec.events())
    finally:
        gate.set()
        r.stop()


def _monotone_keys(snap):
    """Counter-typed keys (plain counters, ``.count``, ``.le<i>``) —
    the ones cluster_snapshot must never regress."""
    return [k for k in snap
            if not is_gauge_key(k)
            and TimeSeriesStore.key_type(k) in ("counter", "bucket")]


def _assert_monotone(before, after, label):
    for k in _monotone_keys(before):
        assert after.get(k, 0.0) >= before[k] - 1e-9, \
            (label, k, before[k], after.get(k))


def test_cluster_counters_monotone_across_replica_kill_and_removal():
    """Departed-replica retention: removing a worker gracefully AND
    losing one to a crash must not regress any cluster-wide counter or
    histogram bucket count in ``cluster_snapshot()``."""
    reg = MetricsRegistry()
    r = Router(policy="round_robin", metrics=reg)
    workers = [r.add_replica(spec=echo_spec(delay_s=0.001), cfg=PROC_CFG,
                             transport="process")
               for _ in range(3)]
    reqs = [r.submit(i) for i in range(18)]
    assert [r.wait(q, 30.0) for q in reqs] == [2 * i for i in range(18)]
    # wait for worker-side counters (replica.batch_s.*) to ship over the
    # heartbeat channel so snapshot A actually holds worker-held keys
    deadline = time.monotonic() + 15.0
    while time.monotonic() < deadline:
        a = r.cluster_snapshot()
        if a.get("replica.batch_s.count", 0.0) > 0:
            break
        time.sleep(0.05)
    assert a.get("replica.batch_s.count", 0.0) > 0, \
        "worker counters never arrived over heartbeats"
    r.remove_replica(workers[0].rid)           # graceful removal
    b = r.cluster_snapshot()
    _assert_monotone(a, b, "after graceful removal")
    workers[1].inject_crash(soft=True)         # abrupt death
    more = [r.submit(100 + i) for i in range(6)]
    assert [r.wait(q, 30.0) for q in more] == \
        [2 * (100 + i) for i in range(6)]
    c = r.cluster_snapshot()
    r.stop()
    _assert_monotone(b, c, "after crash")
    # the new traffic actually moved the merged counters forward
    assert c["router.finish.total"] > b.get("router.finish.total", 0.0)
