"""Paged KV-cache engine: token-exact parity with the dense fused oracle,
prefix-cache reuse, copy-on-write forks, and eviction under pool pressure.

The paged path (block-pool caches, block-table decode, suffix-only admits
behind a content-hashed prefix cache) must be observationally invisible:
greedy token streams match the dense fused engine request-for-request,
including mid-K-loop completion + slot refill and max_len truncation.
"""
import jax
import numpy as np
import pytest

from tests._hyp_compat import given, settings, st

from repro.configs import get_config
from repro.configs.base import reduced
from repro.models import api, transformer as tfm
from repro.serving import BlockAllocator, Engine, PoolExhausted, ServeConfig
from repro.serving.kvpool import hash_token_blocks

# transformer families whose whole cache is position-addressed attention
# K/V — the pageable set (GQA incl. internlm2, MHA, MoE-with-plain-attn)
PAGED_FAMILIES = ["internlm2-1.8b",     # GQA 2:1 (reduced)
                  "gemma-7b",           # MHA, tied embeddings
                  "qwen3-moe-30b-a3b"]  # MoE (batch-1 admits), qk-norm


def _model(arch, seed=0):
    cfg = reduced(get_config(arch))
    params, _ = api.init(jax.random.PRNGKey(seed), cfg)
    return cfg, params


def _drain(params, cfg, scfg, prompts, max_new):
    eng = Engine(params, cfg, scfg)
    reqs = [eng.submit(p, max_new=max_new) for p in prompts]
    eng.run_until_drained()
    return eng, reqs


# ----------------------------------------------------------------------
# parity vs the dense fused oracle
@pytest.mark.parametrize("arch", PAGED_FAMILIES)
def test_paged_matches_dense_with_refill(arch):
    """5 requests through 2 slots: slots complete mid-K-loop and refill
    from the queue; K does not divide max_new; block_size smaller than
    most prompts so sequences span several blocks."""
    cfg, params = _model(arch)
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab, size=n).astype(np.int32)
               for n in (5, 9, 7, 12, 6)]
    _, dense = _drain(params, cfg,
                      ServeConfig(max_len=64, slots=2, fused=True,
                                  sync_every=4),
                      prompts, max_new=6)
    peng, paged = _drain(params, cfg,
                         ServeConfig(max_len=64, slots=2, fused=True,
                                     sync_every=4, paged=True, block_size=8),
                         prompts, max_new=6)
    assert peng.paged
    for i, (a, b) in enumerate(zip(dense, paged)):
        assert a.out_tokens == b.out_tokens, (arch, i)
        assert a.finish_reason == b.finish_reason == "max_new"
    # every request's blocks were released at finish
    assert peng.alloc.free_blocks + peng.alloc.cached_blocks == \
        peng.alloc.num_blocks


def test_paged_truncation_parity():
    """max_len truncation fires at the same token on both paths even when
    it lands mid-K-loop, and the paged slot frees its blocks."""
    cfg, params = _model("internlm2-1.8b")
    rng = np.random.RandomState(2)
    prompts = [rng.randint(0, cfg.vocab, size=n).astype(np.int32)
               for n in (4, 9)]
    _, dense = _drain(params, cfg,
                      ServeConfig(max_len=32, slots=2, fused=True,
                                  sync_every=8),
                      prompts, max_new=100)
    _, paged = _drain(params, cfg,
                      ServeConfig(max_len=32, slots=2, fused=True,
                                  sync_every=8, paged=True, block_size=8),
                      prompts, max_new=100)
    for a, b in zip(dense, paged):
        assert a.out_tokens == b.out_tokens
        assert a.finish_reason == b.finish_reason == "max_len"


def test_paged_kernel_path_matches_reference_path():
    """cfg.use_kernels routes paged decode through the Pallas kernel
    (interpret mode on CPU); tokens must match the jnp gather path."""
    cfg, params = _model("internlm2-1.8b")
    rng = np.random.RandomState(3)
    prompts = [rng.randint(0, cfg.vocab, size=n).astype(np.int32)
               for n in (6, 11)]
    scfg = ServeConfig(max_len=32, slots=2, fused=True, sync_every=4,
                       paged=True, block_size=8)
    _, ref = _drain(params, cfg, scfg, prompts, max_new=5)
    _, ker = _drain(params, cfg.replace(use_kernels=True), scfg,
                    prompts, max_new=5)
    for a, b in zip(ref, ker):
        assert a.out_tokens == b.out_tokens


def test_unpageable_family_falls_back_dense():
    """SSM state is not position-addressed: paged=True degrades to the
    dense fused path (observable, not silent) and still serves."""
    cfg, params = _model("falcon-mamba-7b")
    rng = np.random.RandomState(4)
    prompts = [rng.randint(0, cfg.vocab, size=6).astype(np.int32)]
    scfg = ServeConfig(max_len=32, slots=2, fused=True, paged=True,
                       block_size=8)
    eng, reqs = _drain(params, cfg, scfg, prompts, max_new=4)
    assert not eng.paged
    assert eng.metrics.counter("engine.paged_fallback_dense").value == 1
    assert all(r.done for r in reqs)


# ----------------------------------------------------------------------
# prefix cache
def test_prefix_cache_hits_and_accounting():
    """Second request with a shared 2-block prefix reuses the cached
    blocks (counters record hits and prefill tokens saved) and emits
    exactly the tokens a cold engine would."""
    cfg, params = _model("internlm2-1.8b")
    rng = np.random.RandomState(5)
    common = rng.randint(0, cfg.vocab, size=16).astype(np.int32)
    p1 = np.concatenate([common,
                         rng.randint(0, cfg.vocab, 4).astype(np.int32)])
    p2 = np.concatenate([common,
                         rng.randint(0, cfg.vocab, 3).astype(np.int32)])
    scfg = ServeConfig(max_len=64, slots=2, fused=True, sync_every=4,
                       paged=True, block_size=8)
    eng = Engine(params, cfg, scfg)
    r1 = eng.submit(p1, max_new=5)
    eng.run_until_drained()
    assert eng.metrics.counter("engine.prefix_hit_blocks").value == 0
    r2 = eng.submit(p2, max_new=5)
    eng.run_until_drained()
    assert eng.metrics.counter("engine.prefix_hit_blocks").value == 2
    assert eng.metrics.counter("engine.prefill_tokens_saved").value == 16
    # miss accounting: lookups counted in blocks, hits a subset
    assert eng.metrics.counter("engine.prefix_lookup_blocks").value == 4
    # parity with a cold dense engine for both requests
    _, dense = _drain(params, cfg,
                      ServeConfig(max_len=64, slots=2, fused=True,
                                  sync_every=4), [p1, p2], max_new=5)
    assert r1.out_tokens == dense[0].out_tokens
    assert r2.out_tokens == dense[1].out_tokens


def test_prefix_cache_survives_request_free():
    """Finishing a request keeps its full prompt blocks alive through the
    cache's own reference; an identical later prompt hits all of them."""
    cfg, params = _model("internlm2-1.8b")
    rng = np.random.RandomState(6)
    prompt = rng.randint(0, cfg.vocab, size=17).astype(np.int32)  # 2 full
    scfg = ServeConfig(max_len=64, slots=2, fused=True, paged=True,
                       block_size=8)
    eng = Engine(params, cfg, scfg)
    r1 = eng.submit(prompt.copy(), max_new=4)
    eng.run_until_drained()
    assert eng.alloc.cached_blocks == 2
    r2 = eng.submit(prompt.copy(), max_new=4)
    eng.run_until_drained()
    assert eng.metrics.counter("engine.prefix_hit_blocks").value == 2
    assert r1.out_tokens == r2.out_tokens


def test_eviction_under_pressure():
    """A pool too small to cache everything evicts LRU prefix blocks to
    satisfy new admits instead of refusing them; token streams stay exact
    vs dense throughout."""
    cfg, params = _model("internlm2-1.8b")
    rng = np.random.RandomState(7)
    prompts = [rng.randint(0, cfg.vocab, size=12).astype(np.int32)
               for _ in range(6)]
    # 2 slots x max_len=32/bs=8 dense-equivalent would be 8 blocks; give
    # the pool barely more than one sequence's worth so cached prefixes
    # must be evicted as new prompts arrive
    scfg = ServeConfig(max_len=32, slots=2, fused=True, sync_every=4,
                       paged=True, block_size=8, kv_blocks=7)
    eng, paged = _drain(params, cfg, scfg, prompts, max_new=4)
    assert eng.alloc.evictions > 0
    _, dense = _drain(params, cfg,
                      ServeConfig(max_len=32, slots=2, fused=True,
                                  sync_every=4), prompts, max_new=4)
    for a, b in zip(dense, paged):
        assert a.out_tokens == b.out_tokens


def test_admits_defer_under_pool_pressure():
    """When the pool cannot hold another prompt even after eviction, the
    admit waits in the queue (deferral counter) until blocks free up —
    nothing is dropped and nothing corrupts."""
    cfg, params = _model("internlm2-1.8b")
    rng = np.random.RandomState(8)
    prompts = [rng.randint(0, cfg.vocab, size=12).astype(np.int32)
               for _ in range(3)]
    scfg = ServeConfig(max_len=32, slots=3, fused=True, sync_every=4,
                       paged=True, block_size=8, kv_blocks=4,
                       prefix_cache=False)
    eng, reqs = _drain(params, cfg, scfg, prompts, max_new=4)
    assert all(r.done for r in reqs)
    assert eng.metrics.counter("engine.admit_deferred_kv").value > 0


# ----------------------------------------------------------------------
# copy-on-write forks
def test_fork_greedy_identical_and_cow_isolated():
    """A greedy fork shares the parent's blocks and must continue with
    exactly the parent's stream — COW splits only the written block, and
    the parent's subsequent tokens match an unforked run (shared history
    uncorrupted)."""
    cfg, params = _model("internlm2-1.8b")
    rng = np.random.RandomState(9)
    prompt = rng.randint(0, cfg.vocab, size=10).astype(np.int32)
    scfg = ServeConfig(max_len=64, slots=2, fused=True, sync_every=4,
                       paged=True, block_size=8)
    solo_eng, (solo,) = _drain(params, cfg, scfg, [prompt.copy()],
                               max_new=12)
    eng = Engine(params, cfg, scfg)
    parent = eng.submit(prompt.copy(), max_new=12)
    eng.step()                          # admit + one K-step sync
    child = eng.fork(parent, max_new=parent.max_new - parent.decoded)
    eng.run_until_drained()
    assert eng.alloc.cow_copies > 0
    assert parent.out_tokens == solo.out_tokens
    assert child.out_tokens == solo.out_tokens[:len(child.out_tokens)]


def test_fork_temperature_diverges():
    """With temperature sampling the forked branch explores its own
    continuation while sharing the prompt KV copy-on-write."""
    cfg, params = _model("internlm2-1.8b")
    rng = np.random.RandomState(10)
    prompt = rng.randint(0, cfg.vocab, size=9).astype(np.int32)
    scfg = ServeConfig(max_len=64, slots=2, fused=True, sync_every=4,
                       paged=True, block_size=8, temperature=1.0, seed=3)
    eng = Engine(params, cfg, scfg)
    parent = eng.submit(prompt, max_new=16)
    eng.step()
    fork_at = len(parent.out_tokens)
    child = eng.fork(parent, max_new=parent.max_new - parent.decoded)
    eng.run_until_drained()
    assert parent.out_tokens[:fork_at] == child.out_tokens[:fork_at]
    assert parent.out_tokens != child.out_tokens


def test_fork_requires_paged_and_active():
    cfg, params = _model("internlm2-1.8b")
    dense = Engine(params, cfg, ServeConfig(max_len=32, slots=2))
    req = dense.submit(np.arange(4, dtype=np.int32), max_new=2)
    with pytest.raises(RuntimeError, match="paged"):
        dense.fork(req, max_new=2)
    peng = Engine(params, cfg, ServeConfig(max_len=32, slots=2, paged=True,
                                           block_size=8))
    queued = peng.submit(np.arange(4, dtype=np.int32), max_new=2)
    with pytest.raises(ValueError, match="not active"):
        peng.fork(queued, max_new=2)


# ----------------------------------------------------------------------
# allocator unit behavior (host-side, no jax)
def test_allocator_refcounts_and_free():
    al = BlockAllocator(num_blocks=8, block_size=4)
    s1 = al.new_seq()
    fresh = al.extend_to(s1, 10)          # 3 blocks
    assert len(fresh) == 3 and al.free_blocks == 5
    s2 = al.fork(s1)
    assert all(al.refcount(b) == 2 for b in al.table(s1))
    al.free_seq(s1)
    assert all(al.refcount(b) == 1 for b in al.table(s2))
    al.free_seq(s2)
    assert al.free_blocks == 8


def test_allocator_cow_splits_only_written_range():
    al = BlockAllocator(num_blocks=8, block_size=4)
    s1 = al.new_seq()
    al.extend_to(s1, 12)                  # blocks for positions 0..11
    s2 = al.fork(s1)
    copies = al.cow_targets(s2, 9, 11)    # write range inside block 2
    assert len(copies) == 1
    assert al.table(s2)[:2] == al.table(s1)[:2]       # still shared
    assert al.table(s2)[2] != al.table(s1)[2]         # split
    assert al.refcount(al.table(s1)[2]) == 1
    assert al.cow_targets(s2, 9, 11) == []            # now private


def test_allocator_null_block_never_allocated():
    al = BlockAllocator(num_blocks=4, block_size=4)
    s = al.new_seq()
    al.extend_to(s, 16)
    assert 0 not in al.table(s)


def test_allocator_exhaustion_and_eviction():
    al = BlockAllocator(num_blocks=4, block_size=4)
    s1 = al.new_seq()
    al.extend_to(s1, 8)                   # 2 blocks live
    hashes = hash_token_blocks(list(range(8)), 4)
    al.prefix_insert(hashes, al.table(s1))
    al.free_seq(s1)                       # cache-only now: evictable
    assert al.free_blocks == 2 and al.evictable_blocks == 2
    s2 = al.new_seq()
    al.extend_to(s2, 16)                  # needs all 4 -> evicts 2
    assert al.evictions == 2
    with pytest.raises(PoolExhausted):
        al.extend_to(al.new_seq(), 4)


def test_oversized_prompt_rejected_individually():
    """A prompt the whole pool cannot hold completes empty with an
    explicit finish reason — it must not raise out of step() (killing its
    batch-mates) and must not wedge the queue behind it."""
    cfg, params = _model("internlm2-1.8b")
    rng = np.random.RandomState(11)
    ok_prompt = rng.randint(0, cfg.vocab, size=8).astype(np.int32)
    big_prompt = rng.randint(0, cfg.vocab, size=30).astype(np.int32)
    scfg = ServeConfig(max_len=32, slots=2, fused=True, sync_every=4,
                       paged=True, block_size=8, kv_blocks=4,
                       prefix_cache=False)
    eng = Engine(params, cfg, scfg)
    a = eng.submit(ok_prompt, max_new=3)
    b = eng.submit(big_prompt, max_new=3)       # needs 4+1 blocks > 4
    c = eng.submit(ok_prompt.copy(), max_new=3)
    eng.run_until_drained()
    assert a.done and a.finish_reason == "max_new"
    assert b.done and b.finish_reason == "rejected_prompt_too_long"
    assert b.out_tokens == []
    assert c.done and c.out_tokens == a.out_tokens
    assert eng.metrics.counter("engine.rejected_too_long").value == 1


def test_pool_exhausted_mid_decode_completes_victim():
    """When decode growth exhausts the pool with nothing evictable, the
    engine sacrifices the slot it could not extend: the victim completes
    with finish_reason="kv_pool_exhausted" (its emitted prefix intact and
    token-exact), its blocks return to the pool, and the surviving slot
    decodes on to a token-exact finish — nothing raises out of step()."""
    cfg, params = _model("internlm2-1.8b")
    rng = np.random.RandomState(12)
    prompts = [rng.randint(0, cfg.vocab, size=8).astype(np.int32)
               for _ in range(2)]
    # each sequence wants 32 positions = 4 blocks; 2 x 4 > 5 available,
    # so one slot must be sacrificed mid-decode
    scfg = ServeConfig(max_len=32, slots=2, fused=True, sync_every=4,
                       paged=True, block_size=8, kv_blocks=5,
                       prefix_cache=False)
    eng, reqs = _drain(params, cfg, scfg, prompts, max_new=24)
    assert all(r.done for r in reqs)
    reasons = [r.finish_reason for r in reqs]
    assert reasons.count("kv_pool_exhausted") == 1
    assert eng.metrics.counter("engine.kv_pool_exhausted").value == 1
    # every block returned to the pool when the requests finished
    assert eng.alloc.free_blocks == 5
    # both streams are exact prefixes of the dense oracle's: the victim
    # up to its eviction, the survivor to completion
    _, dense = _drain(params, cfg,
                      ServeConfig(max_len=32, slots=2, fused=True,
                                  sync_every=4), prompts, max_new=24)
    for a, b in zip(dense, reqs):
        assert b.out_tokens == a.out_tokens[:len(b.out_tokens)]
        if b.finish_reason != "kv_pool_exhausted":
            assert b.out_tokens == a.out_tokens
            assert b.finish_reason == a.finish_reason


def test_available_excluding_pinned_hits():
    """The admit headroom probe must not double-count its own prefix hits
    as evictable: taking the hits pins them, shrinking the eviction
    pool."""
    al = BlockAllocator(num_blocks=3, block_size=4)
    s = al.new_seq()
    al.extend_to(s, 12)
    hashes = hash_token_blocks(list(range(12)), 4)
    al.prefix_insert(hashes, al.table(s))
    al.free_seq(s)                         # all 3 blocks cache-only
    hits = al.prefix_lookup(hashes[:2])
    assert al.available_blocks == 3
    assert al.available_excluding(hits) == 1


def test_hash_token_blocks_chains_prefixes():
    bs = 4
    a = hash_token_blocks([1, 2, 3, 4, 5, 6, 7, 8, 9], bs)
    b = hash_token_blocks([1, 2, 3, 4, 5, 6, 7, 8, 42], bs)
    c = hash_token_blocks([9, 2, 3, 4, 5, 6, 7, 8], bs)
    assert len(a) == 2 and a[:2] == b[:2]     # full blocks identical
    assert c[0] != a[0] and c[1] != a[1]      # divergence chains forward


# ----------------------------------------------------------------------
# config validation
def test_serve_config_paged_validation():
    with pytest.raises(ValueError, match="fused"):
        ServeConfig(paged=True, fused=False)
    with pytest.raises(ValueError, match="block_size"):
        ServeConfig(paged=True, max_len=100, block_size=16)


def test_paged_supported_gate():
    assert tfm.paged_supported(reduced(get_config("internlm2-1.8b")), 64)
    assert tfm.paged_supported(reduced(get_config("qwen3-moe-30b-a3b")), 64)
    assert not tfm.paged_supported(reduced(get_config("falcon-mamba-7b")), 64)
    assert not tfm.paged_supported(
        reduced(get_config("recurrentgemma-2b")), 64)
    assert not tfm.paged_supported(
        reduced(get_config("deepseek-v2-lite-16b")), 64)
    assert not tfm.paged_supported(reduced(get_config("gemma3-4b")), 64)


# ----------------------------------------------------------------------
# KV lifecycle properties (PR 8): the swap serialization frame and the
# preempt/swap/restore decode path, over randomized shapes and loads.

@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_pack_unpack_bit_exact_property(seed):
    """serialize -> ship -> deserialize is bit-exact for arbitrary leaf
    counts, block counts, dtypes, and head geometries."""
    from repro.serving import pack_block_arrays, unpack_block_arrays

    rng = np.random.RandomState(seed % (2**31 - 1) or 1)
    n_leaves = int(rng.randint(1, 5))
    n_blocks = int(rng.randint(1, 7))
    arrays = []
    for _ in range(n_leaves):
        dt = np.dtype(["<f4", "<i4", "<f2", "<u1"][rng.randint(0, 4)])
        shape = (int(rng.randint(1, 3)), n_blocks, int(rng.randint(2, 9)),
                 int(rng.randint(1, 4)), int(rng.randint(2, 9)))
        if dt.kind == "f":
            a = rng.randn(*shape).astype(dt)
        else:
            a = rng.randint(0, 255, size=shape).astype(dt)
        arrays.append(a)
    out = unpack_block_arrays(pack_block_arrays(arrays))
    assert len(out) == n_leaves
    for a, b in zip(arrays, out):
        assert a.dtype == b.dtype and a.shape == b.shape
        assert a.tobytes() == b.tobytes()


_SWAP_MODEL = {}


@settings(max_examples=3, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_swap_restore_decode_token_exact_property(seed):
    """Whatever prompt lengths / budgets / request counts the seed draws,
    a tight swapping pool's restored block tables produce token-exact
    decode versus an ample-pool oracle, nothing finishes as a
    ``kv_pool_exhausted`` victim, and every swap-out is restored."""
    from repro.cluster.backends import shared_engine_fns

    if "m" not in _SWAP_MODEL:
        _SWAP_MODEL["m"] = _model("internlm2-1.8b")
    cfg, params = _SWAP_MODEL["m"]
    rng = np.random.RandomState(seed % (2**31 - 1) or 1)
    n_req = int(rng.randint(4, 7))
    prompts = [rng.randint(0, cfg.vocab,
                           size=int(rng.randint(4, 12))).astype(np.int32)
               for _ in range(n_req)]
    max_new = int(rng.randint(6, 14))
    ample = ServeConfig(max_len=48, slots=2, sync_every=4, paged=True,
                        block_size=8, kv_blocks=64, prefix_cache=False)
    tight = ServeConfig(max_len=48, slots=4, sync_every=4, paged=True,
                        block_size=8, kv_blocks=9, prefix_cache=True,
                        kv_swap=True)

    def drain(scfg):
        eng = Engine(params, cfg, scfg,
                     shared_fns=shared_engine_fns(cfg, scfg))
        reqs = [eng.submit(p.copy(), max_new=max_new) for p in prompts]
        eng.run_until_drained()
        return eng, reqs

    _, oracle = drain(ample)
    eng, got = drain(tight)
    for i, (a, b) in enumerate(zip(oracle, got)):
        assert b.finish_reason == "max_new", (i, b.finish_reason)
        assert a.out_tokens == b.out_tokens, \
            (i, a.out_tokens, b.out_tokens)
    snap = eng.metrics.snapshot()
    assert snap.get("engine.kv_pool_exhausted", 0) == 0
    assert snap.get("engine.kv_swap_in", 0) == \
        snap.get("engine.kv_swap_out", 0)
    assert eng.alloc.free_blocks + eng.alloc.cached_blocks == \
        eng.alloc.num_blocks
