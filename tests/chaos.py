"""Chaos harness for the cluster layer.

Drives a replica pool through a randomized *fault schedule* — worker
kills (SIGKILL / injected crash), connection drops, submission delays —
while a steady request stream flows through the router, then checks the
contract every transport promises:

  * **nothing is lost** — every submitted request reaches a terminal
    state (OK, REJECTED, or FAILED with an explicit error); none hang;
  * **nothing is double-completed** — ``ClusterRequest.complete`` fires
    at most once per request, however many times crashes force the
    at-least-once machinery to re-execute its batch;
  * **results are right** — every OK echo result equals ``2 * payload``.

Schedules derive deterministically from a seed, so the property tests in
``tests/test_chaos.py`` (via ``tests/_hyp_compat.py``) shrink/replay like
any other property.  The same harness runs against thread, process, and
socket transports — the point is that the zero-lost contract is a
property of the *transport surface*, not of any one carrier.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.cluster import (MetricsRegistry, ReplicaConfig, Router, Status,
                           echo_spec)
from repro.cluster.replica import ClusterRequest
from repro.cluster.transport import SocketTransport

# what a fault may do to a replica (or to the arrival stream)
ACTIONS = ("kill", "crash", "drop", "delay")


@dataclasses.dataclass(frozen=True)
class Fault:
    at_s: float             # offset from schedule start
    action: str             # one of ACTIONS
    target: int             # replica index (ignored for "delay")
    duration_s: float = 0.05  # "delay" only: arrival-stream stall


def random_schedule(seed: int, n_faults: int, horizon_s: float,
                    n_replicas: int,
                    actions: Sequence[str] = ACTIONS) -> List[Fault]:
    """Deterministic fault schedule from a seed."""
    rng = np.random.RandomState(seed)
    faults = [Fault(at_s=float(rng.uniform(0.0, horizon_s)),
                    action=str(rng.choice(list(actions))),
                    target=int(rng.randint(n_replicas)),
                    duration_s=float(rng.uniform(0.02, 0.15)))
              for _ in range(n_faults)]
    return sorted(faults, key=lambda f: f.at_s)


def partition_schedule(seed: int, n_partitions: int, horizon_s: float,
                       n_replicas: int,
                       duration_bounds_s=(0.3, 0.8)) -> List[Fault]:
    """Deterministic *partial*-partition schedule: each fault drops the
    worker→parent heartbeat direction on one replica for a window sized
    like the gap between autoscaler ticks, while acks and partial results
    keep flowing.  A busy replica must ride it out (acks refresh
    liveness); an idle one is declared dead by the heartbeat monitor and
    its queued work spills — either way the zero-lost contract holds.
    Kept out of :data:`ACTIONS` so existing seeded schedules replay
    byte-identically."""
    rng = np.random.RandomState(seed)
    faults = [Fault(at_s=float(rng.uniform(0.0, horizon_s)),
                    action="partition",
                    target=int(rng.randint(n_replicas)),
                    duration_s=float(rng.uniform(*duration_bounds_s)))
              for _ in range(n_partitions)]
    return sorted(faults, key=lambda f: f.at_s)


@dataclasses.dataclass
class ChaosReport:
    transport: str
    n_requests: int
    ok: int
    rejected: int
    failed: int
    lost: List[int]                       # payloads never reaching a terminal state
    double_completed: List[int]           # payloads completed more than once
    wrong_results: List[int]              # OK payloads with a wrong result
    crashes: float
    disconnects: float
    cancelled: int = 0                    # terminal via Router.cancel
    expired: int = 0                      # terminal via deadline expiry

    def assert_invariants(self) -> "ChaosReport":
        assert not self.lost, \
            f"{self.transport}: {len(self.lost)} request(s) lost " \
            f"(no terminal state): {self.lost[:10]}"
        assert not self.double_completed, \
            f"{self.transport}: double-completed: {self.double_completed[:10]}"
        assert not self.wrong_results, \
            f"{self.transport}: wrong results for {self.wrong_results[:10]}"
        total = self.ok + self.rejected + self.failed \
            + self.cancelled + self.expired
        assert total == self.n_requests, \
            f"{self.transport}: accounting leak: ok={self.ok} " \
            f"rejected={self.rejected} failed={self.failed} " \
            f"cancelled={self.cancelled} expired={self.expired} " \
            f"!= n={self.n_requests}"
        return self


class _CompletionCounter:
    """Counts ``ClusterRequest.complete`` invocations per request object
    via a class-level patch, so a double ack/requeue race that completes
    one request twice cannot hide behind the last-writer's result."""

    def __init__(self):
        self.counts: Dict[int, int] = {}
        self._lock = threading.Lock()
        self._orig = None

    def __enter__(self):
        self._orig = ClusterRequest.complete
        counter = self

        def counting_complete(req, result, replica_rid):
            with counter._lock:
                counter.counts[id(req)] = counter.counts.get(id(req), 0) + 1
            return counter._orig(req, result, replica_rid)

        ClusterRequest.complete = counting_complete
        return self

    def __exit__(self, *exc):
        ClusterRequest.complete = self._orig
        return False


def _apply_fault(fault: Fault, workers: List, gate: threading.Event) -> None:
    if fault.action == "delay":
        gate.clear()
        time.sleep(fault.duration_s)
        gate.set()
        return
    w = workers[fault.target % len(workers)]
    if fault.action == "partition":
        # one-way heartbeat drop (remote transports only: a thread replica
        # has no heartbeat channel to partition)
        if hasattr(w, "inject_hb_partition"):
            w.inject_hb_partition(fault.duration_s)
        return
    if fault.action == "drop" and isinstance(w, SocketTransport):
        w.sever_connection()          # partition: worker survives, reconnects
    elif fault.action == "crash":
        try:
            w.inject_crash(soft=True)  # in-worker raise at a loop checkpoint
        except TypeError:              # thread transport: one crash flavour
            w.inject_crash()
    else:                              # "kill" (and "drop" on non-sockets)
        w.inject_crash()


def run_chaos(transport: str, faults: Sequence[Fault], n_replicas: int = 3,
              n_requests: int = 120, horizon_s: float = 0.6,
              cfg: Optional[ReplicaConfig] = None, max_retries: int = 8,
              timeout_s: float = 60.0) -> ChaosReport:
    """Run one randomized episode and report the outcome tally.

    Requests are spread over ``horizon_s`` so faults land before, between,
    and after dispatches; ``gate`` models "delay" faults as arrival
    stalls.  Whatever the schedule does — including killing every replica
    — the invariants of :meth:`ChaosReport.assert_invariants` must hold.
    """
    if cfg is None:
        cfg = ReplicaConfig(inbox_capacity=512, max_batch=4,
                            heartbeat_timeout_s=1.5)
    metrics = MetricsRegistry()
    router = Router(policy="round_robin", metrics=metrics,
                    max_retries=max_retries, requeue_timeout_s=3.0)
    # "mixed" == one pool spanning every carrier at once: the contract is a
    # property of the Transport surface, so a heterogeneous pool must hold
    # it too
    placements = ("thread", "process", "socket") if transport == "mixed" \
        else (transport,) * n_replicas
    workers = [router.add_replica(spec=echo_spec(delay_s=0.002), cfg=cfg,
                                  transport=placements[i % len(placements)])
               for i in range(n_replicas)]
    gate = threading.Event()
    gate.set()
    reqs: List[ClusterRequest] = []
    pause = horizon_s / max(n_requests, 1)

    with _CompletionCounter() as counter:
        start = time.monotonic()
        stop_faults = threading.Event()

        def fault_loop():
            for f in faults:
                wait = start + f.at_s - time.monotonic()
                if wait > 0 and stop_faults.wait(wait):
                    return
                _apply_fault(f, workers, gate)

        injector = threading.Thread(target=fault_loop, daemon=True,
                                    name="chaos-injector")
        injector.start()
        try:
            for i in range(n_requests):
                gate.wait(1.0)
                reqs.append(router.submit(i, session_key=f"s{i % 7}",
                                          timeout_s=timeout_s))
                time.sleep(pause)
            t_end = time.monotonic() + timeout_s
            for q in reqs:
                q.done.wait(max(t_end - time.monotonic(), 0.1))
        finally:
            stop_faults.set()
            injector.join(timeout=5.0)
            router.stop(drain=True)

        lost = [q.payload for q in reqs if not q.done.is_set()]
        double = [q.payload for q in reqs
                  if counter.counts.get(id(q), 0) > 1]

    wrong = [q.payload for q in reqs
             if q.status is Status.OK and q.result != 2 * q.payload]
    snap = metrics.snapshot()
    return ChaosReport(
        transport=transport,
        n_requests=n_requests,
        ok=sum(q.status is Status.OK for q in reqs),
        rejected=sum(q.status is Status.REJECTED for q in reqs),
        failed=sum(q.status is Status.FAILED for q in reqs),
        lost=lost, double_completed=double, wrong_results=wrong,
        crashes=snap.get("replica.crashes", 0.0),
        disconnects=snap.get("replica.disconnects", 0.0),
        cancelled=sum(q.status is Status.CANCELLED for q in reqs),
        expired=sum(q.status is Status.EXPIRED for q in reqs))


# ----------------------------------------------------------------------
# Slow loris: a worker whose liveness signals stay green — the process is
# alive, the socket heartbeat thread keeps beating — but whose backend
# never returns, so nothing is ever acknowledged.  The schedule-driven
# harness above cannot express this (its faults *kill* things); the loris
# fails by succeeding at staying alive.  Detection is the transports' ack
# timeout (``ReplicaConfig.ack_timeout_s``): the router must eventually
# declare the loris dead, reroute its unacknowledged work to survivors,
# and complete everything exactly once.

def run_slow_loris(transport: str = "process", n_replicas: int = 3,
                   n_requests: int = 40, ack_timeout_s: float = 1.0,
                   timeout_s: float = 60.0) -> ChaosReport:
    assert transport in ("process", "socket"), \
        "slow-loris detection is an ack-timeout property of the remote " \
        "transports (a thread replica shares our interpreter; a stuck " \
        "thread cannot be safely disowned)"
    cfg = ReplicaConfig(inbox_capacity=512, max_batch=4,
                        heartbeat_timeout_s=30.0,   # hb never the trigger
                        ack_timeout_s=ack_timeout_s)
    metrics = MetricsRegistry()
    router = Router(policy="round_robin", metrics=metrics,
                    max_retries=4, requeue_timeout_s=5.0)
    workers = []
    for i in range(n_replicas):
        spec = echo_spec(delay_s=0.002) if i else \
            echo_spec(delay_s=0.002, stall_s=3600.0)   # replica 0: the loris
        workers.append(router.add_replica(spec=spec, cfg=cfg,
                                          transport=transport))
    loris = workers[0]
    reqs: List[ClusterRequest] = []
    with _CompletionCounter() as counter:
        try:
            for i in range(n_requests):
                reqs.append(router.submit(i, session_key=f"s{i % 7}",
                                          timeout_s=timeout_s))
                time.sleep(0.005)
            t_end = time.monotonic() + timeout_s
            for q in reqs:
                q.done.wait(max(t_end - time.monotonic(), 0.1))
        finally:
            router.stop(drain=True)
        lost = [q.payload for q in reqs if not q.done.is_set()]
        double = [q.payload for q in reqs
                  if counter.counts.get(id(q), 0) > 1]
    wrong = [q.payload for q in reqs
             if q.status is Status.OK and q.result != 2 * q.payload]
    snap = metrics.snapshot()
    assert snap.get("replica.ack_timeouts", 0.0) >= 1.0, \
        "the loris was never caught by the ack timeout"
    assert not loris.alive, "the loris must be declared dead"
    assert all(q.replica_rid != loris.rid for q in reqs
               if q.status is Status.OK), \
        "a never-acking replica cannot have completed anything"
    return ChaosReport(
        transport=f"{transport}+loris",
        n_requests=n_requests,
        ok=sum(q.status is Status.OK for q in reqs),
        rejected=sum(q.status is Status.REJECTED for q in reqs),
        failed=sum(q.status is Status.FAILED for q in reqs),
        lost=lost, double_completed=double, wrong_results=wrong,
        crashes=snap.get("replica.crashes", 0.0),
        disconnects=snap.get("replica.disconnects", 0.0),
        cancelled=sum(q.status is Status.CANCELLED for q in reqs),
        expired=sum(q.status is Status.EXPIRED for q in reqs))


# ----------------------------------------------------------------------
# KV-lifecycle chaos: preempt / drain / migrate / kill against a pool of
# paged LM engine replicas running deliberately tight KV pools with host
# swap enabled.  The invariant is sharper than the echo harness's "results
# are right": every OK request's *token stream* must be byte-identical to
# an undisturbed oracle run of the same prompt on an ample-pool engine —
# preemption, swap-out/-in, drain, and warm migration must all be
# observationally invisible to the end-user.

KV_ACTIONS = ("preempt", "drain", "migrate", "kill")


def kv_schedule(seed: int, n_faults: int, horizon_s: float,
                n_replicas: int,
                actions: Sequence[str] = KV_ACTIONS) -> List[Fault]:
    """Deterministic KV-lifecycle fault schedule from a seed."""
    rng = np.random.RandomState(seed)
    faults = [Fault(at_s=float(rng.uniform(0.0, horizon_s)),
                    action=str(rng.choice(list(actions))),
                    target=int(rng.randint(n_replicas)))
              for _ in range(n_faults)]
    return sorted(faults, key=lambda f: f.at_s)


def _lm_backends(n: int, *, kv_blocks: int, slots: int = 4,
                 block_size: int = 8, max_len: int = 48,
                 sync_every: int = 4, kv_swap: bool = True,
                 prefix_cache: bool = True):
    """``n`` live EngineBackends over one shared param set + jit cache.

    Heavy imports stay inside: the echo-harness tests must not pay the
    jax import.  Sharing params and the per-process fn cache means one
    compile serves the whole pool (and the oracle engine, pool size
    aside)."""
    import jax

    from repro.cluster.backends import shared_engine_fns
    from repro.cluster.replica import EngineBackend
    from repro.configs import get_config
    from repro.configs.base import reduced
    from repro.models import api
    from repro.serving import Engine, ServeConfig

    cfg = reduced(get_config("internlm2-1.8b"))
    params, _ = api.init(jax.random.PRNGKey(0), cfg)
    scfg = ServeConfig(max_len=max_len, slots=slots, sync_every=sync_every,
                       paged=True, block_size=block_size,
                       kv_blocks=kv_blocks, prefix_cache=prefix_cache,
                       kv_swap=kv_swap)
    fns = shared_engine_fns(cfg, scfg)
    return cfg, [EngineBackend(Engine(params, cfg, scfg, shared_fns=fns))
                 for _ in range(n)]


def kv_oracle(prompts, max_new: int) -> Dict[tuple, list]:
    """Undisturbed token streams: one ample-pool engine (no swap, no
    pressure) decodes every distinct prompt once.  Greedy decode from the
    shared seed-0 params depends only on (prompt, max_new), so this is
    the ground truth any chaotic run must reproduce byte-for-byte."""
    _, (backend,) = _lm_backends(1, kv_blocks=64, slots=4, kv_swap=False,
                                 prefix_cache=False)
    eng = backend.engine
    keys, reqs = [], {}
    for p in prompts:
        k = (p.tobytes(), max_new)
        if k not in reqs:
            keys.append(k)
            reqs[k] = eng.submit(p.copy(), max_new=max_new)
    eng.run_until_drained()
    return {k: list(reqs[k].out_tokens) for k in keys}


def run_kv_chaos(faults: Sequence[Fault], seed: int = 0,
                 n_replicas: int = 3, n_requests: int = 10,
                 horizon_s: float = 1.5, kv_blocks: int = 10,
                 max_new: int = 12, timeout_s: float = 240.0):
    """One KV-lifecycle chaos episode.

    A steady stream of LM sessions flows through a session-affinity
    router while the schedule preempts (pressure bursts that force
    swap-out), drains, warm-migrates, and kills replicas.  Returns
    ``(ChaosReport, router_metrics_snapshot, backends)`` — the report's
    ``wrong_results`` compares token streams against :func:`kv_oracle`,
    and the snapshot/backends let callers assert that swaps and
    migrations actually happened (a chaos run that never hit the
    machinery under test proves nothing)."""
    cfg, backends = _lm_backends(n_replicas, kv_blocks=kv_blocks)
    rng = np.random.RandomState(seed)
    prompts = [rng.randint(0, cfg.vocab,
                           size=int(rng.randint(6, 12))).astype(np.int32)
               for _ in range(n_requests)]
    oracle = kv_oracle(prompts, max_new)
    # pre-warm each engine's jit outside the fault window so the schedule
    # offsets land on serving time, not compile time
    for b in backends:
        b.engine.submit(prompts[0].copy(), max_new=2)
        b.engine.run_until_drained()

    metrics = MetricsRegistry()
    router = Router(policy="session_affinity", metrics=metrics,
                    max_retries=8, requeue_timeout_s=3.0)
    rcfg = ReplicaConfig(inbox_capacity=512, max_batch=4)
    workers = [router.add_replica(b, rcfg, kind="lm") for b in backends]

    submit_lock = threading.Lock()
    reqs: List[tuple] = []                  # (ClusterRequest, oracle key)

    def submit(i: int, p) -> None:
        with submit_lock:
            q = router.submit((p.copy(), max_new), session_key=f"s{i}",
                              kind="lm", timeout_s=timeout_s)
            reqs.append((q, (p.tobytes(), max_new)))

    def apply(fault: Fault) -> None:
        if fault.action == "preempt":
            # pressure burst: three sessions land at once so the target
            # pool oversubscribes and must swap, not victimize
            for j, p in enumerate(prompts[:3]):
                submit(1000 + fault.target * 10 + j, p)
            return
        alive = [w for w in workers if w.alive]
        if not alive:
            return
        w = alive[fault.target % len(alive)]
        if fault.action == "kill":
            w.inject_crash()
        elif router.n_alive() > 1:          # "drain" / "migrate"
            router.remove_replica(w.rid, drain=True,
                                  migrate=(fault.action == "migrate"))

    pause = horizon_s / max(n_requests, 1)
    with _CompletionCounter() as counter:
        start = time.monotonic()
        stop_faults = threading.Event()

        def fault_loop():
            for f in faults:
                wait = start + f.at_s - time.monotonic()
                if wait > 0 and stop_faults.wait(wait):
                    return
                apply(f)

        injector = threading.Thread(target=fault_loop, daemon=True,
                                    name="kv-chaos-injector")
        injector.start()
        try:
            for i, p in enumerate(prompts):
                submit(i, p)
                time.sleep(pause)
            # let every scheduled fault fire (and its burst submits land)
            # before the terminal wait, so the report covers them all
            injector.join(timeout=horizon_s + 10.0)
            t_end = time.monotonic() + timeout_s
            for q, _ in list(reqs):
                q.done.wait(max(t_end - time.monotonic(), 0.1))
        finally:
            stop_faults.set()
            injector.join(timeout=10.0)
            router.stop(drain=True)

        lost = [i for i, (q, _) in enumerate(reqs) if not q.done.is_set()]
        double = [i for i, (q, _) in enumerate(reqs)
                  if counter.counts.get(id(q), 0) > 1]

    wrong = [i for i, (q, k) in enumerate(reqs)
             if q.status is Status.OK and list(q.result) != oracle[k]]
    snap = metrics.snapshot()
    report = ChaosReport(
        transport="thread+kv",
        n_requests=len(reqs),
        ok=sum(q.status is Status.OK for q, _ in reqs),
        rejected=sum(q.status is Status.REJECTED for q, _ in reqs),
        failed=sum(q.status is Status.FAILED for q, _ in reqs),
        lost=lost, double_completed=double, wrong_results=wrong,
        crashes=snap.get("replica.crashes", 0.0),
        disconnects=snap.get("replica.disconnects", 0.0),
        cancelled=sum(q.status is Status.CANCELLED for q, _ in reqs),
        expired=sum(q.status is Status.EXPIRED for q, _ in reqs))
    return report, snap, backends


# ----------------------------------------------------------------------
# Overload chaos: cancellation, deadline expiry, and poison requests
# racing the crash/spill/requeue machinery.  The invariants sharpen the
# echo harness's contract:
#
#   * nothing expired completes "ok" — any OK request finished inside its
#     deadline (``complete()`` downgrades late acks, so this holds even
#     against a worker that ignored the wire budget);
#   * a cancelled request reaches exactly one terminal state and is never
#     re-dispatched after it (a cancel losing the race to a genuine
#     completion is a legal no-op: OK wins);
#   * a poison request — one that crashes whatever serves it — kills at
#     most ``poison_threshold`` distinct replicas before the router stops
#     retrying it (finish_reason="poison").

OVERLOAD_ACTIONS = ("cancel", "expire", "kill", "delay")
POISON_PAYLOAD = 666_666


def overload_schedule(seed: int, n_faults: int, horizon_s: float,
                      n_replicas: int,
                      actions: Sequence[str] = OVERLOAD_ACTIONS,
                      ) -> List[Fault]:
    """Deterministic overload fault schedule.  A separate helper (not a
    new entry in :data:`ACTIONS`) so existing seeded schedules replay
    byte-identically."""
    rng = np.random.RandomState(seed)
    faults = [Fault(at_s=float(rng.uniform(0.0, horizon_s)),
                    action=str(rng.choice(list(actions))),
                    target=int(rng.randint(max(n_replicas, 1))),
                    duration_s=float(rng.uniform(0.02, 0.10)))
              for _ in range(n_faults)]
    return sorted(faults, key=lambda f: f.at_s)


def run_overload_chaos(transport: str, faults: Sequence[Fault],
                       n_replicas: int = 3, n_requests: int = 80,
                       horizon_s: float = 0.8,
                       cfg: Optional[ReplicaConfig] = None,
                       max_retries: int = 8, timeout_s: float = 60.0,
                       expire_budget_s: float = 0.03,
                       n_poison: int = 1, poison_threshold: int = 2):
    """One overload episode: a steady echo stream plus *request-level*
    faults — "cancel" cancels a recent in-flight request, "expire"
    submits a request with a deliberately tiny deadline budget, "kill"
    and "delay" behave as in :func:`run_chaos`.  ``n_poison``
    replica-killer payloads are injected mid-stream.

    Returns ``(ChaosReport, metrics_snapshot, info)`` where ``info``
    holds the faulted request objects (``cancel_targets``,
    ``expire_reqs``, ``poison_reqs``) and every submitted request
    (``reqs``) for invariant checks the tally alone cannot express.
    """
    if cfg is None:
        cfg = ReplicaConfig(inbox_capacity=512, max_batch=4,
                            heartbeat_timeout_s=1.5)
    metrics = MetricsRegistry()
    router = Router(policy="round_robin", metrics=metrics,
                    max_retries=max_retries, requeue_timeout_s=3.0,
                    poison_threshold=poison_threshold,
                    retry_backoff_base_s=0.002, retry_backoff_max_s=0.02)
    placements = ("thread", "process", "socket") if transport == "mixed" \
        else (transport,) * n_replicas
    workers = [router.add_replica(
                   spec=echo_spec(delay_s=0.002, poison=POISON_PAYLOAD),
                   cfg=cfg, transport=placements[i % len(placements)])
               for i in range(n_replicas)]
    gate = threading.Event()
    gate.set()
    submit_lock = threading.Lock()
    reqs: List[ClusterRequest] = []
    cancel_targets: List[ClusterRequest] = []
    expire_reqs: List[ClusterRequest] = []
    poison_reqs: List[ClusterRequest] = []
    pause = horizon_s / max(n_requests, 1)

    def apply(fault: Fault) -> None:
        if fault.action == "cancel":
            with submit_lock:
                if not reqs:
                    return
                # a recent request: likely queued or in flight, so the
                # cancel races dispatch/spill rather than a settled state
                q = reqs[-1 - (fault.target % min(len(reqs), 8))]
                cancel_targets.append(q)
            router.cancel(q)
            return
        if fault.action == "expire":
            with submit_lock:
                q = router.submit(10_000 + len(expire_reqs),
                                  session_key="exp",
                                  timeout_s=expire_budget_s)
                reqs.append(q)
                expire_reqs.append(q)
            return
        _apply_fault(fault, workers, gate)

    with _CompletionCounter() as counter:
        start = time.monotonic()
        stop_faults = threading.Event()

        def fault_loop():
            for f in faults:
                wait = start + f.at_s - time.monotonic()
                if wait > 0 and stop_faults.wait(wait):
                    return
                apply(f)

        injector = threading.Thread(target=fault_loop, daemon=True,
                                    name="overload-chaos-injector")
        injector.start()
        try:
            for i in range(n_requests):
                gate.wait(1.0)
                with submit_lock:
                    q = router.submit(i, session_key=f"s{i % 7}",
                                      timeout_s=timeout_s)
                    reqs.append(q)
                    if n_poison and i == n_requests // 4 + 1:
                        # poison lands early, while the pool is healthy,
                        # so the retry budget (not pool exhaustion) is
                        # what bounds its blast radius
                        for _ in range(n_poison):
                            pq = router.submit(POISON_PAYLOAD,
                                               session_key="poison",
                                               timeout_s=timeout_s)
                            reqs.append(pq)
                            poison_reqs.append(pq)
                time.sleep(pause)
            injector.join(timeout=horizon_s + 10.0)
            t_end = time.monotonic() + timeout_s
            for q in list(reqs):
                q.done.wait(max(t_end - time.monotonic(), 0.1))
        finally:
            stop_faults.set()
            injector.join(timeout=5.0)
            router.stop(drain=True)

        lost = [q.payload for q in reqs if not q.done.is_set()]
        double = [q.payload for q in reqs
                  if counter.counts.get(id(q), 0) > 1]

    wrong = [q.payload for q in reqs
             if q.status is Status.OK and q.result != 2 * q.payload]
    snap = metrics.snapshot()
    report = ChaosReport(
        transport=f"{transport}+overload",
        n_requests=len(reqs),
        ok=sum(q.status is Status.OK for q in reqs),
        rejected=sum(q.status is Status.REJECTED for q in reqs),
        failed=sum(q.status is Status.FAILED for q in reqs),
        lost=lost, double_completed=double, wrong_results=wrong,
        crashes=snap.get("replica.crashes", 0.0),
        disconnects=snap.get("replica.disconnects", 0.0),
        cancelled=sum(q.status is Status.CANCELLED for q in reqs),
        expired=sum(q.status is Status.EXPIRED for q in reqs))
    info = {"reqs": reqs, "cancel_targets": cancel_targets,
            "expire_reqs": expire_reqs, "poison_reqs": poison_reqs,
            "expire_budget_s": expire_budget_s,
            "poison_threshold": poison_threshold}
    return report, snap, info


def assert_overload_invariants(report: ChaosReport, info: dict) -> None:
    """The overload-specific contract, on top of the base invariants."""
    report.assert_invariants()
    eps = 0.005
    for q in info["reqs"]:
        if q.status is Status.OK and q.deadline_s != float("inf"):
            assert q.finished_s <= q.deadline_s + eps, \
                f"request {q.payload} completed OK past its deadline " \
                f"({q.finished_s - q.deadline_s:.3f}s late)"
    for q in info["cancel_targets"]:
        # OK-wins-race: the cancel may have lost to a genuine completion
        # (or to a backpressure shed that already rejected the target) —
        # but it must be terminal and completed at most once
        assert q.done.is_set(), "cancel target never reached terminal state"
        assert q.status in (Status.OK, Status.CANCELLED, Status.FAILED,
                            Status.EXPIRED, Status.REJECTED)
    for q in info["poison_reqs"]:
        assert q.done.is_set(), "poison request never reached terminal state"
        assert q.status is not Status.OK, \
            "a replica-killing payload cannot have completed OK"
        assert len(q.killed_replicas) <= info["poison_threshold"], \
            f"poison request killed {len(q.killed_replicas)} replicas, " \
            f"budget was {info['poison_threshold']}"
