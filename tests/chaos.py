"""Chaos harness for the cluster layer.

Drives a replica pool through a randomized *fault schedule* — worker
kills (SIGKILL / injected crash), connection drops, submission delays —
while a steady request stream flows through the router, then checks the
contract every transport promises:

  * **nothing is lost** — every submitted request reaches a terminal
    state (OK, REJECTED, or FAILED with an explicit error); none hang;
  * **nothing is double-completed** — ``ClusterRequest.complete`` fires
    at most once per request, however many times crashes force the
    at-least-once machinery to re-execute its batch;
  * **results are right** — every OK echo result equals ``2 * payload``.

Schedules derive deterministically from a seed, so the property tests in
``tests/test_chaos.py`` (via ``tests/_hyp_compat.py``) shrink/replay like
any other property.  The same harness runs against thread, process, and
socket transports — the point is that the zero-lost contract is a
property of the *transport surface*, not of any one carrier.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.cluster import (MetricsRegistry, ReplicaConfig, Router, Status,
                           echo_spec)
from repro.cluster.replica import ClusterRequest
from repro.cluster.transport import SocketTransport

# what a fault may do to a replica (or to the arrival stream)
ACTIONS = ("kill", "crash", "drop", "delay")


@dataclasses.dataclass(frozen=True)
class Fault:
    at_s: float             # offset from schedule start
    action: str             # one of ACTIONS
    target: int             # replica index (ignored for "delay")
    duration_s: float = 0.05  # "delay" only: arrival-stream stall


def random_schedule(seed: int, n_faults: int, horizon_s: float,
                    n_replicas: int,
                    actions: Sequence[str] = ACTIONS) -> List[Fault]:
    """Deterministic fault schedule from a seed."""
    rng = np.random.RandomState(seed)
    faults = [Fault(at_s=float(rng.uniform(0.0, horizon_s)),
                    action=str(rng.choice(list(actions))),
                    target=int(rng.randint(n_replicas)),
                    duration_s=float(rng.uniform(0.02, 0.15)))
              for _ in range(n_faults)]
    return sorted(faults, key=lambda f: f.at_s)


def partition_schedule(seed: int, n_partitions: int, horizon_s: float,
                       n_replicas: int,
                       duration_bounds_s=(0.3, 0.8)) -> List[Fault]:
    """Deterministic *partial*-partition schedule: each fault drops the
    worker→parent heartbeat direction on one replica for a window sized
    like the gap between autoscaler ticks, while acks and partial results
    keep flowing.  A busy replica must ride it out (acks refresh
    liveness); an idle one is declared dead by the heartbeat monitor and
    its queued work spills — either way the zero-lost contract holds.
    Kept out of :data:`ACTIONS` so existing seeded schedules replay
    byte-identically."""
    rng = np.random.RandomState(seed)
    faults = [Fault(at_s=float(rng.uniform(0.0, horizon_s)),
                    action="partition",
                    target=int(rng.randint(n_replicas)),
                    duration_s=float(rng.uniform(*duration_bounds_s)))
              for _ in range(n_partitions)]
    return sorted(faults, key=lambda f: f.at_s)


@dataclasses.dataclass
class ChaosReport:
    transport: str
    n_requests: int
    ok: int
    rejected: int
    failed: int
    lost: List[int]                       # payloads never reaching a terminal state
    double_completed: List[int]           # payloads completed more than once
    wrong_results: List[int]              # OK payloads with a wrong result
    crashes: float
    disconnects: float

    def assert_invariants(self) -> "ChaosReport":
        assert not self.lost, \
            f"{self.transport}: {len(self.lost)} request(s) lost " \
            f"(no terminal state): {self.lost[:10]}"
        assert not self.double_completed, \
            f"{self.transport}: double-completed: {self.double_completed[:10]}"
        assert not self.wrong_results, \
            f"{self.transport}: wrong results for {self.wrong_results[:10]}"
        assert self.ok + self.rejected + self.failed == self.n_requests
        return self


class _CompletionCounter:
    """Counts ``ClusterRequest.complete`` invocations per request object
    via a class-level patch, so a double ack/requeue race that completes
    one request twice cannot hide behind the last-writer's result."""

    def __init__(self):
        self.counts: Dict[int, int] = {}
        self._lock = threading.Lock()
        self._orig = None

    def __enter__(self):
        self._orig = ClusterRequest.complete
        counter = self

        def counting_complete(req, result, replica_rid):
            with counter._lock:
                counter.counts[id(req)] = counter.counts.get(id(req), 0) + 1
            return counter._orig(req, result, replica_rid)

        ClusterRequest.complete = counting_complete
        return self

    def __exit__(self, *exc):
        ClusterRequest.complete = self._orig
        return False


def _apply_fault(fault: Fault, workers: List, gate: threading.Event) -> None:
    if fault.action == "delay":
        gate.clear()
        time.sleep(fault.duration_s)
        gate.set()
        return
    w = workers[fault.target % len(workers)]
    if fault.action == "partition":
        # one-way heartbeat drop (remote transports only: a thread replica
        # has no heartbeat channel to partition)
        if hasattr(w, "inject_hb_partition"):
            w.inject_hb_partition(fault.duration_s)
        return
    if fault.action == "drop" and isinstance(w, SocketTransport):
        w.sever_connection()          # partition: worker survives, reconnects
    elif fault.action == "crash":
        try:
            w.inject_crash(soft=True)  # in-worker raise at a loop checkpoint
        except TypeError:              # thread transport: one crash flavour
            w.inject_crash()
    else:                              # "kill" (and "drop" on non-sockets)
        w.inject_crash()


def run_chaos(transport: str, faults: Sequence[Fault], n_replicas: int = 3,
              n_requests: int = 120, horizon_s: float = 0.6,
              cfg: Optional[ReplicaConfig] = None, max_retries: int = 8,
              timeout_s: float = 60.0) -> ChaosReport:
    """Run one randomized episode and report the outcome tally.

    Requests are spread over ``horizon_s`` so faults land before, between,
    and after dispatches; ``gate`` models "delay" faults as arrival
    stalls.  Whatever the schedule does — including killing every replica
    — the invariants of :meth:`ChaosReport.assert_invariants` must hold.
    """
    if cfg is None:
        cfg = ReplicaConfig(inbox_capacity=512, max_batch=4,
                            heartbeat_timeout_s=1.5)
    metrics = MetricsRegistry()
    router = Router(policy="round_robin", metrics=metrics,
                    max_retries=max_retries, requeue_timeout_s=3.0)
    # "mixed" == one pool spanning every carrier at once: the contract is a
    # property of the Transport surface, so a heterogeneous pool must hold
    # it too
    placements = ("thread", "process", "socket") if transport == "mixed" \
        else (transport,) * n_replicas
    workers = [router.add_replica(spec=echo_spec(delay_s=0.002), cfg=cfg,
                                  transport=placements[i % len(placements)])
               for i in range(n_replicas)]
    gate = threading.Event()
    gate.set()
    reqs: List[ClusterRequest] = []
    pause = horizon_s / max(n_requests, 1)

    with _CompletionCounter() as counter:
        start = time.monotonic()
        stop_faults = threading.Event()

        def fault_loop():
            for f in faults:
                wait = start + f.at_s - time.monotonic()
                if wait > 0 and stop_faults.wait(wait):
                    return
                _apply_fault(f, workers, gate)

        injector = threading.Thread(target=fault_loop, daemon=True,
                                    name="chaos-injector")
        injector.start()
        try:
            for i in range(n_requests):
                gate.wait(1.0)
                reqs.append(router.submit(i, session_key=f"s{i % 7}",
                                          timeout_s=timeout_s))
                time.sleep(pause)
            t_end = time.monotonic() + timeout_s
            for q in reqs:
                q.done.wait(max(t_end - time.monotonic(), 0.1))
        finally:
            stop_faults.set()
            injector.join(timeout=5.0)
            router.stop(drain=True)

        lost = [q.payload for q in reqs if not q.done.is_set()]
        double = [q.payload for q in reqs
                  if counter.counts.get(id(q), 0) > 1]

    wrong = [q.payload for q in reqs
             if q.status is Status.OK and q.result != 2 * q.payload]
    snap = metrics.snapshot()
    return ChaosReport(
        transport=transport,
        n_requests=n_requests,
        ok=sum(q.status is Status.OK for q in reqs),
        rejected=sum(q.status is Status.REJECTED for q in reqs),
        failed=sum(q.status is Status.FAILED for q in reqs),
        lost=lost, double_completed=double, wrong_results=wrong,
        crashes=snap.get("replica.crashes", 0.0),
        disconnects=snap.get("replica.disconnects", 0.0))


# ----------------------------------------------------------------------
# Slow loris: a worker whose liveness signals stay green — the process is
# alive, the socket heartbeat thread keeps beating — but whose backend
# never returns, so nothing is ever acknowledged.  The schedule-driven
# harness above cannot express this (its faults *kill* things); the loris
# fails by succeeding at staying alive.  Detection is the transports' ack
# timeout (``ReplicaConfig.ack_timeout_s``): the router must eventually
# declare the loris dead, reroute its unacknowledged work to survivors,
# and complete everything exactly once.

def run_slow_loris(transport: str = "process", n_replicas: int = 3,
                   n_requests: int = 40, ack_timeout_s: float = 1.0,
                   timeout_s: float = 60.0) -> ChaosReport:
    assert transport in ("process", "socket"), \
        "slow-loris detection is an ack-timeout property of the remote " \
        "transports (a thread replica shares our interpreter; a stuck " \
        "thread cannot be safely disowned)"
    cfg = ReplicaConfig(inbox_capacity=512, max_batch=4,
                        heartbeat_timeout_s=30.0,   # hb never the trigger
                        ack_timeout_s=ack_timeout_s)
    metrics = MetricsRegistry()
    router = Router(policy="round_robin", metrics=metrics,
                    max_retries=4, requeue_timeout_s=5.0)
    workers = []
    for i in range(n_replicas):
        spec = echo_spec(delay_s=0.002) if i else \
            echo_spec(delay_s=0.002, stall_s=3600.0)   # replica 0: the loris
        workers.append(router.add_replica(spec=spec, cfg=cfg,
                                          transport=transport))
    loris = workers[0]
    reqs: List[ClusterRequest] = []
    with _CompletionCounter() as counter:
        try:
            for i in range(n_requests):
                reqs.append(router.submit(i, session_key=f"s{i % 7}",
                                          timeout_s=timeout_s))
                time.sleep(0.005)
            t_end = time.monotonic() + timeout_s
            for q in reqs:
                q.done.wait(max(t_end - time.monotonic(), 0.1))
        finally:
            router.stop(drain=True)
        lost = [q.payload for q in reqs if not q.done.is_set()]
        double = [q.payload for q in reqs
                  if counter.counts.get(id(q), 0) > 1]
    wrong = [q.payload for q in reqs
             if q.status is Status.OK and q.result != 2 * q.payload]
    snap = metrics.snapshot()
    assert snap.get("replica.ack_timeouts", 0.0) >= 1.0, \
        "the loris was never caught by the ack timeout"
    assert not loris.alive, "the loris must be declared dead"
    assert all(q.replica_rid != loris.rid for q in reqs
               if q.status is Status.OK), \
        "a never-acking replica cannot have completed anything"
    return ChaosReport(
        transport=f"{transport}+loris",
        n_requests=n_requests,
        ok=sum(q.status is Status.OK for q in reqs),
        rejected=sum(q.status is Status.REJECTED for q in reqs),
        failed=sum(q.status is Status.FAILED for q in reqs),
        lost=lost, double_completed=double, wrong_results=wrong,
        crashes=snap.get("replica.crashes", 0.0),
        disconnects=snap.get("replica.disconnects", 0.0))
